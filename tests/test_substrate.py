"""Optimizer, data determinism, checkpointing (atomicity/keep-k/elastic),
trainer convergence + resume, serving engine, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.synthetic import SyntheticLMDataset, synthetic_digits
from repro.models import build_model
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8, decompress_int8, error_feedback_init,
                         warmup_cosine)
from repro.serve import DecodeEngine, ServeConfig
from repro.train import CheckpointManager, Trainer, TrainerConfig


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    import dataclasses
    from repro.optim.adamw import AdamWConfig
    cfg = AdamWConfig(weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw_update(g, state, params, 0.1, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_schedule_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(100)) < float(s(50)) < float(s(10))


def test_data_determinism_and_sharding():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=8, global_batch=8)
    b1 = ds.batch(3)
    b2 = ds.batch(3)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # shards partition deterministically
    s0 = ds.batch(3, shard=0, n_shards=2)
    assert s0["tokens"].shape[0] == 4


def test_checkpoint_atomic_keep_k_elastic():
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=2)
        state = {"w": jnp.arange(6.0).reshape(2, 3), "n": jnp.int32(7)}
        for step in (1, 2, 3):
            ck.save(step, state, blocking=True)
        assert ck.all_steps() == [2, 3]          # keep-k GC
        restored = ck.restore(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
        assert np.array_equal(np.asarray(restored["w"]),
                              np.asarray(state["w"]))
        # corrupt tmp dirs are ignored (atomicity)
        os.makedirs(os.path.join(d, "step_00000099.tmp"))
        assert ck.latest_step() == 3


def test_trainer_convergence_and_resume():
    cfg = get_arch("h2o-danube-3-4b").reduced(n_layers=2, d_model=32,
                                              d_ff=64, vocab=128)
    model = build_model(cfg)
    ds = SyntheticLMDataset(cfg.vocab_size, 8, 4)
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(peak_lr=5e-3, warmup_steps=2, total_steps=20,
                             checkpoint_dir=d, checkpoint_every=10)
        tr = Trainer(model.loss, tcfg)
        p0 = model.init(jax.random.key(0))
        _, _, hist = tr.fit(p0, lambda s: ds.batch(s), steps=20,
                            log_every=5)
        assert hist[-1]["loss"] < hist[0]["loss"]
        # resume: a fresh trainer starts from step 20 (nothing to do)
        tr2 = Trainer(model.loss, tcfg)
        _, _, h2 = tr2.fit(model.init(jax.random.key(1)),
                           lambda s: ds.batch(s), steps=20)
        assert h2 == []


def test_serving_engine_continuous_batching():
    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=32,
                                             d_ff=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = DecodeEngine(model, params, ServeConfig(max_len=48, batch_slots=2))
    outs = eng.generate([[1, 2], [3], [4, 5, 6], [7]], max_new_tokens=4)
    assert len(outs) == 4 and all(len(o) == 4 for o in outs)


def test_serving_engine_prefill_conditions_on_full_prompt():
    """Regression: completions must depend on EARLY prompt tokens — the
    old engine only fed the last prompt token into the KV cache."""
    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=32,
                                             d_ff=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = DecodeEngine(model, params, ServeConfig(max_len=48, batch_slots=2))
    a = eng.generate([[5, 9, 2, 7]], max_new_tokens=6)[0]
    b = eng.generate([[11, 3, 2, 7]], max_new_tokens=6)[0]  # same suffix
    assert a != b
    # greedy decode of a slot must not depend on its wave companions
    c = eng.generate([[5, 9, 2, 7], [1, 2]], max_new_tokens=6)
    assert c[0] == a


def test_grad_compression_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    # accumulated decompressed grads converge to accumulated true grads
    for _ in range(30):
        q, scale, err = compress_int8(g, err)
        total = total + decompress_int8(q, scale)
    avg = total / 30
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g),
                               atol=2e-2, rtol=2e-2)


def test_synthetic_digits_learnable():
    imgs, labels = synthetic_digits(64, seed=0)
    assert imgs.shape == (64, 32, 32, 1)
    assert int(labels.min()) >= 0 and int(labels.max()) <= 9
