"""Distribution layer: sharding specs, dry-run lowering on a small fake
mesh, pipeline parallelism — run in subprocesses because the host device
count must be set before jax initializes."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_shardings_divisibility():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models import build_model
        from repro.sharding.specs import make_rules, params_shardings
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        cfg = get_arch("granite-moe-1b-a400m").reduced(
            n_layers=2, d_model=64, n_heads=4, d_ff=32, vocab=512)
        model = build_model(cfg)
        shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        sh = params_shardings(rules, shape)
        # every sharding must evenly divide its array
        for s, leaf in zip(jax.tree.leaves(sh), jax.tree.leaves(shape)):
            spec = s.spec
            for dim, ax in zip(leaf.shape, spec):
                if ax is None: continue
                axes = (ax,) if isinstance(ax, str) else ax
                size = 1
                for a in axes: size *= mesh.shape[a]
                assert dim % size == 0, (leaf.shape, spec)
        print("SPECS_OK")
    """)
    assert "SPECS_OK" in out


def test_tiny_dryrun_train_and_decode():
    """A miniature of launch/dryrun.py on a 2x4 mesh: lower + compile a
    train step and a decode step with full sharding plumbing."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.models import build_model
        from repro.optim.adamw import adamw_init, adamw_update
        from repro.sharding.specs import *
        import dataclasses
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        cfg = get_arch("qwen2.5-32b").reduced(
            n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=512)
        cfg = dataclasses.replace(cfg, remat=True)
        model = build_model(cfg)
        pshape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        oshape = jax.eval_shape(lambda: adamw_init(pshape))
        p_sh = params_shardings(rules, pshape)
        o_sh = opt_state_shardings(rules, oshape, pshape)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        b_sh = batch_shardings(rules, batch)
        def train_step(p, o, b):
            loss, g = jax.value_and_grad(
                lambda pp: model.loss(pp, b)[0])(p)
            p, o = adamw_update(g, o, p, 1e-3)
            return p, o, loss
        with mesh, use_activation_sharding(rules):
            c = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                        out_shardings=(p_sh, o_sh, None)
                        ).lower(pshape, oshape, batch).compile()
        assert c.memory_analysis() is not None
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older JAX returns [dict]
            ca = ca[0] if ca else {}
        print("TRAIN_LOWERED", int(ca.get("flops", 0)) > 0)
        # decode
        cshape = jax.eval_shape(lambda: model.init_cache(8, 64))
        c_sh = cache_shardings(rules, cshape, 8)
        b2 = {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32)}
        b2_sh = batch_shardings(rules, b2)
        def serve_step(p, c, b):
            return model.decode_step(p, c, b["tokens"])
        with mesh:
            c2 = jax.jit(serve_step, in_shardings=(p_sh, c_sh, b2_sh)
                         ).lower(pshape, cshape, b2).compile()
        print("DECODE_LOWERED")
    """)
    assert "TRAIN_LOWERED True" in out and "DECODE_LOWERED" in out


def test_collective_parser_finds_traffic():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.roofline import parse_collective_bytes
        mesh = jax.make_mesh((4,), ("model",))
        w_sh = NamedSharding(mesh, P(None, "model"))
        x_sh = NamedSharding(mesh, P(None))
        def f(x, w):
            return (x @ w).sum(-1)    # contract sharded dim -> all-reduce
        c = jax.jit(f, in_shardings=(x_sh, w_sh)).lower(
            jax.ShapeDtypeStruct((8, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        coll = parse_collective_bytes(c.as_text())
        print("WIRE", sum(coll.values()) > 0)
    """, devices=4)
    assert "WIRE True" in out


def test_pipeline_forward_equivalence():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.pipeline import pipeline_forward
        mesh = jax.make_mesh((4,), ("pod",))
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        n_stage, d = 4, 16
        ws = jax.random.normal(jax.random.key(0), (n_stage, d, d)) * 0.5
        x = jax.random.normal(jax.random.key(1), (8, d))
        run = pipeline_forward(stage_fn, mesh, axis="pod",
                               n_microbatches=2)
        got = run(ws, x)
        want = x
        for i in range(n_stage):
            want = stage_fn(ws[i], want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        print("PIPELINE_OK")
    """, devices=4)
    assert "PIPELINE_OK" in out


def test_moe_ep_shard_map():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_arch
        from repro.models.moe import init_moe, moe_ffn, _route
        from repro.models.moe import _expert_ffn_dense
        from repro.sharding.specs import (make_rules,
                                          use_activation_sharding)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh)
        cfg = get_arch("granite-moe-1b-a400m").reduced(
            n_layers=2, d_model=32, n_heads=4, d_ff=16, vocab=128)
        p = init_moe(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
        want = moe_ffn(p, x, cfg, impl="ragged")
        with mesh, use_activation_sharding(rules):
            got = jax.jit(lambda p, x: moe_ffn(p, x, cfg, impl="ep"))(p, x)
        # EP uses capacity-limited dispatch; allow small dropped-token gap
        diff = float(jnp.mean(jnp.abs(got - want)))
        scale = float(jnp.mean(jnp.abs(want))) + 1e-9
        print("EP_DIFF", diff / scale < 0.25, diff / scale)
    """, devices=8)
    assert "EP_DIFF True" in out, out
