"""Speculative decoding with the NEAT reduced-precision drafter: exact
greedy parity across all five model families and both KV layouts,
monotone acceptance-vs-bits degradation, rollback/page accounting,
width buckets, adaptive draft budgets, spec stats, and the serving
explorer mode."""
import dataclasses

import jax
import pytest

from repro.configs import get_arch
from repro.core import ServingTask, explore, pareto_points
from repro.models import build_model
from repro.serve import DecodeEngine, ServeConfig, SpecConfig
from repro.serve.engine import PageAllocator, ServeStats

# skewed: short and long prompts interleaved, more requests than slots,
# so speculation windows and mid-flight admits/retires all occur
PROMPTS = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7], [13, 14]]

FAMILIES = ["codeqwen1.5-7b",        # dense transformer
            "xlstm-1.3b",            # recurrent (ssm)
            "zamba2-7b",             # hybrid
            "seamless-m4t-medium",   # encoder-decoder
            "granite-moe-1b-a400m"]  # mixture-of-experts


def _tiny(arch):
    cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64, vocab=64)
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


def _cfg(**kw):
    base = dict(max_len=48, batch_slots=2, engine="continuous",
                prefill_chunk=4, page_size=8, debug_invariants=True)
    base.update(kw)
    return ServeConfig(**base)


def _run(model, params, cfg, max_new=6):
    eng = DecodeEngine(model, params, cfg)
    outs = eng.generate(PROMPTS, max_new_tokens=max_new)
    return outs, eng.stats


@pytest.mark.parametrize("arch", FAMILIES)
def test_drafter_bits_sweep_parity_and_monotone_acceptance(arch):
    """Satellite 3: at every drafter-bits level the spec engine's greedy
    completions are byte-identical to the non-speculative engine (the
    ambient-rule truncation changes *which* drafts get accepted, never
    the emitted tokens), acceptance degrades monotonically as drafter
    bits shrink, and the identity drafter (bits=24) is always
    accepted."""
    model, params = _tiny(arch)
    ref, _ = _run(model, params, _cfg())
    acc = {}
    for bits in (4, 10, 24):
        outs, st = _run(model, params,
                        _cfg(spec=SpecConfig(k=3, drafter_bits=bits)))
        assert outs == ref, f"{arch} bits={bits}: spec != non-spec"
        assert st.spec_windows > 0 and st.draft_tokens > 0
        acc[bits] = st.acceptance_rate
    assert acc[24] == pytest.approx(1.0), \
        "identity drafter must be fully accepted"
    assert acc[4] <= acc[10] <= acc[24], f"non-monotone acceptance {acc}"


def test_spec_parity_contiguous_layout():
    """The rectangle (page_size=0) path verifies through the chunked
    q_start/kv_len prefill — parity must hold there too."""
    model, params = _tiny("codeqwen1.5-7b")
    ref, _ = _run(model, params, _cfg(page_size=0))
    outs, st = _run(model, params,
                    _cfg(page_size=0, spec=SpecConfig(k=4)))
    assert outs == ref
    assert st.accepted_tokens > 0
    assert st.steps < 0.7 * _run(model, params, _cfg(page_size=0))[1].steps


def test_spec_parity_adaptive_k():
    """Adaptive per-slot draft budgets change window sizes, never
    emitted tokens."""
    model, params = _tiny("codeqwen1.5-7b")
    ref, _ = _run(model, params, _cfg())
    outs, st = _run(model, params,
                    _cfg(spec=SpecConfig(k=4, drafter_bits=4,
                                         adaptive=True)))
    assert outs == ref
    assert st.spec_windows > 0


def test_retire_on_eos_mid_window_keeps_page_accounting():
    """Satellite 2: a slot hitting EOS inside a speculation window must
    resolve the rollback before its pages are freed; the allocator
    invariant (free + resident == total) is asserted after every step
    via debug_invariants, and completions still match non-spec."""
    model, params = _tiny("codeqwen1.5-7b")
    ref, _ = _run(model, params, _cfg(), max_new=10)
    # pick a token the workload actually emits mid-completion as EOS so
    # retires genuinely happen inside speculation windows
    eos = next(tok for out in ref for tok in out[1:])
    ref_eos, _ = _run(model, params, _cfg(eos_token=eos), max_new=10)
    outs, st = _run(model, params,
                    _cfg(eos_token=eos, spec=SpecConfig(k=4)),
                    max_new=10)
    assert outs == ref_eos
    assert any(len(o) < 10 for o in outs), "EOS never fired — test inert"
    assert st.spec_windows > 0


def test_allocator_rollback_and_invariant():
    alloc = PageAllocator(8)
    pages = alloc.alloc(4)
    assert alloc.free_pages == 4
    # rollback keeps ownership: committed prefix must fit the reservation
    assert alloc.rollback(pages, committed_tokens=0, page_size=4) == 0
    assert alloc.rollback(pages, committed_tokens=13, page_size=4) == 4
    with pytest.raises(AssertionError):
        alloc.rollback(pages, committed_tokens=17, page_size=4)
    alloc.assert_invariant(resident=4)
    with pytest.raises(AssertionError):
        alloc.assert_invariant(resident=3)   # a page leaked
    alloc.free(pages)
    alloc.assert_invariant(resident=0)
    with pytest.raises(AssertionError):
        alloc.assert_invariant(resident=4)   # double-free symmetry


def test_packed_width_buckets_are_powers_of_two():
    """Satellite 1: every packed step ships a power-of-two width <=
    pack_tokens, and a mostly-decode mixed step uses a smaller bucket
    than the full rectangle budget."""
    model, params = _tiny("codeqwen1.5-7b")
    cfg = _cfg(batch_slots=4, pack_tokens=64, prefill_chunk=16)
    _, st = _run(model, params, cfg, max_new=8)
    assert st.packed_widths, "no packed steps recorded"
    for w in st.packed_widths:
        assert w <= 64 and (w & (w - 1)) == 0, f"width {w} not a bucket"
    assert min(st.packed_widths) < 64, \
        "mostly-decode steps never dropped below the full budget"


def test_spec_stats_accounting():
    model, params = _tiny("codeqwen1.5-7b")
    _, st = _run(model, params, _cfg(spec=SpecConfig(k=3)))
    assert st.verify_steps > 0 and st.draft_steps > 0
    assert st.draft_tokens >= st.accepted_tokens
    assert sum(st.accepted_hist.values()) == st.spec_windows
    assert sum(a * n for a, n in st.accepted_hist.items()) \
        == st.accepted_tokens
    assert 0.0 <= st.acceptance_rate <= 1.0
    assert st.p50_ttft_s <= st.p99_ttft_s


def test_spec_config_validation():
    model, params = _tiny("codeqwen1.5-7b")
    with pytest.raises(ValueError):
        DecodeEngine(model, params,
                     ServeConfig(engine="wave", spec=SpecConfig()))
    with pytest.raises(ValueError):
        DecodeEngine(model, params,
                     ServeConfig(engine="continuous", temperature=0.7,
                                 spec=SpecConfig()))
    with pytest.raises(ValueError):
        DecodeEngine(model, params,
                     ServeConfig(engine="continuous",
                                 spec=SpecConfig(k=0)))


def test_serve_stats_ttft_percentiles():
    st = ServeStats()
    assert st.p99_ttft_s == 0.0
    st.ttft_s = {i: float(i) for i in range(1, 101)}   # 1..100
    assert st.ttft_percentile(0.0) == 1.0
    # nearest rank: the ceil(0.5 * 100) = 50th smallest of 1..100 (the
    # historical round(q*(n-1)) form banker's-rounded to index 50, 51.0)
    assert st.p50_ttft_s == pytest.approx(50.0)
    assert st.p99_ttft_s == pytest.approx(99.0)
    assert st.ttft_percentile(1.0) == 100.0


def test_explore_serving_acceptance_energy_front():
    """The serving objective mode: drafter bits as the genome, an
    acceptance-vs-energy front with >= 3 distinct non-dominated genomes,
    energy monotone in bits (the static charge is affine in mantissa
    width), and the identity drafter at zero error."""
    model, params = _tiny("codeqwen1.5-7b")
    rep = explore(
        ServingTask(model, params, PROMPTS,
                    serve_cfg=dataclasses.replace(_cfg(),
                                                  debug_invariants=False),
                    max_new_tokens=6, k=3, bits_grid=(2, 3, 4, 8, 24)),
        objectives="serving")
    assert rep.n_evals == 5
    by_bits = sorted(rep.points, key=lambda p: p.payload["bits"])
    energies = [p.energy for p in by_bits]
    assert energies == sorted(energies) and len(set(energies)) == 5
    ident = by_bits[-1]
    assert ident.payload["bits"] == 24
    assert ident.error == pytest.approx(0.0)
    front = pareto_points(rep.points)
    assert len({p.payload["genome"] for p in front}) >= 3, \
        f"degenerate front: {[(p.payload['bits'], p.error) for p in front]}"
