"""PrecisionPolicy as the one serving precision surface: genome → policy
→ JSON → engine round-trips losslessly, the identity policy is
byte-identical to non-policy serving across all five families × both KV
layouts, all three deprecated precision entry points (engine ``rule=``,
``SpecConfig.drafter_bits``, ``explore_serving``) are parity-exact
through the new API, the KVConfig shim + ServeConfig validation raise
actionable errors, and SLA tiers route/downgrade with per-tier stats."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (ServingTask, explore, explore_serving, pareto_points,
                        use_rule)
from repro.core.fpi import MantissaTrunc
from repro.core.placement import WholeProgram
from repro.core.policy import (PhaseSpec, PolicyRule, PrecisionPolicy,
                               policy_params)
from repro.core.scope import current_phase, phase_scope
from repro.models import build_model
from repro.serve import DecodeEngine, KVConfig, ServeConfig, SpecConfig

PROMPTS = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7], [13, 14]]

FAMILIES = ["codeqwen1.5-7b",        # dense transformer
            "xlstm-1.3b",            # recurrent (ssm)
            "zamba2-7b",             # hybrid
            "seamless-m4t-medium",   # encoder-decoder
            "granite-moe-1b-a400m"]  # mixture-of-experts


@functools.lru_cache(maxsize=None)
def _tiny(arch):
    cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64, vocab=64)
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


def _cfg(**kw):
    base = dict(max_len=48, batch_slots=2, engine="continuous",
                prefill_chunk=4, debug_invariants=True)
    base.update(kw)
    return ServeConfig(**base)


# ---------------------------------------------------------------------------
# phase scopes
# ---------------------------------------------------------------------------

def test_phase_scope_default_semantics():
    """Engine wrappers (explicit) win over model self-tags (default)."""
    assert current_phase() is None
    with phase_scope("draft"):
        assert current_phase() == "draft"
        with phase_scope("decode", default=True):   # model self-tag
            assert current_phase() == "draft"       # engine wins
        with phase_scope("verify"):                 # explicit nests
            assert current_phase() == "verify"
        assert current_phase() == "draft"
    assert current_phase() is None
    with phase_scope("decode", default=True):       # no engine around
        assert current_phase() == "decode"


def test_policy_rule_dispatches_on_phase():
    pol = PrecisionPolicy.drafter(7)
    rule = pol.as_rule()
    assert isinstance(rule, PolicyRule)
    x = jnp.float32(1.0 + 2.0 ** -20)               # needs > 7 bits
    with use_rule(rule):
        from repro.core.quantize import quantize_here
        with phase_scope("draft"):
            assert float(quantize_here(x)) != float(x)
        with phase_scope("decode"):
            assert float(quantize_here(x)) == float(x)
        assert float(quantize_here(x)) == float(x)  # unphased -> decode


# ---------------------------------------------------------------------------
# satellite 4: round-trip + identity byte-parity
# ---------------------------------------------------------------------------

def test_policy_json_roundtrip_lossless():
    """genome → PrecisionPolicy → JSON → PrecisionPolicy is lossless,
    and the round-tripped policy serves byte-identically."""
    pol = PrecisionPolicy(phases={
        "draft": PhaseSpec(family="plc", sites=("sdpa", "mlp"),
                           bits=(6, 9), default_bits=12, mode="trunc",
                           weights=True),
        "prefill": PhaseSpec(family="wp", sites=("__program__",),
                             bits=(14,)),
    }, name="hetero")
    back = PrecisionPolicy.from_json(pol.to_json())
    assert back == pol
    assert back.to_dict() == pol.to_dict()
    assert back.signature() == pol.signature()

    model, params = _tiny("codeqwen1.5-7b")
    a = DecodeEngine(model, params, _cfg(), policy=pol)
    b = DecodeEngine(model, params, _cfg(), policy=back)
    oa = a.generate(PROMPTS, max_new_tokens=4)
    assert oa == b.generate(PROMPTS, max_new_tokens=4)


def test_from_genome_serving_report_roundtrip():
    """A serving-exploration point lifts into a policy whose dict equals
    the payload artifact — the explorer → engine loop is closed."""
    model, params = _tiny("codeqwen1.5-7b")
    task = ServingTask(model=model, params=params, prompts=PROMPTS[:4],
                       serve_cfg=_cfg(), max_new_tokens=4, k=3,
                       n_sites=2, pop_size=4, n_gen=1, max_evals=6)
    rep = explore(task, objectives="serving")
    assert rep.n_evals <= 6 and rep.points
    assert all(s.startswith("draft:") for s in rep.sites)
    pol = PrecisionPolicy.from_genome(rep)
    front = pareto_points(rep.points) or rep.points
    pick = min(front, key=lambda p: p.energy)
    assert pol.to_dict() == pick.payload["policy"]
    # the artifact actually serves
    eng = DecodeEngine(model, params,
                       _cfg(spec=SpecConfig(k=3)), policy=pol)
    outs = eng.generate(PROMPTS[:4], max_new_tokens=4)
    assert all(len(o) == 4 for o in outs)


@pytest.mark.parametrize("arch", FAMILIES)
@pytest.mark.parametrize("page_size", [0, 8])
def test_identity_policy_byte_identical(arch, page_size):
    """Satellite 4: the identity policy (24 bits everywhere) serves
    byte-identically to non-policy serving, both KV layouts."""
    model, params = _tiny(arch)
    ref = DecodeEngine(model, params, _cfg(page_size=page_size))
    idp = DecodeEngine(model, params, _cfg(page_size=page_size),
                       policy=PrecisionPolicy.uniform(24))
    r = ref.generate(PROMPTS, max_new_tokens=4)
    assert idp.generate(PROMPTS, max_new_tokens=4) == r


# ---------------------------------------------------------------------------
# satellite 1: the three deprecated entry points, parity-exact
# ---------------------------------------------------------------------------

def test_engine_rule_kwarg_parity():
    """Deprecated ``DecodeEngine(rule=WholeProgram(...))`` ==
    ``policy=PrecisionPolicy.uniform(bits)`` (the launch/serve.py
    --rule path), byte for byte."""
    model, params = _tiny("codeqwen1.5-7b")
    legacy = DecodeEngine(model, params, _cfg(page_size=8),
                          rule=WholeProgram(fpi=MantissaTrunc(bits=9)))
    new = DecodeEngine(model, params, _cfg(page_size=8),
                       policy=PrecisionPolicy.uniform(9))
    assert (legacy.generate(PROMPTS, max_new_tokens=4)
            == new.generate(PROMPTS, max_new_tokens=4))


def test_trainer_rule_parity():
    """The launch/train.py fold: an ambient uniform PolicyRule produces
    byte-identical quantized forwards to the raw WholeProgram rule."""
    model, params = _tiny("xlstm-1.3b")
    toks = jnp.asarray([[3, 1, 4, 1, 5, 9]], jnp.int32)
    legacy = WholeProgram(fpi=MantissaTrunc(bits=8), target="single")
    folded = PrecisionPolicy.uniform(8).as_rule()
    with use_rule(legacy):
        a = jax.jit(model.forward)(params, toks)
    with use_rule(folded):
        b = jax.jit(model.forward)(params, toks)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spec_drafter_bits_parity():
    """Deprecated ``SpecConfig.drafter_bits`` == explicit
    ``PrecisionPolicy.drafter(bits)``: same outputs, same acceptance."""
    model, params = _tiny("codeqwen1.5-7b")
    legacy = DecodeEngine(model, params,
                          _cfg(spec=SpecConfig(k=3, drafter_bits=6)))
    new = DecodeEngine(model, params, _cfg(spec=SpecConfig(k=3)),
                       policy=PrecisionPolicy.drafter(6))
    ol = legacy.generate(PROMPTS, max_new_tokens=5)
    on = new.generate(PROMPTS, max_new_tokens=5)
    assert ol == on
    assert legacy.stats.acceptance_rate == new.stats.acceptance_rate
    assert legacy.stats.accepted_hist == new.stats.accepted_hist


def test_explore_serving_deprecated_alias_parity():
    """Satellite 3: ``explore_serving`` warns and returns the identical
    report ``explore(ServingTask(..., bits_grid=...))`` produces."""
    model, params = _tiny("codeqwen1.5-7b")
    kw = dict(bits_grid=(6, 24), k=3, serve_cfg=_cfg(), max_new_tokens=4)
    with pytest.warns(DeprecationWarning, match="explore_serving"):
        old = explore_serving(model, params, PROMPTS[:4], **kw)
    task = ServingTask(model=model, params=params, prompts=PROMPTS[:4],
                       serve_cfg=_cfg(), max_new_tokens=4, k=3,
                       bits_grid=(6, 24))
    new = explore(task, objectives="serving")
    assert [(p.error, p.energy, p.payload["bits"]) for p in old.points] \
        == [(p.error, p.energy, p.payload["bits"]) for p in new.points]
    assert (old.task, old.family, old.sites, old.n_evals) \
        == (new.task, new.family, new.sites, new.n_evals)


def test_explore_rejects_mismatched_objectives():
    model, params = _tiny("codeqwen1.5-7b")
    with pytest.raises(TypeError, match="ServingTask"):
        explore("not-a-task", objectives="serving")
    task = ServingTask(model=model, params=params, prompts=PROMPTS[:2])
    with pytest.raises(ValueError, match="objectives"):
        explore(task, objectives="nonsense")


# ---------------------------------------------------------------------------
# satellite 2: KVConfig shim + validation
# ---------------------------------------------------------------------------

def test_kvconfig_shim_and_flat_kwargs_agree():
    flat = ServeConfig(max_len=64, batch_slots=4, page_size=8,
                       kv_pages=16, pack_tokens=8)
    nested = ServeConfig(max_len=64, batch_slots=4,
                         kv=KVConfig(page_size=8, pages=16, pack_tokens=8))
    assert flat.kv == nested.kv
    assert (flat.page_size, flat.kv_pages, flat.pack_tokens) \
        == (nested.page_size, nested.kv_pages, nested.pack_tokens) \
        == (8, 16, 8)
    # redundant but agreeing flat kwargs are fine (dataclasses.replace)
    again = dataclasses.replace(nested, max_len=128)
    assert again.kv.page_size == 8


def test_serveconfig_actionable_errors():
    with pytest.raises(ValueError, match="conflicting"):
        ServeConfig(page_size=8, kv=KVConfig(page_size=16), max_len=64)
    with pytest.raises(ValueError, match="must divide max_len"):
        ServeConfig(max_len=50, page_size=8)
    with pytest.raises(ValueError, match="pack_tokens"):
        ServeConfig(max_len=64, batch_slots=8, page_size=8, pack_tokens=4)
    with pytest.raises(ValueError, match="continuous"):
        ServeConfig(engine="wave", page_size=8, max_len=64)
    with pytest.raises(ValueError, match="greedy-only"):
        ServeConfig(temperature=0.5, spec=SpecConfig())
    with pytest.raises(ValueError, match="continuous"):
        ServeConfig(engine="wave", spec=SpecConfig())
    with pytest.raises(ValueError, match="spec.k"):
        ServeConfig(spec=SpecConfig(k=0))
    with pytest.raises(ValueError, match="tier_slots"):
        ServeConfig(batch_slots=2,
                    tiers={"a": PrecisionPolicy.uniform(24)},
                    tier_slots={"b": 1})
    with pytest.raises(ValueError, match="tier_floor"):
        ServeConfig(batch_slots=2,
                    tiers={"a": PrecisionPolicy.uniform(24)},
                    tier_floor="z")
    with pytest.raises(ValueError, match="batch_slots"):
        ServeConfig(batch_slots=1,
                    tiers={"a": PrecisionPolicy.uniform(24),
                           "b": PrecisionPolicy.uniform(8)})


def test_rule_and_policy_mutually_exclusive():
    model, params = _tiny("codeqwen1.5-7b")
    with pytest.raises(ValueError, match="not both"):
        DecodeEngine(model, params, _cfg(),
                     rule=WholeProgram(fpi=MantissaTrunc(bits=8)),
                     policy=PrecisionPolicy.uniform(8))


# ---------------------------------------------------------------------------
# tentpole: SLA tiers + energy accounting
# ---------------------------------------------------------------------------

def _tier_cfg(**kw):
    base = dict(max_len=48, batch_slots=4, prefill_chunk=4,
                estimate_energy=True,
                tiers={"gold": PrecisionPolicy.uniform(24),
                       "bronze": PrecisionPolicy.uniform(6)})
    base.update(kw)
    return ServeConfig(**base)


def test_tiered_serving_routes_and_reports():
    """Requests route to their asked tier, exact-tier output is
    byte-identical to non-policy serving, and per-tier stats cover
    tokens/sec, acceptance, TTFT percentiles and estimated pJ."""
    model, params = _tiny("codeqwen1.5-7b")
    eng = DecodeEngine(model, params, _tier_cfg())
    asked = ["gold", "bronze", "gold", "bronze", "gold", "bronze"]
    outs = eng.generate(PROMPTS, max_new_tokens=4, tiers=asked)
    st = eng.stats
    assert set(st.per_tier) == {"gold", "bronze"}
    assert st.downgraded == 0
    assert st.tier_of == dict(enumerate(asked))
    assert st.tokens_out == sum(len(o) for o in outs)
    assert st.est_pj > 0 and st.per_tier["bronze"].est_pj > 0
    for ts in st.per_tier.values():
        assert ts.wall_s > 0 and ts.p99_ttft_s >= ts.p50_ttft_s >= 0
    # the exact tier == non-policy serving on the same sub-workload
    gold_ids = [0, 2, 4]
    ref = DecodeEngine(model, params, _cfg())
    r = ref.generate([PROMPTS[i] for i in gold_ids], max_new_tokens=4)
    assert [outs[i] for i in gold_ids] == r
    # cheaper tier bills fewer pJ per row than the exact tier
    gold, bronze = st.per_tier["gold"], st.per_tier["bronze"]
    assert bronze.est_pj / max(sum(bronze.phase_rows.values()), 1) \
        < gold.est_pj / max(sum(gold.phase_rows.values()), 1)


def test_tiered_admission_downgrades_to_floor_only():
    """Backlog pressure walks requests down, never below the floor."""
    model, params = _tiny("codeqwen1.5-7b")
    cfg = _tier_cfg(batch_slots=6,
                    tiers={"gold": PrecisionPolicy.uniform(24),
                           "silver": PrecisionPolicy.uniform(12),
                           "bronze": PrecisionPolicy.uniform(6)},
                    tier_slots={"gold": 2, "silver": 2, "bronze": 2},
                    tier_backlog=1, tier_floor="silver",
                    estimate_energy=False)
    eng = DecodeEngine(model, params, cfg)
    eng.generate(PROMPTS, max_new_tokens=3, tiers="gold")
    st = eng.stats
    # 6 gold asks against backlog threshold 1x2 slots: overflow walks
    # down to silver and STOPS there (floor), bronze gets nothing
    assert st.downgraded == 4
    assert sorted(st.tier_of.values()) \
        == ["gold", "gold", "silver", "silver", "silver", "silver"]
    assert st.per_tier["bronze"].n_requests == 0


def test_tiers_share_compiled_programs():
    """Tiers with equal policy signatures share one compiled program
    set (the compilation cache is keyed on policy.signature())."""
    model, params = _tiny("codeqwen1.5-7b")
    cfg = _tier_cfg(tiers={"a": PrecisionPolicy.uniform(24),
                           "b": PrecisionPolicy.uniform(24)},
                    estimate_energy=False)
    eng = DecodeEngine(model, params, cfg)
    assert eng._sub["a"]._step is eng._sub["b"]._step
    cfg2 = _tier_cfg(estimate_energy=False)
    eng2 = DecodeEngine(model, params, cfg2)
    assert eng2._sub["gold"]._step is not eng2._sub["bronze"]._step


def test_energy_estimate_monotone_in_bits():
    """A cheaper uniform policy estimates fewer pJ/token than identity
    on the identical workload (same steps — greedy outputs are only
    equal for the identity policy, so compare the ambient-only spec
    path where outputs are pinned by exact verification)."""
    model, params = _tiny("codeqwen1.5-7b")

    def run(policy):
        eng = DecodeEngine(model, params,
                           _cfg(spec=SpecConfig(k=3),
                                estimate_energy=True), policy=policy)
        outs = eng.generate(PROMPTS, max_new_tokens=4)
        return outs, eng.stats

    o24, s24 = run(PrecisionPolicy.drafter(24))
    o6, s6 = run(PrecisionPolicy.drafter(6))
    assert o24 == o6                       # exact verification pins output
    pj24 = s24.est_pj / max(sum(s24.phase_rows.values()), 1)
    pj6 = s6.est_pj / max(sum(s6.phase_rows.values()), 1)
    assert pj6 < pj24


def test_measured_census_rides_energy_estimate():
    """``estimate_energy=True`` additionally measures the token
    stream's fused §III-C bit census: per-phase counts and measured pJ
    land on the stats (overall and per tier), a cheaper tier measures
    strictly fewer active bits, and collecting the census never changes
    the served completions."""
    model, params = _tiny("codeqwen1.5-7b")
    asked = ["gold", "bronze"] * 3
    eng = DecodeEngine(model, params, _tier_cfg())
    outs = eng.generate(PROMPTS, max_new_tokens=4, tiers=asked)
    st = eng.stats
    assert st.measured_pj > 0 and st.phase_census
    gold, bronze = st.per_tier["gold"], st.per_tier["bronze"]
    assert 0 < bronze.measured_pj_per_token < gold.measured_pj_per_token
    assert sum(st.phase_census.values()) \
        == sum(gold.phase_census.values()) \
        + sum(bronze.phase_census.values())
    off = DecodeEngine(model, params, _tier_cfg(estimate_energy=False))
    assert off.generate(PROMPTS, max_new_tokens=4, tiers=asked) == outs
    assert off.stats.measured_pj == 0.0 and not off.stats.phase_census


def test_serving_nsga_recurrent_census_fallback():
    """A pure-recurrent decode path has no censused kernels, so its
    measured census totals zero; the serving energy axis must fall back
    to the abstract width-affine estimate rather than collapsing every
    genome to 0 pJ/token."""
    model, params = _tiny("xlstm-1.3b")
    rep = explore(ServingTask(model=model, params=params,
                              prompts=PROMPTS[:2], serve_cfg=_cfg(),
                              max_new_tokens=3, k=2, n_sites=2,
                              pop_size=4, n_gen=1, max_evals=4),
                  objectives="serving")
    assert rep.points
    for p in rep.points:
        assert p.payload["measured_pj_per_token"] == 0.0
        assert p.energy == p.payload["est_pj_per_token"] > 0.0


def test_policy_params_per_layer_views():
    """policy_params truncates only the layers a plc spec names, leaving
    other layers' weights bit-exact."""
    model, params = _tiny("codeqwen1.5-7b")
    spec = PhaseSpec(family="pli", sites=("model/layer00",), bits=(4,),
                     default_bits=24, weights=True)
    views = policy_params(params, spec)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_v = jax.tree.leaves(views)
    changed = unchanged = 0
    for (path, p), v in zip(flat_p, flat_v):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            continue
        if np.array_equal(np.asarray(p), np.asarray(v)):
            unchanged += 1
        else:
            changed += 1
            assert "layers" in jax.tree_util.keystr(path)
    assert changed > 0 and unchanged > 0
