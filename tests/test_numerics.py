import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.utils.numerics import (bits_for_storage, float_spec,
                                  manipulated_bits, truncate_mantissa,
                                  truncate_mantissa_dynamic)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("bits", [1, 2, 5, 8])
def test_idempotent(dtype, bits):
    x = jnp.asarray(np.random.default_rng(0).standard_normal(256), dtype)
    once = truncate_mantissa(x, bits)
    twice = truncate_mantissa(once, bits)
    assert np.array_equal(np.asarray(once, np.float64),
                          np.asarray(twice, np.float64))


def test_identity_at_full_width():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(64), jnp.float32)
    assert np.array_equal(np.asarray(truncate_mantissa(x, 24)),
                          np.asarray(x))
    # clamping: wider than native is identity too
    assert np.array_equal(np.asarray(truncate_mantissa(x, 53)),
                          np.asarray(x))


def test_special_values_preserved():
    x = jnp.array([np.nan, np.inf, -np.inf, 0.0, -0.0], jnp.float32)
    for bits in (1, 4, 12):
        y = np.asarray(truncate_mantissa(x, bits))
        assert np.isnan(y[0]) and np.isinf(y[1]) and np.isinf(y[2])
        assert y[3] == 0.0 and y[4] == 0.0


def test_dynamic_matches_static():
    x = jnp.asarray(np.random.default_rng(2).standard_normal(512),
                    jnp.float32)
    for bits in range(1, 25):
        a = truncate_mantissa(x, bits, "rne")
        b = truncate_mantissa_dynamic(x, jnp.int32(bits), "rne")
        assert np.array_equal(np.asarray(a).view(np.uint32),
                              np.asarray(b).view(np.uint32)), bits


@settings(max_examples=60, deadline=None)
@given(st.floats(min_value=-1e20, max_value=1e20,
                 allow_nan=False, allow_infinity=False),
       st.integers(min_value=1, max_value=24))
def test_error_bounded_by_ulp(v, bits):
    """|trunc(x) - x| <= 2^(1-bits) * |x| for RNE at `bits` mantissa."""
    x = jnp.float32(v)
    y = float(truncate_mantissa(x, bits))
    if v == 0.0:
        assert y == 0.0
        return
    rel = abs(y - float(x)) / max(abs(float(x)), 1e-38)
    assert rel <= 2.0 ** (-bits) * 1.0001


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=23))
def test_error_monotone_in_bits(bits):
    """Fewer bits can only increase (or keep) the error."""
    x = jnp.asarray(np.linspace(0.1, 10.0, 257), jnp.float32)
    e_low = float(jnp.sum(jnp.abs(truncate_mantissa(x, bits) - x)))
    e_high = float(jnp.sum(jnp.abs(truncate_mantissa(x, bits + 1) - x)))
    assert e_high <= e_low * 1.0001


def test_manipulated_bits():
    x = jnp.array([1.0, 1.5, 1.25, np.pi], jnp.float32)
    got = list(np.asarray(manipulated_bits(x)))
    assert got[0] == 1 and got[1] == 2 and got[2] == 3 and got[3] == 24


def test_manipulated_bits_after_truncation_bounded():
    x = jnp.asarray(np.random.default_rng(3).standard_normal(1024),
                    jnp.float32)
    for bits in (3, 7, 13):
        t = truncate_mantissa(x, bits)
        assert int(jnp.max(manipulated_bits(t))) <= bits


def test_bits_for_storage():
    assert bits_for_storage(24, jnp.float32) == 32
    assert bits_for_storage(1, jnp.float32) == 9
    assert bits_for_storage(8, jnp.bfloat16) == 16
