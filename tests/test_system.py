"""End-to-end behaviour tests for the NEAT system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import get_app, make_task
from repro.core import (CallStack, CurrentScope, IDENTITY, MantissaTrunc,
                        WholeProgram, explore, neat_transform, profile,
                        static_energy)


def test_whole_system_blackscholes_cip_beats_wp():
    """Paper §V-C: per-function placement finds configs at least as good
    as whole-program at matched error (CIP's space contains WP)."""
    task = make_task(get_app("blackscholes"), n_train=2, n_test=1)
    rep_wp = explore(task, family="wp", n_sites=1, pop_size=10, n_gen=4,
                     max_evals=30, seed=0, robustness=False)
    rep_cip = explore(task, family="cip", n_sites=4, pop_size=14, n_gen=5,
                      max_evals=80, seed=0, robustness=False)
    for thr in (0.05, 0.10):
        assert rep_cip.savings(thr) >= rep_wp.savings(thr) - 0.02, thr


def test_radar_fcs_distinguishes_callers():
    """Paper §V-F: FCS can assign different FPIs to the two FFT call
    sites; CIP cannot."""
    app = get_app("radar")
    inp = make_task(app, n_train=1, n_test=0).train_inputs[0]
    exact = np.asarray(app.fn(*inp))
    # FCS: aggressive truncation in the LPF path, exact in PC
    rule_fcs = CallStack(mapping={"lpf": MantissaTrunc(6),
                                  "pc": MantissaTrunc(24)})
    rule_cip_like = CurrentScope(mapping={"fft": MantissaTrunc(6)})
    out_fcs = np.asarray(neat_transform(app.fn, rule_fcs)(*inp))
    out_cip = np.asarray(neat_transform(app.fn, rule_cip_like)(*inp))
    err_fcs = np.linalg.norm(out_fcs - exact) / np.linalg.norm(exact)
    err_cip = np.linalg.norm(out_cip - exact) / np.linalg.norm(exact)
    # FCS truncates only the LPF call; CIP hits both -> FCS strictly closer
    assert 0 < err_fcs < err_cip


def test_profile_top10_coverage():
    """Paper §V-C: the top-10 functions cover ~all FLOPs."""
    for name in ("blackscholes", "kmeans", "radar", "fluidanimate"):
        task = make_task(get_app(name), n_train=1, n_test=0)
        prof = profile(get_app(name).fn, *task.train_inputs[0])
        cov = prof.coverage(prof.top_functions(10))
        assert cov >= 0.85, (name, cov)


def test_energy_monotone_in_bits():
    task = make_task(get_app("kmeans"), n_train=1, n_test=0)
    prof = profile(get_app("kmeans").fn, *task.train_inputs[0])
    energies = [static_energy(prof, WholeProgram(fpi=MantissaTrunc(b))).fpu_pj
                for b in (4, 8, 16, 24)]
    assert all(a < b for a, b in zip(energies, energies[1:]))
