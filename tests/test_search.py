"""NSGA-II, Pareto analysis, explorer (+ hypothesis property tests)."""
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.nsga2 import (Evaluated, crowding_distance, dominates,
                              fast_non_dominated_sort, nsga2, pareto_front)
from repro.core.pareto import (TradeoffPoint, correlation,
                               energy_at_threshold, harmonic_mean,
                               lower_convex_hull, pareto_points,
                               savings_at_threshold)


def test_dominates():
    assert dominates((1, 1), (2, 2))
    assert dominates((1, 2), (1, 3))
    assert not dominates((1, 2), (2, 1))
    assert not dominates((1, 1), (1, 1))


def test_fast_non_dominated_sort():
    objs = np.array([[1, 1], [2, 2], [1, 2], [2, 1], [3, 3]])
    fronts = fast_non_dominated_sort(objs)
    assert set(fronts[0].tolist()) == {0}
    assert set(fronts[1].tolist()) == {2, 3}
    assert set(fronts[2].tolist()) == {1}


def test_crowding_boundaries_infinite():
    objs = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    cd = crowding_distance(objs)
    assert np.isinf(cd[0]) and np.isinf(cd[3])


def test_nsga2_converges_on_known_front():
    # objectives: (sum(bits)/max, sum((24-bits)^2)) — front = tradeoff
    def ev(g):
        b = np.asarray(g)
        return (b.sum() / (24 * len(b)), float(((24 - b) ** 2).sum()) / 500)

    res = nsga2(ev, n_genes=4, low=1, high=24, pop_size=16, n_gen=8,
                max_evals=200, seed=1)
    assert res.n_evals <= 200
    front = res.front()
    # front must be mutually non-dominated
    for p in front:
        assert not any(dominates(q.objectives, p.objectives)
                       for q in front if q is not p)
    # extremes discovered
    assert any(e.genome == (24, 24, 24, 24) for e in res.evaluated)


def test_budget_respected():
    calls = []

    def ev(g):
        calls.append(g)
        return (sum(g), -sum(g))

    nsga2(ev, n_genes=3, low=1, high=24, pop_size=10, n_gen=50,
          max_evals=37, seed=0)
    assert len(calls) <= 37


def test_pareto_and_hull():
    pts = [TradeoffPoint(e, en) for e, en in
           [(0.0, 1.0), (0.01, 0.8), (0.02, 0.9), (0.05, 0.5),
            (0.05, 0.45), (0.2, 0.44)]]
    front = pareto_points(pts)
    assert [(p.error, p.energy) for p in front] == \
        [(0.0, 1.0), (0.01, 0.8), (0.05, 0.45), (0.2, 0.44)]
    hull = lower_convex_hull(pts)
    assert len(hull) <= len(front)
    assert energy_at_threshold(pts, 0.03) == 0.8
    assert savings_at_threshold(pts, 0.05) == pytest.approx(0.55)
    assert savings_at_threshold(pts, -1.0) == 0.0   # nothing qualifies


def test_harmonic_mean_and_correlation():
    assert harmonic_mean([1.0, 1.0]) == pytest.approx(1.0)
    assert correlation([1, 2, 3], [2, 4, 6]) == pytest.approx(1.0)
    assert correlation([1, 2, 3], [3, 2, 1]) == pytest.approx(-1.0)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.floats(0.1, 1)),
                min_size=1, max_size=40))
def test_hull_below_all_points(pts_raw):
    pts = [TradeoffPoint(e, en) for e, en in pts_raw]
    hull = lower_convex_hull(pts)
    # hull points are a subset and non-dominated
    for h in hull:
        assert not any((p.error <= h.error and p.energy < h.energy)
                       for p in pts)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_nsga2_deterministic_given_seed(seed):
    def ev(g):
        return (sum(g), -min(g))
    a = nsga2(ev, 3, 1, 8, pop_size=6, n_gen=2, max_evals=30, seed=seed)
    b = nsga2(ev, 3, 1, 8, pop_size=6, n_gen=2, max_evals=30, seed=seed)
    assert [e.genome for e in a.evaluated] == [e.genome for e in b.evaluated]
