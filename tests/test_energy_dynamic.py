"""Device-resident dynamic energy: Pallas bit-census kernel vs jnp oracle
(bit-exact), batched dynamic estimator vs the host-side
``dynamic_fpu_energy`` reference, and static-vs-dynamic front sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.apps import get_app, make_task
from repro.core import explore
from repro.core.estimators import (DynamicEnergyEstimator,
                                   StaticEnergyEstimator, fold_bit_counts,
                                   host_device_parity, make_estimator,
                                   register_estimator)
from repro.core.explorer import ExplorationTask, PopulationEvaluator, \
    sites_for_family
from repro.core.profiler import profile
from repro.core.scope import pscope
from repro.kernels.ops import bit_census
from repro.kernels.ref import bit_census_ref


# ---------------------------------------------------------------------------
# Pallas kernel vs jnp oracle: bit-exact across dtypes and shapes
# ---------------------------------------------------------------------------

SHAPES = [(1,), (7,), (33, 5), (257, 130), (3, 128, 2), (1024, 600)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("shape", SHAPES)
def test_bit_census_kernel_matches_oracle(dtype, shape):
    rng = np.random.default_rng(hash((str(dtype), shape)) % 2**32)
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    # salt with the census's edge classes: zero fraction, specials, exacts
    flat = x.reshape(-1)
    salt = jnp.asarray([0.0, 1.0, 0.25, -2.0, jnp.inf, -jnp.inf, jnp.nan],
                       dtype)[: flat.shape[0]]
    x = flat.at[: salt.shape[0]].set(salt).reshape(shape)
    assert int(bit_census(x, backend="interpret")) == int(bit_census_ref(x))


def test_bit_census_kernel_matches_oracle_f64():
    with enable_x64():
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((65, 9)), jnp.float64)
        assert int(bit_census(x, backend="interpret")) \
            == int(bit_census_ref(x))


def test_bit_census_edges():
    # zero fraction counts the implicit bit only; empty tensors count 0
    assert int(bit_census_ref(jnp.zeros((4, 4), jnp.float32))) == 16
    assert int(bit_census(jnp.zeros((4, 4), jnp.float32),
                          backend="interpret")) == 16
    assert int(bit_census(jnp.zeros((0,), jnp.float32),
                          backend="interpret")) == 0
    # full-precision odd fraction counts every mantissa bit
    x = jnp.asarray([np.float32(1.0) + np.float32(2.0 ** -23)])
    assert int(bit_census(x, backend="interpret")) == 24
    # auto backend (jnp ref on CPU) agrees with forced emulation
    y = jnp.asarray(np.linspace(-3, 3, 77), jnp.float32)
    assert int(bit_census(y)) == int(bit_census(y, backend="interpret"))


# ---------------------------------------------------------------------------
# batched dynamic estimator vs host dynamic_fpu_energy, per genome
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def bs_setup():
    task = make_task(get_app("blackscholes"), n_train=3, n_test=2)
    prof = profile(task.fn, *task.train_inputs[0])
    sites = sites_for_family(prof, "cip", 4)
    exact = [jax.tree.map(np.asarray, task.fn(*inp))
             for inp in task.train_inputs]
    return task, prof, sites, exact


def test_dynamic_estimator_matches_host_reference(bs_setup):
    """Per-(genome, input) device census folded to pJ == the eager
    host-side capture fed to dynamic_fpu_energy, to well under 1e-6
    (both are f64 reductions of identical exact integer counts)."""
    task, prof, sites, exact = bs_setup
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=5,
                             collect_bits=True)
    rng = np.random.default_rng(0)
    genomes = [tuple(int(v) for v in rng.integers(1, 25, len(sites)))
               for _ in range(5)]
    ev.errors_matrix(genomes, task.train_inputs, exact)
    est = make_estimator("dynamic", prof, "cip", sites, target=task.target)
    assert est.fpu_matrix(ev, genomes).shape == (5, len(task.train_inputs))
    worst = host_device_parity(task, "cip", sites, est, ev, genomes,
                               task.train_inputs)
    assert worst < 1e-6


def test_dynamic_estimator_scan_app_matches_host():
    """Scan bodies thread their census out through the scan outputs: the
    fold over iterations must equal the eager reference too."""
    task = make_task(get_app("kmeans"), n_train=2, n_test=0)
    prof = profile(task.fn, *task.train_inputs[0])
    sites = sites_for_family(prof, "fcs", 4)
    exact = [jax.tree.map(np.asarray, task.fn(*inp))
             for inp in task.train_inputs]
    ev = PopulationEvaluator(task, "fcs", sites, pop_hint=2,
                             collect_bits=True)
    genomes = [(6,) * len(sites), (20,) * len(sites)]
    ev.errors_matrix(genomes, task.train_inputs, exact)
    est = make_estimator("dynamic", prof, "fcs", sites, target=task.target)
    assert host_device_parity(task, "fcs", sites, est, ev, genomes,
                              task.train_inputs) < 1e-6
    # every channel carries its static count bound (scan folds compound
    # it by the iteration count to pick an exact accumulator)
    assert all(ch.max_count > 0 for ch in ev.bit_channels)


def test_heterogeneous_input_shapes_fold_per_signature():
    """Unstackable (shape-varying) inputs dispatch at distinct jit
    signatures whose census channels differ (shape enters the
    flops/numel weight): each input's counts must fold with its own
    signature's scales, matching the host reference per input."""
    def fn(a, b):
        with pscope("mm"):
            return (a @ b) * jnp.float32(0.5)

    rng = np.random.default_rng(9)
    inputs = [
        (jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
         jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)),
        (jnp.asarray(rng.standard_normal((4, 16)), jnp.float32),
         jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)),
    ]
    task = ExplorationTask(name="ragged", fn=fn, train_inputs=inputs,
                           test_inputs=[])
    prof = profile(task.fn, *inputs[0])
    sites = sites_for_family(prof, "cip", 2)
    exact = [jax.tree.map(np.asarray, task.fn(*inp)) for inp in inputs]
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=3,
                             collect_bits=True)
    genomes = [(6,) * len(sites), (12,) * len(sites), (24,) * len(sites)]
    ev.errors_matrix(genomes, inputs, exact)
    assert PopulationEvaluator.stack_inputs(inputs) is None  # truly ragged
    # the dot channel's weight = 2K differs between the two inputs
    w0 = {c.weight for c in ev.bit_channels_list[0]}
    w1 = {c.weight for c in ev.bit_channels_list[1]}
    assert w0 != w1
    est = make_estimator("dynamic", prof, "cip", sites, target=task.target)
    assert host_device_parity(task, "cip", sites, est, ev, genomes,
                              inputs) < 1e-6
    # the serial path agrees input by input as well
    for p, g in enumerate(genomes):
        ev.errors_serial(g, inputs, exact)
        for i in range(len(inputs)):
            np.testing.assert_array_equal(ev.last_serial_bit_counts[i],
                                          ev.last_bit_counts_list[i][p])


def test_cond_branches_measured_by_index():
    """Cond branches thread per-branch counters through the switch: the
    *taken* branch's exact census is selected by branch index (the
    other branches' union segments stay zero), replacing the old static
    largest-branch bound. With same-op-class branches the static charge
    is branch-invariant, so measured dynamic energy is <= it (trailing
    zeros only shrink the census); inputs taking *different* branches
    measure different energies (x*2 shifts the exponent and flips no
    mantissa bits, x*1.5 manipulates them), and the host reference
    agrees per input. (With *different*-class branches the static model
    still charges the most-equations branch, so a costlier taken branch
    may legitimately exceed it — the documented while-style caveat.)"""
    def fn(x):
        with pscope("branch"):
            y = jax.lax.cond(jnp.sum(x) > 0,
                             lambda v: v * jnp.float32(2.0),
                             lambda v: v * jnp.float32(1.5), x)
        return y

    rng = np.random.default_rng(5)
    xpos = jnp.abs(jnp.asarray(rng.standard_normal((8, 16)), jnp.float32))
    inputs = [(xpos,), (-xpos,)]       # branch 1 vs branch 0
    task = ExplorationTask(name="br", fn=fn, train_inputs=inputs,
                           test_inputs=[])
    prof = profile(task.fn, *inputs[0])
    sites = sites_for_family(prof, "cip", 3)
    exact = [jax.tree.map(np.asarray, task.fn(*inp)) for inp in inputs]
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=2,
                             collect_bits=True)
    genomes = [(5,) * len(sites), (24,) * len(sites)]
    ev.errors_matrix(genomes, inputs, exact)
    dyn = make_estimator("dynamic", prof, "cip", sites, target=task.target)
    stat = make_estimator("static", prof, "cip", sites, target=task.target)
    df = dyn.fpu_matrix(ev, genomes)           # (P, I) per-input energies
    sf, _ = stat.population(genomes)
    # measured census of the taken branch never exceeds its static bound
    assert np.all(df <= np.asarray(sf)[:, None] * (1 + 1e-12))
    # the two inputs take different branches -> different measured bits
    assert not np.allclose(df[:, 0], df[:, 1])
    assert host_device_parity(task, "cip", sites, dyn, ev, genomes,
                              inputs) < 1e-6


def test_cond_branch_census_matches_eager_branch():
    """The union counts vector really carries the taken branch's exact
    census: evaluating the cond app equals evaluating the taken branch's
    body as a straight-line function, channel for channel."""
    def fn(x):
        with pscope("branch"):
            return jax.lax.cond(jnp.sum(x) > 0,
                                lambda v: v * jnp.float32(1.5),
                                lambda v: v + jnp.float32(1.0), x)

    def taken(x):                      # the branch a positive x selects
        with pscope("branch"):
            return x * jnp.float32(1.5)

    rng = np.random.default_rng(7)
    x = jnp.abs(jnp.asarray(rng.standard_normal((4, 8)), jnp.float32))

    def dyn_energy(f):
        task = ExplorationTask(name="c", fn=f, train_inputs=[(x,)],
                               test_inputs=[])
        prof = profile(task.fn, x)
        sites = sites_for_family(prof, "cip", 3)
        # uniform genomes, so site-count differences between the cond
        # app and the straight-line branch don't matter
        genomes = [(6,) * len(sites), (24,) * len(sites)]
        exact = [jax.tree.map(np.asarray, task.fn(x))]
        ev = PopulationEvaluator(task, "cip", sites, pop_hint=2,
                                 collect_bits=True)
        ev.errors_matrix(genomes, [(x,)], exact)
        est = make_estimator("dynamic", prof, "cip", sites,
                             target=task.target)
        return np.asarray(est.fpu_matrix(ev, genomes))

    # the untaken branch's union segment is zero and the taken segment
    # carries the straight-line census, so the cond app's measured FPU
    # energy equals the taken branch evaluated as a plain function
    np.testing.assert_allclose(dyn_energy(fn), dyn_energy(taken),
                               rtol=1e-9)


def test_while_bodies_measured_via_carry():
    """While bodies thread their census through the loop carry: the
    data-dependent trip count is *measured*, not charged the profiler's
    one-iteration static bound — so a 3-trip loop's dynamic FPU energy
    exceeds the 1-trip static charge.

    Parity caveat: a while body only ever executes compiled, and XLA's
    value-changing loop fusions (mul+add -> fma) differ between the
    device's whole-program compile, the host reference's standalone loop
    compile, and eager unrolled execution — so *full-precision*
    trailing-zero counts can disagree in low-order bits across the
    three. Reduced-width genomes truncate those bits away (exact
    equality); full-width parity is asserted to a documented 5e-3."""
    trips = 3

    def fn(x):
        with pscope("loop"):
            def body(c):
                i, v = c
                return i + 1, v * jnp.float32(1.5) + x
            _, y = jax.lax.while_loop(lambda c: c[0] < trips, body,
                                      (jnp.int32(0), x))
        return y

    def unrolled(x):
        with pscope("loop"):
            y = x
            for _ in range(trips):
                y = y * jnp.float32(1.5) + x
        return y

    rng = np.random.default_rng(5)
    inputs = [(jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),)]
    task = ExplorationTask(name="wl", fn=fn, train_inputs=inputs,
                           test_inputs=[])
    prof = profile(task.fn, *inputs[0])
    sites = sites_for_family(prof, "cip", 3)
    exact = [jax.tree.map(np.asarray, task.fn(*inp)) for inp in inputs]
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=2,
                             collect_bits=True)
    genomes = [(5,) * len(sites), (24,) * len(sites)]
    ev.errors_matrix(genomes, inputs, exact)
    dyn = make_estimator("dynamic", prof, "cip", sites, target=task.target)
    assert host_device_parity(task, "cip", sites, dyn, ev, genomes,
                              inputs) < 5e-3

    # the measured loop census == the loop unrolled by hand: bit-exact at
    # the truncated genome, fma-fusion-tolerant at full width
    from repro.core.energy import dynamic_fpu_energy
    from repro.core.interpreter import capture_bit_census
    from repro.core.placement import rule_from_genome
    for g, rel in ((genomes[0], 1e-12), (genomes[1], 5e-3)):
        rule = rule_from_genome("cip", sites, g, target=task.target,
                                mode=task.mode)
        _, rec_w = capture_bit_census(fn, rule, "cip", sites,
                                      target=task.target)(*inputs[0])
        _, rec_u = capture_bit_census(unrolled, rule, "cip", sites,
                                      target=task.target)(*inputs[0])
        assert dynamic_fpu_energy(rec_w) == pytest.approx(
            dynamic_fpu_energy(rec_u), rel=rel)

    # at the truncated genome the rounding absorbs fusion differences:
    # device accumulators equal the host records exactly, channel by
    # channel, and equal trips x the per-iteration census
    rule = rule_from_genome("cip", sites, genomes[0], target=task.target,
                            mode=task.mode)
    _, recs = capture_bit_census(fn, rule, "cip", sites,
                                 target=task.target)(*inputs[0])
    np.testing.assert_array_equal(
        np.asarray([r.count for r in recs]),
        np.asarray(ev.last_bit_counts_list[0][0]))

    # trip counts measured, not bounded: 3 trips of real values dwarf the
    # profiler's single-iteration static estimate
    stat = make_estimator("static", prof, "cip", sites, target=task.target)
    df, _ = dyn.population(genomes, evaluator=ev)
    sf, _ = stat.population(genomes)
    assert np.all(df > sf)


def test_ungoverned_while_bodies_keep_old_path():
    """A while whose body mints no census channel (integer-only work)
    threads an empty accumulator tuple — the old behavior, exactly: the
    loop runs, the census is untouched, and host/device still agree on
    the surrounding governed ops."""
    def fn(x):
        with pscope("count"):
            n, _ = jax.lax.while_loop(
                lambda c: c[0] < 4,
                lambda c: (c[0] + 1, c[1]),
                (jnp.int32(0), jnp.int32(7)))
        with pscope("scale"):
            return x * (1.0 + 0.1 * n.astype(jnp.float32))

    rng = np.random.default_rng(11)
    inputs = [(jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),)]
    task = ExplorationTask(name="uw", fn=fn, train_inputs=inputs,
                           test_inputs=[])
    prof = profile(task.fn, *inputs[0])
    sites = sites_for_family(prof, "cip", 2)
    exact = [jax.tree.map(np.asarray, task.fn(*inp)) for inp in inputs]
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=1,
                             collect_bits=True)
    genomes = [(8,) * len(sites)]
    ev.errors_matrix(genomes, inputs, exact)
    dyn = make_estimator("dynamic", prof, "cip", sites, target=task.target)
    assert host_device_parity(task, "cip", sites, dyn, ev, genomes,
                              inputs) < 1e-6


def test_governed_transcendentals_keep_static_charge(bs_setup):
    """Governed FLOPs the interpreter does not intercept (blackscholes is
    exp/log-heavy) must keep their static genome-scaled charge: at the
    full-precision genome the dynamic estimate stays close below static
    (random mantissas average ~full-1 manipulated bits), not collapsed to
    a fraction of it."""
    task, prof, sites, exact = bs_setup
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=1,
                             collect_bits=True)
    genomes = [(24,) * len(sites)]
    ev.errors_matrix(genomes, task.train_inputs, exact)
    stat = make_estimator("static", prof, "cip", sites, target=task.target)
    dyn = make_estimator("dynamic", prof, "cip", sites, target=task.target)
    sf, _ = stat.population(genomes)
    df, _ = dyn.population(genomes, evaluator=ev)
    assert df[0] <= sf[0] * (1 + 1e-9)
    assert df[0] > 0.8 * sf[0]
    assert dyn.governed_residual(genomes)[0] > 0


def test_serial_path_matches_batched_census(bs_setup):
    """errors_serial collects the same accumulators as the batched
    dispatch, genome by genome."""
    task, prof, sites, exact = bs_setup
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=3,
                             collect_bits=True)
    genomes = [(8,) * len(sites), (3,) * len(sites), (24,) * len(sites)]
    ev.errors_matrix(genomes, task.train_inputs, exact)
    batched = ev.last_bit_counts.copy()
    for p, g in enumerate(genomes):
        ev.errors_serial(g, task.train_inputs, exact)
        np.testing.assert_array_equal(
            np.stack(ev.last_serial_bit_counts), batched[p])


# ---------------------------------------------------------------------------
# static-vs-dynamic sanity: dynamic energy <= static for identical genomes
# ---------------------------------------------------------------------------

def _sparse_task():
    """A scoped app fed sparse-mantissa inputs (small integers / exact
    powers of two): the dynamic census should be far below the static
    charge, never above it."""
    def fn(x, y):
        with pscope("prod"):
            a = x * y
        with pscope("blend"):
            b = a + x
            c = b * jnp.float32(0.5)
        return c

    rng = np.random.default_rng(7)
    inputs = [(jnp.asarray(rng.integers(1, 9, (64, 32)), jnp.float32),
               jnp.asarray(2.0 ** rng.integers(-3, 4, (64, 32)),
                           jnp.float32))
              for _ in range(2)]
    return ExplorationTask(name="sparse", fn=fn, train_inputs=inputs,
                           test_inputs=[])


def test_dynamic_leq_static_on_sparse_inputs():
    task = _sparse_task()
    prof = profile(task.fn, *task.train_inputs[0])
    sites = sites_for_family(prof, "cip", 4)
    exact = [jax.tree.map(np.asarray, task.fn(*inp))
             for inp in task.train_inputs]
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=6,
                             collect_bits=True)
    rng = np.random.default_rng(1)
    genomes = [tuple(int(v) for v in rng.integers(1, 25, len(sites)))
               for _ in range(6)]
    ev.errors_matrix(genomes, task.train_inputs, exact)
    stat = make_estimator("static", prof, "cip", sites, target=task.target)
    dyn = make_estimator("dynamic", prof, "cip", sites, target=task.target)
    sf, sm = stat.population(genomes)
    df, dm = dyn.population(genomes, evaluator=ev)
    assert np.all(df <= sf * (1 + 1e-9))
    # sparse mantissas leave most static bits uncharged
    assert np.all(df < sf)
    # memory energy stays the static storage model
    np.testing.assert_allclose(dm, sm)
    # per-site folding is consistent with the per-genome totals
    per_site = fold_bit_counts(ev.bit_channels, ev.last_bit_counts,
                               len(sites))
    np.testing.assert_allclose(
        per_site.sum(axis=2).mean(axis=1) + dyn.coeffs.fpu_const
        + dyn.governed_residual(genomes), df, rtol=1e-12)


def test_explore_dynamic_end_to_end(bs_setup):
    """explore(energy="dynamic") stays population-batched: identical
    dispatch count to the static objective, dynamic energies on the
    shared static-baseline axis, robustness energies recomputed on the
    unseen inputs."""
    task, _, _, _ = bs_setup
    kw = dict(family="cip", n_sites=4, pop_size=8, n_gen=2, max_evals=24,
              seed=0)
    rep_s = explore(task, energy="static", **kw)
    rep_d = explore(task, energy="dynamic", **kw)
    assert rep_d.energy_estimator == "dynamic"
    assert rep_d.n_dispatches <= rep_s.n_dispatches + 2
    assert rep_d.n_evals == rep_s.n_evals
    assert all(np.isfinite(p.energy) and p.energy > 0 for p in rep_d.points)
    # same genomes explored (identical NSGA-II seeds + error objective
    # stream would only diverge through the energy objective's ranking)
    assert np.isfinite(rep_d.robustness_energy_r)

    # serial dynamic path agrees with the batched dynamic front
    rep_ds = explore(task, energy="dynamic", batched=False,
                     robustness=False, **kw)
    front_b = {p.payload["genome"]: p.energy for p in rep_d.hull}
    front_s = {p.payload["genome"]: p.energy for p in rep_ds.hull}
    assert set(front_b) == set(front_s)
    for g in front_b:
        assert front_b[g] == pytest.approx(front_s[g], rel=1e-6)


def test_estimator_registry_and_errors(bs_setup):
    task, prof, sites, exact = bs_setup
    with pytest.raises(ValueError, match="unknown energy estimator"):
        make_estimator("entropy", prof, "cip", sites)
    # a ready-made estimator instance passes through
    est = make_estimator("dynamic", prof, "cip", sites)
    assert make_estimator(est) is est
    # dynamic estimator refuses stale/missing accumulators
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=2,
                             collect_bits=True)
    with pytest.raises(ValueError, match="bit-census"):
        est.population([(8,) * len(sites)], evaluator=ev)
    # custom registration plugs into explore() and reports its own name
    register_estimator("dynamic2", DynamicEnergyEstimator)
    est2 = make_estimator("dynamic2", prof, "cip", sites)
    assert est2.needs_bit_census
    assert est2.name == "dynamic2"


def test_measured_power_estimator_serial_path(bs_setup):
    """The third registrant: per-op roofline time x device TDP. Width-
    monotone, baseline-consistent, and the serial explorer path ranks on
    it exactly like the batched path (it is census-free, so both paths
    reduce to the same einsum)."""
    task, prof, sites, exact = bs_setup
    est = make_estimator("measured-power", prof, "cip", sites,
                         target=task.target)
    assert est.name == "measured-power"
    assert not est.needs_bit_census
    genomes = [(4,) * len(sites), (12,) * len(sites), (24,) * len(sites)]
    fpu, mem = est.population(genomes)
    # transprecision timing: wider mantissas -> more seconds -> more J
    assert np.all(np.diff(fpu) > 0) and np.all(np.diff(mem) > 0)
    # the full-width genome reproduces the identity baseline
    np.testing.assert_allclose(fpu[-1], est.baseline().fpu_pj, rtol=1e-12)
    # MXU-rate charges differ from the paper's EPI table: the static and
    # measured-power estimators disagree on absolute pJ
    stat = make_estimator("static", prof, "cip", sites, target=task.target)
    assert not np.allclose(fpu, stat.population(genomes)[0])

    kw = dict(family="cip", n_sites=4, pop_size=6, n_gen=1, max_evals=10,
              seed=0, robustness=False)
    rep_b = explore(task, energy="measured-power", **kw)
    rep_s = explore(task, energy="measured-power", batched=False, **kw)
    assert rep_b.energy_estimator == "measured-power"
    front_b = {p.payload["genome"]: p.energy for p in rep_b.hull}
    front_s = {p.payload["genome"]: p.energy for p in rep_s.hull}
    assert set(front_b) == set(front_s)
    for g in front_b:
        assert front_b[g] == pytest.approx(front_s[g], rel=1e-6)


def test_custom_estimator_drives_serial_path(bs_setup):
    """A non-census custom estimator must rank genomes on *its* energies
    in batched AND serial mode (the serial path used to silently fall
    back to static_energy)."""
    task, prof, sites, _ = bs_setup

    class Halved(StaticEnergyEstimator):
        def population(self, bits_matrix, *, evaluator=None):
            fpu, mem = super().population(bits_matrix, evaluator=evaluator)
            return fpu / 2.0, mem

    coeffs = make_estimator("static", prof, "cip", sites).coeffs
    kw = dict(family="cip", n_sites=4, pop_size=6, n_gen=1, max_evals=10,
              seed=0, robustness=False)
    for batched in (True, False):
        rep_h = explore(task, energy=Halved(coeffs, name="halved"),
                        batched=batched, **kw)
        rep_s = explore(task, energy="static", batched=batched, **kw)
        assert rep_h.energy_estimator == "halved"
        by_genome = {p.payload["genome"]: p.energy for p in rep_s.points}
        for p in rep_h.points:
            # halved pJ against the unhalved baseline: exactly half
            assert p.energy == pytest.approx(
                by_genome[p.payload["genome"]] / 2.0, rel=1e-6)
