"""Population-batched exploration engine: batched-vs-serial parity,
tensorized energy parity, and NSGA-II ask/tell determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import get_app, make_task
from repro.core import energy as energy_mod
from repro.core import explore
from repro.core.explorer import PopulationEvaluator, sites_for_family
from repro.core.interpreter import (neat_transform_dynamic,
                                    neat_transform_population)
from repro.core.nsga2 import NSGA2, nsga2
from repro.core.placement import rule_from_genome, site_index_for_stack
from repro.core.profiler import profile


@pytest.fixture(scope="module")
def bs_task():
    return make_task(get_app("blackscholes"), n_train=3, n_test=2)


@pytest.fixture(scope="module")
def bs_setup(bs_task):
    prof = profile(bs_task.fn, *bs_task.train_inputs[0])
    sites = sites_for_family(prof, "cip", 4)
    exact = [jax.tree.map(np.asarray, bs_task.fn(*inp))
             for inp in bs_task.train_inputs]
    return prof, sites, exact


# ---------------------------------------------------------------------------
# vmapped transform == per-genome dynamic transform
# ---------------------------------------------------------------------------

def test_population_transform_matches_dynamic(bs_task, bs_setup):
    _, sites, _ = bs_setup
    g = neat_transform_dynamic(bs_task.fn, "cip", sites)
    G = neat_transform_population(bs_task.fn, "cip", sites)
    rng = np.random.default_rng(0)
    bits = rng.integers(1, 25, size=(5, len(sites)))
    inp = bs_task.train_inputs[0]
    batched = G(jnp.asarray(bits, jnp.int32), *inp)
    for p in range(len(bits)):
        single = g(jnp.asarray(bits[p], jnp.int32), *inp)
        for bl, sl in zip(jax.tree.leaves(batched), jax.tree.leaves(single)):
            np.testing.assert_allclose(np.asarray(bl)[p], np.asarray(sl),
                                       rtol=1e-6, atol=1e-7)


def test_errors_matrix_matches_serial(bs_task, bs_setup):
    """eval_population objectives == looped eval_genome to ~1e-6."""
    _, sites, exact = bs_setup
    ev = PopulationEvaluator(bs_task, "cip", sites, pop_hint=8)
    rng = np.random.default_rng(1)
    genomes = [tuple(int(v) for v in rng.integers(1, 25, len(sites)))
               for _ in range(8)]
    mat = ev.errors_matrix(genomes, bs_task.train_inputs, exact)
    ser = np.asarray([ev.errors_serial(g, bs_task.train_inputs, exact)
                      for g in genomes])
    np.testing.assert_allclose(mat, ser, rtol=1e-6, atol=1e-9)


def test_errors_matrix_single_input_path(bs_task, bs_setup):
    """The unstackable / single-input fallback (one dispatch per input)."""
    _, sites, exact = bs_setup
    ev = PopulationEvaluator(bs_task, "cip", sites, pop_hint=4)
    genomes = [(24,) * len(sites), (6,) * len(sites)]
    mat = ev.errors_matrix(genomes, bs_task.train_inputs[:1], exact[:1])
    ser = np.asarray([ev.errors_serial(g, bs_task.train_inputs[:1],
                                       exact[:1]) for g in genomes])
    np.testing.assert_allclose(mat, ser, rtol=1e-6, atol=1e-9)


# ---------------------------------------------------------------------------
# tensorized energy == scalar static_energy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,app", [("cip", "blackscholes"),
                                        ("wp", "blackscholes"),
                                        ("fcs", "kmeans"),
                                        ("plc", "kmeans"),
                                        ("pli", "radar")])
def test_population_energy_matches_static(family, app):
    task = make_task(get_app(app), n_train=1, n_test=0)
    prof = profile(task.fn, *task.train_inputs[0])
    sites = sites_for_family(prof, family, 4)
    coeffs = energy_mod.energy_coeffs(prof, family, sites, target="single")
    base = energy_mod.static_energy(prof, None)
    b = coeffs.baseline()
    assert b.fpu_pj == pytest.approx(base.fpu_pj, rel=1e-9)
    assert b.mem_pj == pytest.approx(base.mem_pj, rel=1e-9)
    rng = np.random.default_rng(2)
    bits = rng.integers(1, 25, size=(10, len(sites)))
    fpu, mem = energy_mod.population_energy(coeffs, bits)
    for p in range(len(bits)):
        rule = rule_from_genome(family, sites,
                                tuple(int(v) for v in bits[p]),
                                target="single")
        rep = energy_mod.static_energy(prof, rule)
        assert fpu[p] == pytest.approx(rep.fpu_pj, rel=1e-6)
        assert mem[p] == pytest.approx(rep.mem_pj, rel=1e-6)


def test_site_index_shared_between_interpreter_and_energy():
    idx = {"a": 0, "b": 1, "__default__": 2}
    assert site_index_for_stack("cip", idx, ("x", "a")) == 0
    assert site_index_for_stack("cip", idx, ("a", "x")) == 2   # default
    assert site_index_for_stack("fcs", idx, ("a", "x")) == 0   # outward walk
    assert site_index_for_stack("wp", idx, ()) == 0
    assert site_index_for_stack("plc", {"conv": 3}, ("m", "conv7")) == 3
    assert site_index_for_stack("pli", {"m/conv1": 4}, ("m", "conv1", "k")) == 4


# ---------------------------------------------------------------------------
# ask/tell NSGA-II
# ---------------------------------------------------------------------------

def _toy_eval(g):
    b = np.asarray(g)
    return (b.sum() / (24 * len(b)), float(((24 - b) ** 2).sum()) / 500)


def test_ask_tell_matches_legacy_wrapper():
    """Same seed -> identical evaluated set through either API."""
    for seed in (0, 3, 11):
        a = nsga2(_toy_eval, 4, 1, 24, pop_size=12, n_gen=5,
                  max_evals=90, seed=seed)
        opt = NSGA2(4, 1, 24, pop_size=12, n_gen=5, max_evals=90, seed=seed)
        while not opt.done:
            batch = opt.ask()
            assert len(batch) == len(set(batch))      # deduplicated
            opt.tell(batch, [_toy_eval(g) for g in batch])
        b = opt.result()
        assert [e.genome for e in a.evaluated] == \
            [e.genome for e in b.evaluated]
        assert [e.objectives for e in a.evaluated] == \
            [e.objectives for e in b.evaluated]
        assert [e.genome for e in a.population] == \
            [e.genome for e in b.population]
        assert a.n_evals == b.n_evals


def test_ask_tell_budget_counts_unique():
    opt = NSGA2(3, 1, 24, pop_size=10, n_gen=50, max_evals=37, seed=0)
    seen = []
    while not opt.done:
        batch = opt.ask()
        seen.extend(batch)
        opt.tell(batch, [_toy_eval(g) for g in batch])
    assert len(seen) == len(set(seen)) <= 37
    assert opt.result().n_evals <= 37


def test_tell_validates_batch():
    opt = NSGA2(3, 1, 24, pop_size=6, n_gen=2, max_evals=30, seed=0)
    batch = opt.ask()
    with pytest.raises(ValueError):
        opt.tell(batch[:-1], [_toy_eval(g) for g in batch[:-1]])
    # out-of-order tell is fine
    rev = list(reversed(batch))
    opt.tell(rev, [_toy_eval(g) for g in rev])
    assert not opt.done or opt.result()


# ---------------------------------------------------------------------------
# end-to-end: batched explorer == serial explorer
# ---------------------------------------------------------------------------

def test_explore_batched_matches_serial(bs_task):
    rep_b = explore(bs_task, family="cip", n_sites=4, pop_size=10, n_gen=3,
                    max_evals=40, seed=0, batched=True, robustness=True)
    rep_s = explore(bs_task, family="cip", n_sites=4, pop_size=10, n_gen=3,
                    max_evals=40, seed=0, batched=False, robustness=True)
    assert rep_b.n_evals == rep_s.n_evals
    gb = [p.payload["genome"] for p in rep_b.points]
    gs = [p.payload["genome"] for p in rep_s.points]
    assert gb == gs                           # identical evaluated stream
    for pb, ps in zip(rep_b.points, rep_s.points):
        assert pb.error == pytest.approx(ps.error, rel=1e-6, abs=1e-9)
        assert pb.energy == pytest.approx(ps.energy, rel=1e-6)
    assert [p.payload["genome"] for p in rep_b.hull] == \
        [p.payload["genome"] for p in rep_s.hull]
    # batching is the point: far fewer compiled dispatches
    assert rep_b.n_dispatches < rep_s.n_dispatches / 4
    assert rep_b.robustness_error_r == pytest.approx(
        rep_s.robustness_error_r, rel=1e-6)


def test_explore_sharded_population(bs_task):
    """Population-axis sharding (1-D 'pop' mesh over however many local
    devices exist; CI forces 8 CPU devices via XLA_FLAGS)."""
    rep = explore(bs_task, family="cip", n_sites=4, pop_size=10, n_gen=2,
                  max_evals=30, seed=0, batched=True, shard=True,
                  robustness=False)
    ref = explore(bs_task, family="cip", n_sites=4, pop_size=10, n_gen=2,
                  max_evals=30, seed=0, batched=True, shard=False,
                  robustness=False)
    assert [p.payload["genome"] for p in rep.points] == \
        [p.payload["genome"] for p in ref.points]
    for a, b in zip(rep.points, ref.points):
        assert a.error == pytest.approx(b.error, rel=1e-6, abs=1e-9)
