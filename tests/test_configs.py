"""Assert every assigned architecture config matches the assignment's
exact dimensions."""
import pytest

from repro.configs import get_arch

ASSIGNED = {
    # name: (L, d_model, H, kv, d_ff, vocab)
    "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
    "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "seamless-m4t-medium": (24, 1024, 16, 16, 4096, 256206),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
}


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_exact_dims(name):
    cfg = get_arch(name)
    l, d, h, kv, ff, v = ASSIGNED[name]
    assert cfg.n_layers == l
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_family_features():
    assert get_arch("qwen3-moe-30b-a3b").n_experts == 128
    assert get_arch("qwen3-moe-30b-a3b").top_k == 8
    assert get_arch("granite-moe-1b-a400m").n_experts == 32
    assert get_arch("granite-moe-1b-a400m").top_k == 8
    assert get_arch("zamba2-7b").ssm_state == 64
    assert get_arch("h2o-danube-3-4b").sliding_window is not None
    assert get_arch("qwen2.5-32b").qkv_bias
    assert get_arch("qwen2-72b").qkv_bias
    assert get_arch("codeqwen1.5-7b").qkv_bias
    assert get_arch("chameleon-34b").qk_norm
    enc = get_arch("seamless-m4t-medium")
    assert enc.n_enc_layers == 12 and enc.n_dec_layers == 12
    kinds = get_arch("xlstm-1.3b").block_kinds
    assert kinds.count("slstm") == 6 and kinds.count("mlstm") == 42
