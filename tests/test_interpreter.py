import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CurrentScope, MantissaTrunc, WholeProgram,
                        neat_transform, neat_transform_dynamic, pscope)


def f_scoped(x):
    with pscope("heavy"):
        y = x * 1.23456789
    with pscope("light"):
        z = x + 0.98765432
    return y + z


def test_identity_rule_is_exact():
    x = jnp.linspace(0.0, 3.0, 32)
    out = neat_transform(f_scoped, WholeProgram(fpi=MantissaTrunc(24)))(x)
    assert np.allclose(np.asarray(out), np.asarray(f_scoped(x)), atol=0)


def test_scope_selective():
    x = jnp.linspace(1.0, 2.0, 16)
    rule = CurrentScope(mapping={"heavy": MantissaTrunc(3)})
    out = neat_transform(f_scoped, rule)(x)
    exact = f_scoped(x)
    assert not np.allclose(np.asarray(out), np.asarray(exact))
    # only-light rule perturbs differently
    rule2 = CurrentScope(mapping={"light": MantissaTrunc(3)})
    out2 = neat_transform(f_scoped, rule2)(x)
    assert not np.allclose(np.asarray(out2), np.asarray(out))


def test_control_flow_scan():
    def f(x):
        def body(c, t):
            with pscope("inner"):
                return c * 1.1 + t, c
        c, ys = jax.lax.scan(body, x, jnp.arange(4.0))
        return c + ys.sum()

    x = jnp.float32(1.0)
    exact = f(x)
    out = neat_transform(f, WholeProgram(fpi=MantissaTrunc(24)))(x)
    assert np.allclose(float(out), float(exact))
    out_q = neat_transform(f, WholeProgram(fpi=MantissaTrunc(4)))(x)
    assert not np.isnan(float(out_q))


def test_control_flow_cond_while():
    def f(x):
        y = jax.lax.cond(x.sum() > 0, lambda v: v * 2.0,
                         lambda v: v - 1.0, x)
        def cond(c):
            return c[0] < 10.0
        def body(c):
            return (c[0] * 1.5, c[1] + 1)
        out = jax.lax.while_loop(cond, body, (y.sum(), 0))
        return out[0]

    x = jnp.ones(4)
    exact = float(f(x))
    got = float(neat_transform(f, WholeProgram(fpi=MantissaTrunc(24)))(x))
    assert np.isclose(got, exact)
    q = float(neat_transform(f, WholeProgram(fpi=MantissaTrunc(5)))(x))
    assert np.isfinite(q)


def test_census_collected():
    fn = neat_transform(f_scoped, WholeProgram(fpi=MantissaTrunc(8)))
    fn(jnp.ones(8))
    assert fn.last_census
    scopes = {k[0] for k in fn.last_census}
    assert any("heavy" in s for s in scopes)


def test_dynamic_transform_jit_and_grad():
    g = jax.jit(neat_transform_dynamic(f_scoped, "cip", ["heavy", "light"]))
    x = jnp.linspace(1.0, 2.0, 8)
    full = g(jnp.array([24, 24], jnp.int32), x)
    assert np.allclose(np.asarray(full), np.asarray(f_scoped(x)), atol=1e-7)
    qa = g(jnp.array([3, 24], jnp.int32), x)
    qb = g(jnp.array([24, 3], jnp.int32), x)
    assert not np.allclose(np.asarray(qa), np.asarray(qb))


def test_pytree_inputs_outputs():
    def f(d):
        with pscope("s"):
            return {"out": d["a"] * 2.0 + d["b"]}

    rule = WholeProgram(fpi=MantissaTrunc(24))
    got = neat_transform(f, rule)({"a": jnp.ones(3), "b": jnp.ones(3)})
    assert np.allclose(np.asarray(got["out"]), 3.0)
