"""Chunked batched prefill: greedy-completion and KV-cache parity with
the streaming prefill path, across every model family and chunk size,
including ragged batches where slots flip prefill -> decode mid-step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import DecodeEngine, ServeConfig

# one arch per family: dense, moe, recurrent (ssm), hybrid, encdec
ARCHS = ["codeqwen1.5-7b", "granite-moe-1b-a400m", "xlstm-1.3b",
         "zamba2-7b", "seamless-m4t-medium"]

# skewed: lengths straddle every tested chunk size (1, 7, 32), so chunks
# end mid-prompt, exactly at a prompt end, and past it (ragged tails)
PROMPTS = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7],
           [8, 9, 10, 11, 12], [6] * 9, [13, 14]]


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64,
                                         vocab=64)
            model = build_model(cfg)
            cache[arch] = (model, model.init(jax.random.key(0)))
        return cache[arch]

    return get


def _engine(model, params, engine, chunk=32, slots=2, **kw):
    return DecodeEngine(model, params,
                        ServeConfig(max_len=48, batch_slots=slots,
                                    engine=engine, prefill_chunk=chunk,
                                    **kw))


@pytest.mark.parametrize("chunk", [1, 7, 32])
@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_matches_streaming_greedy(arch, chunk, models):
    """Greedy completions are identical whether prompts are ingested in
    1-, 7- or 32-token chunks or streamed token by token (the wave
    parity reference), for every family. With 2 slots and 8 skewed
    requests, chunk > 1 steps are mixed: one slot decodes while the
    other is still chunk-prefilling."""
    model, params = models(arch)
    wave = _engine(model, params, "wave").generate(PROMPTS,
                                                   max_new_tokens=6)
    cont = _engine(model, params, "continuous", chunk=chunk)
    got = cont.generate(PROMPTS, max_new_tokens=6)
    assert got == wave
    assert all(len(o) == 6 for o in got)
    if chunk >= 32:
        # every prompt fits one chunk: prefill collapses to one step per
        # admission group, so far fewer dispatches than streaming
        stream = _engine(model, params, "continuous", chunk=1)
        stream.generate(PROMPTS, max_new_tokens=6)
        assert cont.stats.steps < stream.stats.steps
        assert cont.stats.prefill_tokens == stream.stats.prefill_tokens


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_matches_streaming_cache(arch, models):
    """prefill_chunk leaves the cache exactly where streaming the same
    tokens through decode_step leaves it — KV entries, recurrent state
    and per-slot positions — while slots ingest ragged chunk tails."""
    model, params = models(arch)
    prompts = [[5, 9, 2, 7, 11, 3, 8], [1, 2], [3] * 5]
    b, s_len, chunk = 3, 24, 4

    # streaming reference: one decode_step per token, frozen once done
    cache_s = model.init_cache(b, s_len)
    last_s = [None] * b
    for t in range(max(len(p) for p in prompts)):
        cur = np.zeros((b, 1), np.int32)
        for i, p in enumerate(prompts):
            cur[i, 0] = p[min(t, len(p) - 1)]
        lg, new = model.decode_step(params, cache_s, jnp.asarray(cur))
        live = jnp.asarray([t < len(p) for p in prompts])
        cache_s = jax.tree.map(
            lambda n, o: jnp.where(
                live.reshape((b,) + (1,) * (n.ndim - 1)), n, o),
            new, cache_s)
        for i, p in enumerate(prompts):
            if t == len(p) - 1:
                last_s[i] = np.asarray(lg[i, 0])

    # chunked: ragged n_new, finished slots frozen (as the engine does
    # by feeding them decode tokens; here we mask the merge directly)
    cache_c = model.init_cache(b, s_len)
    rem = [list(p) for p in prompts]
    last_c = [None] * b
    while any(rem):
        toks = np.zeros((b, chunk), np.int32)
        n_new = np.ones((b,), np.int32)
        live = np.asarray([bool(r) for r in rem])
        for i in range(b):
            take = rem[i][:chunk]
            n_new[i] = max(len(take), 1)
            toks[i, :len(take)] = take
            rem[i] = rem[i][len(take):]
        lg, new = model.prefill_chunk(params, cache_c, jnp.asarray(toks),
                                      jnp.asarray(n_new))
        lv = jnp.asarray(live)
        cache_c = jax.tree.map(
            lambda n, o: jnp.where(
                lv.reshape((b,) + (1,) * (n.ndim - 1)), n, o),
            new, cache_c)
        for i in range(b):
            if live[i] and not rem[i] and last_c[i] is None:
                last_c[i] = np.asarray(lg[i, 0])

    for i in range(b):
        np.testing.assert_allclose(last_c[i], last_s[i], rtol=2e-4,
                                   atol=2e-4)
    jax.tree.map(
        lambda a, bb: np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(bb, np.float64),
            rtol=1e-5, atol=1e-5),
        cache_c, cache_s)


def test_mixed_step_isolates_decode_and_prefill_slots(models):
    """One mixed chunked step — slot 0 decoding (n_new=1), slot 1 still
    prefilling (n_new=chunk) — must give each slot exactly what it gets
    served alone: the ragged tail masking keeps slots independent."""
    model, params = models("codeqwen1.5-7b")
    b, s_len, chunk = 2, 24, 4
    prompt0, prompt1 = [5, 9, 2], [7, 11, 3, 8, 1, 2]

    cache = model.init_cache(b, s_len)
    # step 1: slot 0 ingests its whole prompt, slot 1 its first chunk
    toks = np.zeros((b, chunk), np.int32)
    toks[0, :3] = prompt0
    toks[1, :4] = prompt1[:4]
    lg1, cache = model.prefill_chunk(params, cache, jnp.asarray(toks),
                                     jnp.asarray([3, 4], np.int32))
    tok0 = int(jnp.argmax(lg1[0, 0]))
    # step 2 (mixed): slot 0 decodes tok0, slot 1 finishes prefilling
    toks = np.zeros((b, chunk), np.int32)
    toks[0, 0] = tok0
    toks[1, :2] = prompt1[4:]
    lg2, cache = model.prefill_chunk(params, cache, jnp.asarray(toks),
                                     jnp.asarray([1, 2], np.int32))
    assert np.array_equal(np.asarray(cache["pos"]), [4, 6])

    # references: each request served alone through the same chunked path
    def solo(prompt, plan):
        c = model.init_cache(1, s_len)
        fed = 0
        out = None
        for n in plan:
            t = np.zeros((1, chunk), np.int32)
            t[0, :n] = prompt[fed:fed + n]
            fed += n
            out, c = model.prefill_chunk(params, c, jnp.asarray(t),
                                         jnp.asarray([n], np.int32))
        return out

    solo0 = solo(prompt0 + [tok0], [3, 1])
    solo1 = solo(prompt1, [4, 2])
    np.testing.assert_allclose(np.asarray(lg2[0]), np.asarray(solo0[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lg2[1]), np.asarray(solo1[0]),
                               rtol=1e-5, atol=1e-5)


def test_chunked_matches_streaming_greedy_under_rule(models):
    """Reduced-precision serving: with an active NEAT placement rule the
    decode path quantizes the attention scores before its softmax, so
    the chunked path must fuse the same truncation into the kernel
    (``qk_bits``/``pv_bits`` resolved from the ambient rule) — greedy
    parity with the wave reference must survive the rule."""
    from repro.core.fpi import MantissaTrunc
    from repro.core.placement import WholeProgram
    model, params = models("codeqwen1.5-7b")
    rule = WholeProgram(fpi=MantissaTrunc(8), target="single")

    def engine(kind, chunk):
        return DecodeEngine(model, params,
                            ServeConfig(max_len=48, batch_slots=2,
                                        engine=kind, prefill_chunk=chunk),
                            rule=rule)

    wave = engine("wave", 1).generate(PROMPTS, max_new_tokens=6)
    chunked = engine("continuous", 7).generate(PROMPTS, max_new_tokens=6)
    assert chunked == wave

    # the rule really reaches the chunked path (not vacuous parity):
    # truncated-vs-full-precision chunk logits must differ
    from repro.core.quantize import use_rule
    toks = jnp.asarray([[5, 9, 2, 7]], jnp.int32)
    n_new = jnp.asarray([4], jnp.int32)
    with use_rule(WholeProgram(fpi=MantissaTrunc(4), target="single")):
        lg_rule, _ = model.prefill_chunk(params, model.init_cache(1, 16),
                                         toks, n_new)
    lg_full, _ = model.prefill_chunk(params, model.init_cache(1, 16),
                                     toks, n_new)
    assert not np.allclose(np.asarray(lg_rule), np.asarray(lg_full),
                           atol=1e-6)


def test_scan_layers_prefill_chunk_matches_streaming():
    """The lax.scan-over-layers cache layout (stacked (L, B, S, KV, Dh)
    leaves) takes the same chunked path: ragged chunk == each request
    streamed solo."""
    import dataclasses
    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=32,
                                             d_ff=64, vocab=64)
    cfg = dataclasses.replace(cfg, scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 16)
    toks = jnp.asarray([[5, 9, 2, 0], [1, 0, 0, 0]], jnp.int32)
    lg, cache = model.prefill_chunk(params, cache, toks,
                                    jnp.asarray([3, 1], jnp.int32))
    assert np.array_equal(np.asarray(cache["pos"]), [3, 1])

    def solo(seq):
        c = model.init_cache(1, 16)
        out = None
        for t in seq:
            out, c = model.decode_step(params, c,
                                       jnp.asarray([[t]], jnp.int32))
        return np.asarray(out[0, 0])

    np.testing.assert_allclose(np.asarray(lg[0, 0]), solo([5, 9, 2]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lg[1, 0]), solo([1]),
                               rtol=2e-4, atol=2e-4)


def test_chunked_write_never_clamps_onto_valid_entries(models):
    """A chunk whose padding columns would land past max_len must drop
    them (scatter mode='drop'), not clamp the write start back onto
    earlier valid entries: decoding near the end of the cache with a
    chunk-shaped step leaves the prefix intact."""
    model, params = models("codeqwen1.5-7b")
    s_len, chunk = 8, 4
    cache = model.init_cache(1, s_len)
    # fill 6 positions, leaving 2 free — less than the chunk width
    toks = jnp.asarray([[5, 9, 2, 7]], jnp.int32)
    _, cache = model.prefill_chunk(params, cache, toks,
                                   jnp.asarray([4], np.int32))
    _, cache = model.prefill_chunk(params, cache,
                                   jnp.asarray([[11, 3, 0, 0]], jnp.int32),
                                   jnp.asarray([2], np.int32))
    before = np.asarray(cache["layers"][0]["k"]).copy()
    # decode one token at pos 6: padding columns 1..3 index 7..9 (>= S)
    _, cache = model.prefill_chunk(params, cache,
                                   jnp.asarray([[1, 0, 0, 0]], jnp.int32),
                                   jnp.asarray([1], np.int32))
    after = np.asarray(cache["layers"][0]["k"])
    np.testing.assert_array_equal(after[:, :6], before[:, :6])
    assert np.any(after[:, 6] != 0)          # the real token landed
    np.testing.assert_array_equal(after[:, 7], before[:, 7])  # untouched
