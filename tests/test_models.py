"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finiteness asserts) and decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, SHAPES
from repro.models import build_model

RNG = jax.random.key(0)
B, T = 2, 16


def _batch(cfg, rng=RNG, t=T):
    tokens = jax.random.randint(rng, (B, t), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            rng, (B, t, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    if cfg.family == "encdec":
        logits = model.forward(params, batch)
    else:
        logits = model.forward(params, batch["tokens"])
    assert logits.shape == (B, T, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", list_archs())
def test_arch_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in flat)
    assert sum(float(jnp.sum(jnp.abs(g))) for g in flat) > 0


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "xlstm-1.3b", "zamba2-7b",
                                  "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch):
    """Greedy decode over a prefix reproduces forward logits (cache
    correctness)."""
    cfg = get_arch(arch).reduced()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_impl="ragged")
    model = build_model(cfg)
    params = model.init(RNG)
    toks = jax.random.randint(jax.random.key(3), (B, 8), 0, cfg.vocab_size)
    full = model.forward(params, toks)           # (B, 8, V)
    cache = model.init_cache(B, 16)
    outs = []
    for t in range(8):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-2, rtol=2e-2)


def test_shape_skip_rules():
    full_attn = get_arch("qwen2-72b")
    swa = get_arch("h2o-danube-3-4b")
    ssm = get_arch("xlstm-1.3b")
    hyb = get_arch("zamba2-7b")
    long = SHAPES["long_500k"]
    assert not long.applies(full_attn)
    assert long.applies(swa) and long.applies(ssm) and long.applies(hyb)
    assert long.skip_reason(full_attn)


def test_param_counts_match_scale():
    """Config-level param counts are in the advertised ballpark."""
    approx = {
        "qwen2-72b": 72e9, "qwen2.5-32b": 32e9, "chameleon-34b": 34e9,
        "codeqwen1.5-7b": 7e9, "h2o-danube-3-4b": 4e9,
        "qwen3-moe-30b-a3b": 30e9, "granite-moe-1b-a400m": 1.3e9,
        "xlstm-1.3b": 1.3e9, "zamba2-7b": 7e9,
    }
    for name, want in approx.items():
        got = get_arch(name).param_count()
        assert 0.55 * want < got < 1.6 * want, (name, got, want)


def test_moe_active_params():
    cfg = get_arch("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert active < 0.25 * cfg.param_count()     # 3B active of 30B
