import jax.numpy as jnp
import pytest

from repro.core.fpi import (IDENTITY, MantissaTrunc, OperandTrunc, PerOpTrunc,
                            single_precision_fpis, double_precision_fpis)
from repro.core.placement import (CallStack, CurrentScope, LayerCategory,
                                  LayerInstance, WholeProgram,
                                  register_fp_selector, rule_from_genome,
                                  selector_registry)


def test_fpi_families_sizes():
    assert len(single_precision_fpis()) == 24      # paper Table I
    assert len(double_precision_fpis()) == 53


def test_wp_selects_everywhere():
    rule = WholeProgram(fpi=MantissaTrunc(7))
    for stack in [(), ("a",), ("a", "b", "c")]:
        assert rule.select(stack, "mul", jnp.float32).mantissa_bits(
            jnp.float32) == 7
    # wrong target dtype -> identity
    assert rule.select(("a",), "mul", jnp.float64) is IDENTITY


def test_cip_innermost_only():
    rule = CurrentScope(mapping={"fft": MantissaTrunc(5)},
                        default=MantissaTrunc(20))
    assert rule.select(("lpf", "fft"), "add",
                       jnp.float32).mantissa_bits(jnp.float32) == 5
    # fft on the stack but not innermost -> default
    assert rule.select(("fft", "post"), "add",
                       jnp.float32).mantissa_bits(jnp.float32) == 20


def test_fcs_walks_outward():
    rule = CallStack(mapping={"lpf": MantissaTrunc(4),
                              "pc": MantissaTrunc(24)})
    assert rule.select(("lpf", "fft"), "mul",
                       jnp.float32).mantissa_bits(jnp.float32) == 4
    assert rule.select(("pc", "fft"), "mul",
                       jnp.float32).mantissa_bits(jnp.float32) == 24
    # innermost match wins over outer
    rule2 = CallStack(mapping={"a": MantissaTrunc(3),
                               "b": MantissaTrunc(9)})
    assert rule2.select(("a", "b", "x"), "mul",
                        jnp.float32).mantissa_bits(jnp.float32) == 9


def test_plc_category_strips_digits():
    rule = LayerCategory(mapping={"conv": MantissaTrunc(6)})
    for leaf in ("conv1", "conv2", "conv12"):
        assert rule.select(("model", leaf), "conv",
                           jnp.float32).mantissa_bits(jnp.float32) == 6


def test_pli_longest_prefix():
    rule = LayerInstance(mapping={"m/conv1": MantissaTrunc(3),
                                  "m": MantissaTrunc(11)})
    assert rule.select(("m", "conv1"), "conv",
                       jnp.float32).mantissa_bits(jnp.float32) == 3
    assert rule.select(("m", "conv2"), "conv",
                       jnp.float32).mantissa_bits(jnp.float32) == 11


def test_per_op_fpi():
    fpi = PerOpTrunc(bits_by_op=(("add", 8), ("mul", 24)))
    x = jnp.float32(1.2345671)
    approx_add = fpi.perform_operation("add", (x,), x)
    exact_mul = fpi.perform_operation("mul", (x,), x)
    assert float(exact_mul) == float(x)
    assert float(approx_add) != float(x)


def test_operand_trunc_fpi():
    fpi = OperandTrunc(bits=4)
    x = jnp.float32(1.23456)
    (qx,) = fpi.quantize_operands("mul", (x,))
    assert float(qx) != float(x)
    assert fpi.perform_operation("mul", (x,), x) is x


def test_genome_bridge_and_registry():
    for family in ("wp", "cip", "fcs", "plc", "pli"):
        rule = rule_from_genome(family, ["f1", "f2"], [4, 9])
        assert rule.tunable_sites()
    r = register_fp_selector("test_sel", WholeProgram(fpi=MantissaTrunc(5)))
    assert selector_registry.get("test_sel") is r
