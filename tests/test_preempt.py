"""Preemption with KV swap: byte-identical completions when requests
are forcibly swapped out mid-flight (every model family, contiguous and
paged layouts, sync-every-token and megastep schedules), preemption
inside a speculation window, priority preemption on the contiguous
path, deadline shedding, and the allocator's swap-ledger invariant."""
import jax
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import DecodeEngine, ServeConfig
from repro.serve.engine import PageAllocator, SpecConfig

# one arch per family: dense, moe, recurrent (ssm), hybrid, encdec
ARCHS = ["codeqwen1.5-7b", "granite-moe-1b-a400m", "xlstm-1.3b",
         "zamba2-7b", "seamless-m4t-medium"]

# more requests than slots: the queue stays non-empty while the first
# admitted wave runs, so the forced swap-out lands between steps and
# the victim really waits in the queue before re-admission
PROMPTS = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7]]


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64,
                                         vocab=64)
            model = build_model(cfg)
            cache[arch] = (model, model.init(jax.random.key(0)))
        return cache[arch]

    return get


def _engine(model, params, **kw):
    return DecodeEngine(model, params,
                        ServeConfig(max_len=48, batch_slots=2,
                                    engine="continuous", **kw))


# ---------------------------------------------------------------------------
# forced preemption/restore parity: every family x layout x schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("sync_every", [1, 8])
def test_forced_preemption_byte_identical(arch, sync_every, models):
    """Swapping the first admitted wave out to host (snapshot, free,
    re-queue, restore) changes no output token: both cache layouts
    reproduce the undisturbed engine's greedy completions exactly, and
    the victims report ``preempted_n`` instead of ``ok``."""
    model, params = models(arch)
    ref = _engine(model, params).generate(PROMPTS, max_new_tokens=8)
    for kv in ({}, {"page_size": 4, "kv_pages": 24}):
        eng = _engine(model, params, sync_every=sync_every,
                      force_preempt=(0, 1), **kv)
        got = eng.generate(PROMPTS, max_new_tokens=8)
        assert got == ref, f"layout {kv or 'contiguous'}"
        assert eng.stats.preemptions >= 2
        assert eng.stats.status[0].startswith("preempted_")
        assert eng.stats.status[1].startswith("preempted_")
        assert all(eng.stats.status[i] == "ok" for i in (2, 3, 4))
        if kv and model.paged_kv:
            # real pages moved through host buffers both ways
            assert eng.stats.swap_out_bytes > 0
            assert eng.stats.swap_in_bytes == eng.stats.swap_out_bytes


def test_preempt_during_spec_window(models):
    """A slot swapped out between speculation windows resumes from the
    restored cache and re-drafts — accepted-token history is carried in
    the restore payload, rejected drafts are simply never snapshotted
    (the snapshot covers ``spos`` committed rows only) — and the
    completions still match non-speculative greedy byte-for-byte."""
    model, params = models("codeqwen1.5-7b")
    ref = _engine(model, params).generate(PROMPTS, max_new_tokens=8)
    for kv in ({}, {"page_size": 4, "kv_pages": 24}):
        eng = _engine(model, params, spec=SpecConfig(k=3, drafter_bits=10),
                      force_preempt=(0, 1), **kv)
        got = eng.generate(PROMPTS, max_new_tokens=8)
        assert got == ref, f"layout {kv or 'contiguous'}"
        assert eng.stats.preemptions >= 2
        assert eng.stats.spec_windows > 0   # speculation really ran


# ---------------------------------------------------------------------------
# priority preemption (contiguous path), deadline shedding
# ---------------------------------------------------------------------------

def test_priority_preempts_contiguous(models):
    """A high-priority arrival that finds every (dense, unpaged) slot
    busy swaps out the lowest-priority most-recent slot instead of
    queueing behind it; the victim resumes later and every completion
    still matches the closed-loop reference."""
    model, params = models("codeqwen1.5-7b")
    prompts = [[5, 9, 2, 7], [1, 2], [3, 4, 5]]
    ref = _engine(model, params).generate(prompts, max_new_tokens=[40, 40, 8])
    eng = _engine(model, params)
    # the two low-priority requests admit at t=0 and run ~40 compiled
    # steps; the high-priority request arrives after the first step's
    # compile (>> 10ms) and must preempt to meet its priority
    got = eng.generate(prompts, max_new_tokens=[40, 40, 8],
                       priority=[0, 0, 2], arrival_s=[0.0, 0.0, 0.01])
    assert got == ref
    assert eng.stats.preemptions >= 1
    assert eng.stats.status[2] == "ok"
    assert all(eng.stats.status[i].split("_")[0] in ("ok", "preempted")
               for i in range(3))


def test_deadline_shed_leaves_rest_intact(models):
    """A request whose TTFT deadline expires while queued is retired
    with ``shed_deadline`` (empty completion, no exception) and every
    other request completes byte-identically; goodput counts only the
    delivered completions."""
    model, params = models("codeqwen1.5-7b")
    ref = _engine(model, params).generate(PROMPTS, max_new_tokens=6)
    eng = _engine(model, params)
    outs = eng.generate(PROMPTS + [[9, 9]],
                        max_new_tokens=6,
                        deadline_s=[None] * len(PROMPTS) + [0.0])
    assert outs[-1] == []
    assert eng.stats.status[len(PROMPTS)] == "shed_deadline"
    assert eng.stats.shed_deadline == 1
    assert outs[:len(PROMPTS)] == ref
    assert eng.stats.goodput_tokens == sum(len(o) for o in outs)


# ---------------------------------------------------------------------------
# allocator swap ledger
# ---------------------------------------------------------------------------

def test_allocator_swap_ledger_unit():
    a = PageAllocator(8)
    p = a.alloc(5)
    a.assert_invariant(5, 0)
    a.note_swap_out(3)        # 3 pages' KV gathered to host...
    a.free(p)                 # ...and the pages returned to the pool
    a.assert_invariant(0, 3)
    a.note_swap_in(3)         # restore (or shed) releases the ledger
    a.assert_invariant(0, 0)
    with pytest.raises(AssertionError):
        a.assert_invariant(1, 0)          # leaked page
    with pytest.raises(AssertionError):
        a.note_swap_in(1)                 # swap-in without a swap-out
