"""Shared test helpers."""
import pytest


def optional_hypothesis():
    """(given, settings, st) — real hypothesis when installed, otherwise
    stubs that turn each property test into a clean skip (the rest of the
    module still runs)."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        def given(*_a, **_k):
            def deco(f):
                def stub():
                    pytest.skip("hypothesis not installed")
                stub.__name__ = f.__name__
                return stub
            return deco

        def settings(*_a, **_k):
            return lambda f: f

        class _NoStrategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _NoStrategies()
