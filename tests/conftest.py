"""Shared test helpers."""
import pytest


@pytest.fixture(autouse=True)
def _debug_invariants_on():
    """Run every test with the engine's allocator/ledger invariant
    checks enabled (``ServeConfig.debug_invariants=None`` resolves to
    this module default), so page-leak and swap-ledger bugs fail the
    suite loudly instead of surfacing as silent corruption. Production
    keeps the cheap default; tests opt the whole suite in."""
    from repro.serve import engine
    prev = engine.DEBUG_INVARIANTS_DEFAULT
    engine.DEBUG_INVARIANTS_DEFAULT = True
    try:
        yield
    finally:
        engine.DEBUG_INVARIANTS_DEFAULT = prev


def optional_hypothesis():
    """(given, settings, st) — real hypothesis when installed, otherwise
    stubs that turn each property test into a clean skip (the rest of the
    module still runs)."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        def given(*_a, **_k):
            def deco(f):
                def stub():
                    pytest.skip("hypothesis not installed")
                stub.__name__ = f.__name__
                return stub
            return deco

        def settings(*_a, **_k):
            return lambda f: f

        class _NoStrategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _NoStrategies()
