"""App correctness + the paper's qualitative claims on them."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import app_registry, get_app, make_task
from repro.core import explore, profile


def test_all_f32_apps_run_and_profile():
    for name, app in app_registry.items():
        if app.target == "double" or name == "ferret":
            continue
        task = make_task(app, n_train=1, n_test=0)
        out = app.fn(*task.train_inputs[0])
        for leaf in jax.tree.leaves(out):
            assert np.all(np.isfinite(np.asarray(leaf, np.float64))), name
        prof = profile(app.fn, *task.train_inputs[0])
        assert prof.total_flops > 1000, name


def test_radar_fft_against_jnp():
    from repro.apps.radar import _fft, N
    x = jax.random.normal(jax.random.key(0), (3, N))
    fr, fi = _fft(x, jnp.zeros_like(x))
    ref = jnp.fft.fft(x)
    np.testing.assert_allclose(np.asarray(fr), np.asarray(ref.real),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fi), np.asarray(ref.imag),
                               atol=1e-3, rtol=1e-3)
    rr, ri = _fft(fr, fi, inverse=True)
    np.testing.assert_allclose(np.asarray(rr), np.asarray(x),
                               atol=1e-4, rtol=1e-4)


def test_blackscholes_put_call_parity():
    app = get_app("blackscholes")
    (spot, strike, rate, vol, t) = make_task(app, n_train=1,
                                             n_test=0).train_inputs[0]
    call, put = app.fn(spot, strike, rate, vol, t)
    lhs = np.asarray(call - put)
    rhs = np.asarray(spot - strike * jnp.exp(-rate * t))
    np.testing.assert_allclose(lhs, rhs, atol=2e-3, rtol=1e-3)


def test_kmeans_reduces_inertia():
    app = get_app("kmeans")
    (pts, init) = make_task(app, n_train=1, n_test=0).train_inputs[0]
    from repro.apps.kmeans import _distances
    _, inertia = app.fn(pts, init)
    d0 = _distances(pts, init)
    inertia0 = float(jnp.sum(jnp.min(d0, axis=-1)))
    assert float(inertia) <= inertia0


def test_particlefilter_double_precision():
    with jax.experimental.enable_x64():
        app = get_app("particlefilter")
        task = make_task(app, n_train=1, n_test=0)
        est = app.fn(*task.train_inputs[0])
        assert est.dtype == jnp.float64
        assert np.all(np.isfinite(np.asarray(est)))


def test_ferret_mixed_precision_profile():
    with jax.experimental.enable_x64():
        app = get_app("ferret")
        task = make_task(app, n_train=1, n_test=0)
        prof = profile(app.fn, *task.train_inputs[0])
        dts = prof.dtype_breakdown()
        assert "float32" in dts and "float64" in dts   # paper Fig. 4


def test_heartwall_sensitive_to_truncation():
    """Paper: heartwall's two FLOP functions are bit-width sensitive."""
    from repro.core import CurrentScope, MantissaTrunc, neat_transform
    app = get_app("heartwall")
    inp = make_task(app, n_train=1, n_test=0).train_inputs[0]
    exact = np.asarray(app.fn(*inp))
    rule = CurrentScope(mapping={"normalize": MantissaTrunc(3),
                                 "correlate": MantissaTrunc(3)})
    approx = np.asarray(neat_transform(app.fn, rule)(*inp))
    rel = np.linalg.norm(approx - exact) / np.linalg.norm(exact)
    assert rel > 0.01      # aggressive truncation visibly hurts


def test_explore_savings_positive():
    task = make_task(get_app("kmeans"), n_train=2, n_test=1)
    rep = explore(task, family="cip", n_sites=3, pop_size=10, n_gen=3,
                  max_evals=50, seed=0)
    assert rep.n_evals <= 50
    assert rep.savings(0.10) > 0.1
    assert rep.robustness_error_r > 0.5
