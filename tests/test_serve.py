"""Continuous-batching decode engine: greedy parity vs. the wave
scheduler, slot-reuse KV isolation, per-slot positions/reset, stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import DecodeEngine, ServeConfig
from repro.serve.engine import Request

# skewed: short and long prompts interleaved so waves idle and the
# continuous scheduler admits mid-flight (more requests than slots)
PROMPTS = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7],
           [8, 9, 10, 11, 12], [6] * 9, [13, 14]]


def _tiny(arch):
    cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64, vocab=64)
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


def _engine(model, params, engine, slots=2, max_len=48, **kw):
    return DecodeEngine(model, params,
                        ServeConfig(max_len=max_len, batch_slots=slots,
                                    engine=engine, **kw))


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b",   # dense transformer
                                  "xlstm-1.3b",       # recurrent (ssm)
                                  "zamba2-7b"])       # hybrid
def test_continuous_matches_wave_greedy(arch):
    """Same seed + greedy: identical per-request completions from both
    schedulers, for KV-cache and recurrent-state families alike."""
    model, params = _tiny(arch)
    wave = _engine(model, params, "wave").generate(PROMPTS,
                                                   max_new_tokens=6)
    cont = _engine(model, params, "continuous").generate(PROMPTS,
                                                         max_new_tokens=6)
    assert cont == wave
    assert all(len(o) == 6 for o in cont)


def test_continuous_fewer_steps_higher_occupancy():
    """The point of continuous batching: on a skewed workload it retires
    + refills mid-flight, so fewer compiled steps and busier slots."""
    model, params = _tiny("codeqwen1.5-7b")
    w = _engine(model, params, "wave")
    c = _engine(model, params, "continuous")
    ow = w.generate(PROMPTS, max_new_tokens=6)
    oc = c.generate(PROMPTS, max_new_tokens=6)
    assert oc == ow
    assert c.stats.steps < w.stats.steps
    assert c.stats.occupancy > w.stats.occupancy
    assert c.stats.tokens_out == w.stats.tokens_out == 6 * len(PROMPTS)


def test_slot_reuse_never_attends_to_previous_request():
    """A recycled slot's completion must equal the completion the same
    request gets from a fresh engine — any leakage of the previous
    occupant's KV entries would change the logits."""
    model, params = _tiny("codeqwen1.5-7b")
    # 1 slot forces every request after the first into a recycled slot
    eng = _engine(model, params, "continuous", slots=1)
    together = eng.generate(PROMPTS, max_new_tokens=6)
    for p, got in zip(PROMPTS, together):
        alone = _engine(model, params, "continuous",
                        slots=1).generate([p], max_new_tokens=6)[0]
        assert got == alone


def test_reset_slot_masks_poisoned_cache():
    """Poison one slot's KV cache with garbage, reset just that slot, and
    decode: logits must match a fresh cache — per-slot masking + reset
    fully isolate the recycled slot — while the untouched slot's state
    survives the reset."""
    model, params = _tiny("codeqwen1.5-7b")
    toks = jnp.asarray([[5], [9]], jnp.int32)

    fresh = model.init_cache(2, 16)
    logits_fresh, cache_fresh = model.decode_step(params, fresh, toks)

    poisoned = model.init_cache(2, 16)
    poisoned = jax.tree.map(
        lambda x: jnp.full_like(x, 37.0) if x.ndim > 1 else x, poisoned)
    mask = jnp.asarray([True, True])
    logits_reset, _ = model.decode_step(
        params, model.reset_slots(poisoned, mask), toks)
    np.testing.assert_allclose(np.asarray(logits_reset),
                               np.asarray(logits_fresh),
                               rtol=1e-5, atol=1e-5)

    # partial reset: slot 1 restarts, slot 0 keeps decoding unperturbed
    logits2_ref, _ = model.decode_step(params, cache_fresh, toks)
    part = model.reset_slots(cache_fresh, jnp.asarray([False, True]))
    logits2_got, _ = model.decode_step(params, part, toks)
    np.testing.assert_allclose(np.asarray(logits2_got[0]),
                               np.asarray(logits2_ref[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(logits2_got[1]),
                               np.asarray(logits_fresh[1]),
                               rtol=1e-5, atol=1e-5)


def test_per_slot_positions_match_lockstep():
    """Two slots at different positions decode exactly like each slot
    would alone: per-slot positions + causal masks are independent."""
    model, params = _tiny("codeqwen1.5-7b")
    seq = [5, 9, 2, 7, 11, 3]

    # slot A is 2 tokens ahead of slot B within the same batched cache
    cache = model.init_cache(2, 16)
    logits_a = logits_b = None
    for t, tok in enumerate(seq):
        cur = np.zeros((2, 1), np.int32)
        cur[0, 0] = tok
        cur[1, 0] = seq[t - 2] if t >= 2 else 0
        logits, cache = model.decode_step(params, cache,
                                          jnp.asarray(cur))
        logits_a = np.asarray(logits[0])
        if t >= 2:
            logits_b = np.asarray(logits[1])
        elif t < 2:   # slot B idles: reset it so position restarts
            cache = model.reset_slots(cache, jnp.asarray([False, True]))

    # reference: each sequence decoded alone in a single-slot cache
    def solo(tokens):
        c = model.init_cache(1, 16)
        out = None
        for tok in tokens:
            out, c = model.decode_step(
                params, c, jnp.asarray([[tok]], jnp.int32))
        return np.asarray(out[0])

    np.testing.assert_allclose(logits_a, solo(seq), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(logits_b, solo(seq[:-2]), rtol=1e-5,
                               atol=1e-5)


def test_wave_engine_unchanged_reference():
    """The wave path keeps its seed behavior: full-prompt conditioning
    and slot independence (regression tests inherited from the old
    engine)."""
    model, params = _tiny("codeqwen1.5-7b")
    eng = _engine(model, params, "wave")
    a = eng.generate([[5, 9, 2, 7]], max_new_tokens=6)[0]
    b = eng.generate([[11, 3, 2, 7]], max_new_tokens=6)[0]
    assert a != b
    c = eng.generate([[5, 9, 2, 7], [1, 2]], max_new_tokens=6)
    assert c[0] == a


def test_eos_retires_slot_early():
    """EOS retirement frees the slot for the queue in both engines and
    truncates the completion identically."""
    model, params = _tiny("codeqwen1.5-7b")
    probe = _engine(model, params, "wave").generate(PROMPTS,
                                                    max_new_tokens=6)
    eos = probe[0][2]   # a token the first request actually emits
    w = _engine(model, params, "wave", eos_token=eos)
    c = _engine(model, params, "continuous", eos_token=eos)
    ow = w.generate(PROMPTS, max_new_tokens=6)
    oc = c.generate(PROMPTS, max_new_tokens=6)
    assert oc == ow
    assert ow[0][-1] == eos and len(ow[0]) <= 3


def test_unknown_engine_rejected():
    model, params = _tiny("codeqwen1.5-7b")
    with pytest.raises(ValueError):
        _engine(model, params, "batched")


def test_sjf_admission_matches_fifo_greedy():
    """Shortest-job-first changes only the admission *order*: under greedy
    decoding every request's completion is identical to FIFO, for both
    schedulers, and outputs stay in request order."""
    model, params = _tiny("codeqwen1.5-7b")
    for engine in ("continuous", "wave"):
        fifo = _engine(model, params, engine).generate(PROMPTS,
                                                       max_new_tokens=6)
        sjf = _engine(model, params, engine,
                      admission="sjf").generate(PROMPTS, max_new_tokens=6)
        assert sjf == fifo


def test_sjf_admits_short_prompts_first():
    """SJF really reorders: the wave queue (streaming prefill, stride 1)
    comes out length-sorted (stably), and on the skewed workload the wave
    scheduler packs similar-length prompts together — strictly fewer
    compiled steps than FIFO packing (waves stop idling behind one long
    prefill)."""
    model, params = _tiny("codeqwen1.5-7b")
    eng = _engine(model, params, "wave", admission="sjf")
    q = eng._admission_order(
        [Request(i, list(p), 3) for i, p in enumerate(PROMPTS)])
    assert [len(r.tail) for r in q] == sorted(len(p) for p in PROMPTS)
    assert q[0].rid == 4                     # the single-token prompt
    assert [r.rid for r in q if len(r.tail) == 2] == [1, 7]   # stable

    fifo = _engine(model, params, "wave")
    sjf = _engine(model, params, "wave", admission="sjf")
    assert fifo.generate(PROMPTS, max_new_tokens=6) == \
        sjf.generate(PROMPTS, max_new_tokens=6)
    assert sjf.stats.steps < fifo.stats.steps


def test_sjf_key_is_post_chunking_prefill_steps():
    """The continuous engine's SJF key is the *post-chunking* remaining-
    prefill length (compiled prefill steps, ceil(len/chunk)), not the raw
    tail length: prompts whose prefill costs the same number of chunk
    steps keep arrival order, while genuinely costlier prefills still
    sort later."""
    model, params = _tiny("codeqwen1.5-7b")
    eng = _engine(model, params, "continuous", admission="sjf",
                  prefill_chunk=8)
    q = eng._admission_order(
        [Request(i, list(p), 3) for i, p in enumerate(PROMPTS)])
    steps = [-(-len(r.tail) // 8) for r in q]
    assert steps == sorted(steps)
    # every prompt but [3]*12 and [6]*9 fits one 8-token chunk: those two
    # sort last, everything else keeps arrival order (stable sort)
    assert [r.rid for r in q] == [0, 1, 3, 4, 5, 7, 2, 6]
    # with chunk 1 the key degenerates to the raw length (streaming)
    eng1 = _engine(model, params, "continuous", admission="sjf",
                   prefill_chunk=1)
    q1 = eng1._admission_order(
        [Request(i, list(p), 3) for i, p in enumerate(PROMPTS)])
    assert [len(r.tail) for r in q1] == sorted(len(p) for p in PROMPTS)


def test_per_request_budgets():
    """A per-request max_new vector caps each completion independently
    and matches the same request served alone with that budget."""
    model, params = _tiny("codeqwen1.5-7b")
    budgets = [1, 2, 3, 4, 5, 6, 2, 3]
    for engine in ("continuous", "wave"):
        eng = _engine(model, params, engine)
        outs = eng.generate(PROMPTS, max_new_tokens=budgets)
        assert [len(o) for o in outs] == budgets
        # numpy integer scalars broadcast like Python ints
        np_outs = eng.generate(PROMPTS[:2], max_new_tokens=np.int32(3))
        assert [len(o) for o in np_outs] == [3, 3]
        # budgets only truncate: prefixes of the uniform-budget outputs
        full = _engine(model, params, engine).generate(PROMPTS,
                                                       max_new_tokens=6)
        for o, f, b in zip(outs, full, budgets):
            assert o == f[:b]


def test_bad_budgets_rejected():
    model, params = _tiny("codeqwen1.5-7b")
    eng = _engine(model, params, "continuous")
    with pytest.raises(ValueError):
        eng.generate(PROMPTS, max_new_tokens=[3] * (len(PROMPTS) - 1))
    with pytest.raises(ValueError):
        eng.generate(PROMPTS, max_new_tokens=[0] * len(PROMPTS))
    with pytest.raises(ValueError):   # int broadcast validates the same
        eng.generate(PROMPTS, max_new_tokens=0)
    with pytest.raises(ValueError):
        _engine(model, params, "continuous", admission="lifo")
