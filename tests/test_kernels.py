"""Per-kernel allclose vs the ref.py oracles, shape/dtype sweeps, in
interpret mode (the kernels' TPU target is exercised structurally)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("shape", [(8, 128), (300,), (17, 130), (2, 3, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [2, 8, 16])
def test_mantissa_trunc_kernel(shape, dtype, bits):
    x = jnp.asarray(RNG.standard_normal(shape) * 10, dtype)
    got = ops.mantissa_trunc(x, bits, backend="interpret")
    want = ref.mantissa_trunc_ref(x, bits)
    assert np.array_equal(np.asarray(got, np.float64),
                          np.asarray(want, np.float64))


@pytest.mark.parametrize("mode", ["rne", "trunc"])
def test_mantissa_trunc_modes(mode):
    x = jnp.asarray(RNG.standard_normal(512), jnp.float32)
    got = ops.mantissa_trunc(x, 6, mode, backend="interpret")
    want = ref.mantissa_trunc_ref(x, 6, mode)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (100, 70, 90),
                                   (128, 256, 128), (33, 17, 65)])
def test_quant_matmul_kernel(m, k, n):
    a = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
    got = ops.quant_matmul(a, b, a_bits=8, b_bits=8, out_bits=12,
                           backend="interpret")
    want = ref.quant_matmul_ref(a, b, 8, 8, 12)
    # blocked accumulation order differs from the oracle's single dot
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-2, rtol=5e-3)


def test_quant_matmul_full_bits_is_plain_matmul():
    a = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
    got = ops.quant_matmul(a, b, backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 48])
@pytest.mark.parametrize("tq,tk", [(64, 64), (64, 128), (33, 77),
                                   (64, 200), (200, 200)])
def test_flash_attention_kernel(causal, window, tq, tk):
    if tq > tk:
        pytest.skip("queries longer than keys undefined here")
    b, hq, hkv, d = 2, 4, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, hq, tq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              backend="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("t", [64, 200])
def test_flash_attention_ragged_kv_len(causal, t):
    """Per-row valid-KV prefix mask (continuous batching's ragged slots):
    kernel == oracle, and each row == dense attention over only its own
    prefix."""
    b, hq, hkv, d = 3, 4, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, hq, t, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, t, d)), jnp.float32)
    kv_len = jnp.asarray([64, 40, 17], jnp.int32)
    if t == 200:   # non-multiple of block_k: exercises the left-pad mask
        kv_len = jnp.asarray([200, 150, 90], jnp.int32)
    got = ops.flash_attention(q, k, v, causal=causal, kv_len=kv_len,
                              backend="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=causal, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)
    # row b attending over its kv_len[b]-prefix == unmasked attention on
    # the sliced prefix (queries restricted to the same prefix)
    for row, n in enumerate(np.asarray(kv_len)):
        sl = ref.flash_attention_ref(q[row:row + 1, :, :n],
                                     k[row:row + 1, :, :n],
                                     v[row:row + 1, :, :n], causal=causal)
        np.testing.assert_allclose(np.asarray(got[row:row + 1, :, :n]),
                                   np.asarray(sl), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 16])
def test_flash_attention_q_start(dtype, window):
    """The chunked-prefill layout: a (B, H, C, D) query chunk placed at
    per-row cache positions ``q_start`` attends causally against each
    row's ``kv_len``-prefix. Kernel == oracle, and each row equals the
    right-aligned kernel path on its own prefix slice (queries = the
    prefix's last C positions) — the two mask paths are one contract."""
    b, hq, hkv, c, s, d = 3, 4, 2, 8, 70, 32
    tol = dict(atol=3e-5, rtol=1e-4) if dtype == jnp.float32 \
        else dict(atol=3e-2, rtol=3e-2)
    q = jnp.asarray(RNG.standard_normal((b, hq, c, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    q_start = jnp.asarray([0, 5, 61], jnp.int32)
    n_new = jnp.asarray([8, 8, 3], jnp.int32)     # ragged chunk tails
    kv_len = q_start + n_new
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              kv_len=kv_len, q_start=q_start,
                              backend="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   kv_len=kv_len, q_start=q_start)
    assert not np.any(np.isnan(np.asarray(got, np.float32)))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)
    # per-row semantic check against the pre-existing right-aligned path
    for row in range(b):
        n = int(n_new[row])
        hi = int(kv_len[row])
        sl = ops.flash_attention(q[row:row + 1, :, :n],
                                 k[row:row + 1, :, :hi],
                                 v[row:row + 1, :, :hi], causal=True,
                                 window=window, backend="interpret")
        np.testing.assert_allclose(np.asarray(got[row:row + 1, :, :n],
                                              np.float32),
                                   np.asarray(sl, np.float32), **tol)


def test_flash_attention_q_start_defaults_to_right_alignment():
    """q_start = tk - tq reproduces the default layout exactly, and rows
    whose mask admits no key come back as zeros (not NaN) from kernel
    and oracle alike."""
    b, h, tq, tk, d = 2, 2, 16, 64, 16
    q = jnp.asarray(RNG.standard_normal((b, h, tq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, h, tk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, h, tk, d)), jnp.float32)
    base = ops.flash_attention(q, k, v, causal=True, backend="interpret")
    qs = jnp.full((b,), tk - tq, jnp.int32)
    aligned = ops.flash_attention(q, k, v, causal=True, q_start=qs,
                                  backend="interpret")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(aligned))
    # kv_len == 0 masks every key for row 0: zeros, no NaN poisoning
    kv_len = jnp.asarray([0, tk], jnp.int32)
    got = ops.flash_attention(q, k, v, causal=True, q_start=qs,
                              kv_len=kv_len, backend="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=True, q_start=qs,
                                   kv_len=kv_len)
    assert np.all(np.asarray(got[0]) == 0) and np.all(
        np.asarray(want[0]) == 0)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               atol=3e-5, rtol=1e-4)


def test_sdpa_scan_matches_oracle():
    """The jnp scanned-flash fallback (models/attention.py::_sdpa_scan,
    the big-T training path) obeys the same contract as the kernel and
    oracle: right-aligned and q_start layouts, windows, ragged kv_len,
    fused truncation, zero rows for empty masks — including query
    lengths the q-block does NOT divide (the padded tail used to shift
    every real query's causal mask left by the pad)."""
    from repro.models.attention import _sdpa_scan
    b, hq, hkv, d = 2, 4, 2, 16
    cases = [
        (33, 77, None, None, None, 24),    # block_q does not divide tq
        (64, 64, 16, None, None, 24),
        (64, 128, None, [100, 70], None, 24),
        (8, 70, 8, [11, 40], [3, 32], 24),  # chunked-prefill layout
        (33, 77, None, None, None, 7),      # fused NEAT truncation
        (33, 77, 16, [60, 77], None, 24),   # rows with no valid key
    ]
    for tq, tk, window, kvl, qs, bits in cases:
        q = jnp.asarray(RNG.standard_normal((b, hq, tq, d)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
        kv_len = None if kvl is None else jnp.asarray(kvl, jnp.int32)
        q_start = None if qs is None else jnp.asarray(qs, jnp.int32)
        got = _sdpa_scan(q, k, v, causal=True, window=window, block_q=16,
                         kv_len=kv_len, q_start=q_start, qk_bits=bits,
                         pv_bits=bits)
        want = ref.flash_attention_ref(q, k, v, causal=True,
                                       window=window, kv_len=kv_len,
                                       q_start=q_start, qk_bits=bits,
                                       pv_bits=bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=1e-4,
                                   err_msg=f"case {(tq, tk, window)}")


def test_flash_attention_fused_truncation():
    b, hq, hkv, t, d = 1, 2, 1, 64, 16
    q = jnp.asarray(RNG.standard_normal((b, hq, t, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, t, d)), jnp.float32)
    got = ops.flash_attention(q, k, v, qk_bits=8, pv_bits=10,
                              backend="interpret")
    want = ref.flash_attention_ref(q, k, v, qk_bits=8, pv_bits=10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-3, rtol=1e-2)
    # and truncation visibly changes the result
    exact = ref.flash_attention_ref(q, k, v)
    assert not np.allclose(np.asarray(got), np.asarray(exact))


def test_bf16_flash():
    b, h, t, d = 1, 2, 64, 32
    q = jnp.asarray(RNG.standard_normal((b, h, t, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((b, h, t, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((b, h, t, d)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, backend="interpret")
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# paged flash attention: block-table pool vs the contiguous layouts
# ---------------------------------------------------------------------------

def _paged_from_contiguous(k, v, page_size, num_pages, seed=0):
    """Scatter a contiguous (B, Hkv, S, D) K/V pair into a shared pool
    under a random-but-collision-free block table."""
    b, hkv, s, d = k.shape
    max_pages = s // page_size
    prng = np.random.default_rng(seed)
    perm = prng.permutation(num_pages)[: b * max_pages]
    tbl = perm.reshape(b, max_pages)
    k_pool = np.zeros((num_pages, page_size, hkv, d), np.float32)
    v_pool = np.zeros((num_pages, page_size, hkv, d), np.float32)
    for row in range(b):
        for p in range(max_pages):
            sl = slice(p * page_size, (p + 1) * page_size)
            k_pool[tbl[row, p]] = np.asarray(k[row, :, sl]).transpose(
                1, 0, 2)
            v_pool[tbl[row, p]] = np.asarray(v[row, :, sl]).transpose(
                1, 0, 2)
    return (jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(tbl, jnp.int32))


@pytest.mark.parametrize("window", [None, 9])
@pytest.mark.parametrize("backend", ["interpret", "ref"])
def test_paged_flash_matches_contiguous(backend, window):
    """The block-table gather path is the contiguous kernel on a
    scattered pool: same q_start/kv_len mask contract, same outputs."""
    b, hq, hkv, d = 3, 4, 2, 32
    ps, mp, num_pages = 8, 4, 17
    s = mp * ps
    q = jnp.asarray(RNG.standard_normal((b, hq, 6, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    k_pool, v_pool, tbl = _paged_from_contiguous(k, v, ps, num_pages)
    kv_len = jnp.asarray([s, 17, 5], jnp.int32)
    q_start = jnp.asarray([s - 6, 11, 4], jnp.int32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   kv_len=kv_len, q_start=q_start)
    got = ops.paged_flash_attention(q, k_pool, v_pool, tbl, causal=True,
                                    window=window, kv_len=kv_len,
                                    q_start=q_start, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


def test_paged_flash_sentinel_tables_are_masked():
    """Table entries past a row's allocation may hold the sentinel (==
    num_pages): reads are clamped to a valid page and kv_len masks them,
    so outputs only ever depend on allocated pages."""
    b, hq, hkv, d = 2, 2, 1, 16
    ps, mp, num_pages = 4, 4, 9
    s = mp * ps
    q = jnp.asarray(RNG.standard_normal((b, hq, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    k_pool, v_pool, tbl = _paged_from_contiguous(k, v, ps, num_pages)
    kv_len = jnp.asarray([6, 3], jnp.int32)   # <= first two pages
    q_start = kv_len - 1
    full = ops.paged_flash_attention(q, k_pool, v_pool, tbl,
                                     kv_len=kv_len, q_start=q_start,
                                     backend="interpret")
    sent = np.asarray(tbl).copy()
    sent[:, 2:] = num_pages                   # unallocated -> sentinel
    got = ops.paged_flash_attention(q, k_pool, v_pool,
                                    jnp.asarray(sent), kv_len=kv_len,
                                    q_start=q_start, backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=3e-5, rtol=1e-4)


def test_paged_flash_packed_decode_rows():
    """The packed-prefill layout: every packed token is a batch row with
    Tq == 1, its own table, q_start = its position and kv_len = pos + 1
    — each row must equal dense attention over its slot's prefix."""
    hq, hkv, d = 4, 2, 16
    ps, mp, num_pages = 4, 3, 11
    s = mp * ps
    k = jnp.asarray(RNG.standard_normal((1, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, hkv, s, d)), jnp.float32)
    k_pool, v_pool, tbl = _paged_from_contiguous(k, v, ps, num_pages)
    n_rows = 5
    q = jnp.asarray(RNG.standard_normal((n_rows, hq, 1, d)), jnp.float32)
    qpos = jnp.asarray([0, 3, 7, 10, 11], jnp.int32)
    rows_tbl = jnp.broadcast_to(tbl, (n_rows, mp))
    got = ops.paged_flash_attention(q, k_pool, v_pool, rows_tbl,
                                    kv_len=qpos + 1, q_start=qpos,
                                    backend="interpret")
    for i, p in enumerate(np.asarray(qpos)):
        sl = ref.flash_attention_ref(q[i:i + 1], k[:, :, :p + 1],
                                     v[:, :, :p + 1], causal=True)
        np.testing.assert_allclose(np.asarray(got[i:i + 1]),
                                   np.asarray(sl), atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# multi-page KV blocks: pages_per_block sweeps, mid-block sentinels,
# block_k validation/routing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ppb", [1, 2, 4])
@pytest.mark.parametrize("ps", [8, 16, 64, 128])
def test_paged_flash_page_size_sweep(ps, ppb):
    """Every (page_size, pages_per_block) cell matches the gathered
    oracle under ragged kv_len/q_start — including table widths
    pages_per_block does NOT divide (the padded sentinel sub-pages are
    masked in logical coordinates, so the kernel's wider block_k never
    shows through)."""
    b, hq, hkv, d = 2, 2, 1, 16
    mp = 3
    s = mp * ps
    num_pages = b * mp + 2
    q = jnp.asarray(RNG.standard_normal((b, hq, 4, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    k_pool, v_pool, tbl = _paged_from_contiguous(k, v, ps, num_pages)
    kv_len = jnp.asarray([s, s // 2 + 1], jnp.int32)
    q_start = kv_len - 4
    want = ref.flash_attention_ref(q, k, v, causal=True, kv_len=kv_len,
                                   q_start=q_start)
    got = ops.paged_flash_attention(q, k_pool, v_pool, tbl, causal=True,
                                    kv_len=kv_len, q_start=q_start,
                                    pages_per_block=ppb,
                                    backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("ppb", [2, 4])
def test_paged_flash_sentinel_pages_mid_block(ppb):
    """Sentinel table entries landing in the MIDDLE of a multi-page
    block (with ppb == table width the whole row is one block) never
    leak unallocated pages into the output."""
    b, hq, hkv, d = 2, 2, 1, 16
    ps, mp, num_pages = 4, 4, 9
    s = mp * ps
    q = jnp.asarray(RNG.standard_normal((b, hq, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    k_pool, v_pool, tbl = _paged_from_contiguous(k, v, ps, num_pages)
    kv_len = jnp.asarray([6, 3], jnp.int32)   # <= first two pages
    q_start = kv_len - 1
    full = ops.paged_flash_attention(q, k_pool, v_pool, tbl,
                                     kv_len=kv_len, q_start=q_start,
                                     pages_per_block=ppb,
                                     backend="interpret")
    sent = np.asarray(tbl).copy()
    sent[:, 2:] = num_pages                   # unallocated -> sentinel
    got = ops.paged_flash_attention(q, k_pool, v_pool,
                                    jnp.asarray(sent), kv_len=kv_len,
                                    q_start=q_start, pages_per_block=ppb,
                                    backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("ppb", [2, 3])
def test_paged_flash_packed_decode_rows_multi_page(ppb):
    """Packed decode rows (Tq == 1, per-row tables) under multi-page
    blocks; ppb=2 does not divide the 3-page table, so the padded
    sentinel column is exercised on the hot decode layout."""
    hq, hkv, d = 4, 2, 16
    ps, mp, num_pages = 4, 3, 11
    s = mp * ps
    k = jnp.asarray(RNG.standard_normal((1, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, hkv, s, d)), jnp.float32)
    k_pool, v_pool, tbl = _paged_from_contiguous(k, v, ps, num_pages)
    n_rows = 5
    q = jnp.asarray(RNG.standard_normal((n_rows, hq, 1, d)), jnp.float32)
    qpos = jnp.asarray([0, 3, 7, 10, 11], jnp.int32)
    rows_tbl = jnp.broadcast_to(tbl, (n_rows, mp))
    got = ops.paged_flash_attention(q, k_pool, v_pool, rows_tbl,
                                    kv_len=qpos + 1, q_start=qpos,
                                    pages_per_block=ppb,
                                    backend="interpret")
    for i, p in enumerate(np.asarray(qpos)):
        sl = ref.flash_attention_ref(q[i:i + 1], k[:, :, :p + 1],
                                     v[:, :, :p + 1], causal=True)
        np.testing.assert_allclose(np.asarray(got[i:i + 1]),
                                   np.asarray(sl), atol=3e-5, rtol=1e-4)


def test_paged_flash_block_k_validation():
    """block_k is routed through pages_per_block, never silently
    clamped: non-multiples and conflicting explicit settings raise with
    actionable messages; a consistent block_k dispatches the multi-page
    kernel and matches the oracle."""
    b, hq, hkv, d = 1, 2, 1, 16
    ps, mp, num_pages = 8, 4, 6
    s = mp * ps
    q = jnp.asarray(RNG.standard_normal((b, hq, 2, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    k_pool, v_pool, tbl = _paged_from_contiguous(k, v, ps, num_pages)
    kv_len = jnp.asarray([s], jnp.int32)
    q_start = kv_len - 2
    with pytest.raises(ValueError, match="multiple of page_size"):
        ops.paged_flash_attention(q, k_pool, v_pool, tbl, kv_len=kv_len,
                                  q_start=q_start, block_k=12,
                                  backend="interpret")
    with pytest.raises(ValueError, match="conflicts with pages_per_block"):
        ops.paged_flash_attention(q, k_pool, v_pool, tbl, kv_len=kv_len,
                                  q_start=q_start, block_k=16,
                                  pages_per_block=4, backend="interpret")
    with pytest.raises(ValueError, match="pages_per_block"):
        ops.paged_flash_attention(q, k_pool, v_pool, tbl, kv_len=kv_len,
                                  q_start=q_start, pages_per_block=0,
                                  backend="interpret")
    got = ops.paged_flash_attention(q, k_pool, v_pool, tbl, kv_len=kv_len,
                                    q_start=q_start, block_k=16,
                                    backend="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=True, kv_len=kv_len,
                                   q_start=q_start)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused bit-census epilogues: kernel scalar == host census of the output
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["interpret", "ref"])
@pytest.mark.parametrize("bits", [24, 8])
def test_flash_attention_census_matches_host(backend, bits):
    b, hq, hkv, tq, tk, d = 2, 4, 2, 33, 77, 16
    q = jnp.asarray(RNG.standard_normal((b, hq, tq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    out, c = ops.flash_attention(q, k, v, causal=True, qk_bits=bits,
                                 pv_bits=bits, collect_census=True,
                                 backend=backend)
    assert int(c) == int(ref.bit_census_ref(out))


@pytest.mark.parametrize("ppb", [1, 2])
def test_paged_flash_census_matches_host(ppb):
    b, hq, hkv, d = 2, 2, 1, 16
    ps, mp, num_pages = 8, 3, 8
    s = mp * ps
    q = jnp.asarray(RNG.standard_normal((b, hq, 4, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    k_pool, v_pool, tbl = _paged_from_contiguous(k, v, ps, num_pages)
    kv_len = jnp.asarray([s, 13], jnp.int32)
    q_start = kv_len - 4
    out, c = ops.paged_flash_attention(q, k_pool, v_pool, tbl,
                                       kv_len=kv_len, q_start=q_start,
                                       pages_per_block=ppb,
                                       collect_census=True,
                                       backend="interpret")
    assert int(c) == int(ref.bit_census_ref(out))


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (100, 70, 90)])
def test_quant_matmul_census_matches_host(m, k, n):
    """Padded rows/cols must be masked out of the fused census — the
    (100, 70, 90) case pads every grid axis."""
    a = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
    out, c = ops.quant_matmul(a, b, a_bits=8, b_bits=8, out_bits=12,
                              collect_census=True, backend="interpret")
    assert int(c) == int(ref.bit_census_ref(out))
