"""LeNet-5 case study (paper §V-H): training on synthetic digits, PLC/PLI
placement over layers, per-layer bit recommendation path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LayerCategory, LayerInstance, MantissaTrunc,
                        neat_transform, profile, use_rule)
from repro.data.synthetic import synthetic_digits
from repro.models.lenet import (accuracy, init_lenet5, lenet5_forward,
                                lenet5_loss)


@pytest.fixture(scope="module")
def trained():
    imgs, labels = synthetic_digits(512, seed=0)
    params = init_lenet5(jax.random.key(0))

    @jax.jit
    def step(p, i, l):
        g = jax.grad(lenet5_loss)(p, i, l)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    for epoch in range(60):
        params = step(params, imgs, labels)
    return params, imgs, labels


def test_lenet_trains(trained):
    params, imgs, labels = trained
    acc = float(accuracy(params, imgs, labels))
    assert acc > 0.85, acc


def test_lenet_flop_breakdown(trained):
    """Paper Fig. 10: conv layers dominate the FLOPs."""
    params, imgs, _ = trained
    prof = profile(lenet5_forward, params, imgs[:64])
    by_leaf = {}
    for path, st in prof.scopes.items():
        leaf = path.split("/")[-1] if path else ""
        by_leaf[leaf] = by_leaf.get(leaf, 0) + st.flops
    conv = sum(v for k, v in by_leaf.items() if k.startswith("conv"))
    assert conv / prof.total_flops > 0.5


def test_lenet_plc_rule(trained):
    params, imgs, labels = trained
    base = float(accuracy(params, imgs, labels))
    rule = LayerCategory(mapping={"conv": MantissaTrunc(8),
                                  "tanh": MantissaTrunc(8),
                                  "fc": MantissaTrunc(8)})
    fn = neat_transform(lambda im: lenet5_forward(params, im), rule)
    logits = fn(imgs[:256])
    acc = float(jnp.mean((jnp.argmax(logits, -1) == labels[:256])
                         .astype(jnp.float32)))
    assert acc > base - 0.1     # 8 mantissa bits barely hurts (paper)


def test_lenet_pli_differs_from_plc(trained):
    params, imgs, _ = trained
    plc = LayerCategory(mapping={"conv": MantissaTrunc(2)})
    pli = LayerInstance(mapping={"conv1": MantissaTrunc(2)})
    f_plc = neat_transform(lambda im: lenet5_forward(params, im), plc)
    f_pli = neat_transform(lambda im: lenet5_forward(params, im), pli)
    a = np.asarray(f_plc(imgs[:32]))
    b = np.asarray(f_pli(imgs[:32]))
    assert not np.allclose(a, b)   # PLC hits all convs, PLI only conv1
