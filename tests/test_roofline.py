"""Roofline math + HLO collective-parsing units."""
import pytest

from repro.launch.roofline import (Roofline, model_flops_for,
                                   parse_collective_bytes,
                                   _split_computations)
from repro.configs import get_arch

HLO = """\
HloModule test

%body.1 (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ag = f32[8,8]{1,0} all-gather(%p), replica_groups=[4,4]<=[16], dims={0}
  ROOT %t = tuple(%i, %ag)
}

%cond.1 (arg: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (p0: f32[16,16]) -> f32[16,16] {
  %ar = f32[16,16]{1,0} all-reduce(%p0), replica_groups=[2,8]<=[16], to_apply=%add
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[16,16]{1,0} copy(%ar)
}
"""


def test_split_computations():
    comps, entry = _split_computations(HLO)
    assert entry == "main"
    assert "body.1" in comps and "cond.1" in comps


def test_collective_parse_with_loop_trips():
    out = parse_collective_bytes(HLO)
    # all-reduce: 16*16*4 bytes * 2 * (7/8) ring
    ar = 16 * 16 * 4 * 2 * (7 / 8)
    # all-gather inside while: 8*8*4 * (3/4) * 10 trips
    ag = 8 * 8 * 4 * (3 / 4) * 10
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["all-gather"] == pytest.approx(ag)


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops_per_chip=197e12, hbm_bytes_per_chip=819e9,
                 wire_bytes_per_chip=100e9, collectives={},
                 model_flops=197e12 * 256, chips=256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(2.0)
    assert r.bottleneck == "collective"
    assert r.step_s == pytest.approx(2.0)
    assert r.mfu == pytest.approx(0.5)
    assert r.useful_flop_ratio == pytest.approx(1.0)


def test_model_flops_train_vs_decode():
    cfg = get_arch("qwen2-72b")
    train = model_flops_for(cfg, "train", 4096, 256)
    decode = model_flops_for(cfg, "decode", 32768, 128)
    assert train > 1e17
    assert decode == pytest.approx(2.0 * cfg.active_param_count() * 128)


def test_moe_active_flops_used():
    cfg = get_arch("qwen3-moe-30b-a3b")
    f = model_flops_for(cfg, "train", 4096, 256)
    # 6 * N_active * D with N_active ~3B, D=1M tokens
    assert f == pytest.approx(6.0 * cfg.active_param_count() * 4096 * 256)
