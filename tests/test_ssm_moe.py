"""SSM recurrence + MoE dispatch equivalence tests (kernel-level oracles
for the model zoo's custom math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.config import ModelConfig
from repro.models.moe import (_expert_ffn_dense, _expert_ffn_ragged, _route,
                              init_moe)
from repro.models.ssm import (chunked_linear_recurrence, recurrence_step)

RNG = np.random.default_rng(0)


def _sequential_recurrence(a, k, v, q):
    b, t, h = a.shape
    n, p = k.shape[-1], v.shape[-1]
    s = np.zeros((b, h, n, p))
    ys = np.zeros((b, t, h, p))
    for i in range(t):
        s = a[:, i, :, None, None] * s + \
            k[:, i, :, :, None] * v[:, i, :, None, :]
        ys[:, i] = np.einsum("bhn,bhnp->bhp", q[:, i], s)
    return ys, s


@pytest.mark.parametrize("t,chunk", [(16, 4), (32, 8), (37, 8), (64, 64)])
def test_chunked_recurrence_matches_sequential(t, chunk):
    b, h, n, p = 2, 3, 4, 5
    a = jnp.asarray(RNG.uniform(0.7, 1.0, (b, t, h)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, t, h, n)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, t, h, p)), jnp.float32)
    q = jnp.asarray(RNG.standard_normal((b, t, h, n)), jnp.float32)
    y, s = chunked_linear_recurrence(a, k, v, q, chunk=chunk)
    y_ref, s_ref = _sequential_recurrence(np.asarray(a), np.asarray(k),
                                          np.asarray(v), np.asarray(q))
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), s_ref, atol=2e-4, rtol=1e-3)


def test_step_matches_chunked():
    b, t, h, n, p = 1, 6, 2, 3, 4
    a = jnp.asarray(RNG.uniform(0.8, 1.0, (b, t, h)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, t, h, n)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, t, h, p)), jnp.float32)
    q = jnp.asarray(RNG.standard_normal((b, t, h, n)), jnp.float32)
    y_chunk, _ = chunked_linear_recurrence(a, k, v, q, chunk=4)
    state = jnp.zeros((b, h, n, p))
    for i in range(t):
        y, state = recurrence_step(state, a[:, i], k[:, i], v[:, i], q[:, i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_chunk[:, i]),
                                   atol=2e-4, rtol=1e-3)


def _moe_cfg():
    return get_arch("granite-moe-1b-a400m").reduced(d_model=32, d_ff=16)


def test_moe_ragged_matches_dense():
    cfg = _moe_cfg()
    p = init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.standard_normal((24, cfg.d_model)), jnp.float32)
    w, idx = _route(p, x, cfg)
    y_dense = _expert_ffn_dense(p, x, cfg, w, idx)
    y_ragged = _expert_ffn_ragged(p, x, cfg, w, idx)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ragged),
                               atol=1e-4, rtol=1e-3)


def test_moe_routing_normalized():
    cfg = _moe_cfg()
    p = init_moe(jax.random.key(1), cfg)
    x = jnp.asarray(RNG.standard_normal((16, cfg.d_model)), jnp.float32)
    w, idx = _route(p, x, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(idx.max()) < cfg.n_experts


def test_moe_grad_flows_through_ragged():
    cfg = _moe_cfg()
    p = init_moe(jax.random.key(2), cfg)
    x = jnp.asarray(RNG.standard_normal((8, cfg.d_model)), jnp.float32)

    def loss(p):
        w, idx = _route(p, x, cfg)
        return jnp.sum(_expert_ffn_ragged(p, x, cfg, w, idx) ** 2)

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["gate"]))) > 0
    assert float(jnp.sum(jnp.abs(g["router"]["w"]))) > 0
