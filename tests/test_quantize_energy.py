"""Scope-mode quantization (STE gradients, rule contexts) + energy model
details."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CurrentScope, MantissaTrunc, WholeProgram,
                        census_energy, dynamic_fpu_energy, neat_quantize,
                        pscope, quantize_here, use_rule)
from repro.core.energy import _epi
from repro.core.quantize import ste_truncate


def test_ste_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(ste_truncate(x, 4) ** 2))(
        jnp.array([1.234, 2.345]))
    # d/dx sum(q(x)^2) with STE = 2*q(x)
    q = ste_truncate(jnp.array([1.234, 2.345]), 4)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(q), rtol=1e-6)


def test_quantize_here_requires_context():
    x = jnp.float32(1.2345678)
    assert float(quantize_here(x)) == float(x)     # no rule -> identity
    rule = WholeProgram(fpi=MantissaTrunc(3))
    with use_rule(rule):
        assert float(quantize_here(x)) != float(x)
    assert float(quantize_here(x)) == float(x)     # context restored


def test_quantize_here_scope_sensitive():
    rule = CurrentScope(mapping={"hot": MantissaTrunc(2)})
    x = jnp.float32(1.2345678)
    with use_rule(rule):
        with pscope("hot"):
            q_hot = float(quantize_here(x))
        with pscope("cold"):
            q_cold = float(quantize_here(x))
    assert q_hot != float(x) and q_cold == float(x)


def test_neat_quantize_bf16_mant8_identity():
    x = jnp.asarray([1.5, 2.25], jnp.bfloat16)
    out = neat_quantize(x, MantissaTrunc(8))
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(x, np.float32))


def test_epi_table_orderings():
    # paper Fig. 1: div > mul > add; 64-bit > 32-bit
    assert _epi("div", "float64") > _epi("mul", "float64") > \
        _epi("add", "float64")
    assert _epi("add", "float64") > _epi("add", "float32")


def test_census_energy_scales_with_bits():
    census = {("f/hot", "mul", "float32"): 1000,
              ("f/cold", "add", "float32"): 500}
    base = census_energy(census, None).fpu_pj
    rule = CurrentScope(mapping={"hot": MantissaTrunc(6)})
    low = census_energy(census, rule).fpu_pj
    assert low < base
    # only the hot scope scaled: delta = 1000*epi_mul*(1 - 6/24)
    expect = base - 1000 * _epi("mul", "float32") * (1 - 6 / 24)
    assert abs(low - expect) < 1e-6


def test_dynamic_energy_decreases_after_truncation():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(4096),
                    jnp.float32)
    from repro.utils.numerics import truncate_mantissa
    e_full = dynamic_fpu_energy({"s": x})
    e_trunc = dynamic_fpu_energy({"s": truncate_mantissa(x, 5)})
    assert e_trunc < 0.5 * e_full
