"""Fused decode megasteps (``ServeConfig.sync_every > 1``): byte parity
with the single-step scheduler across families × KV layouts × window
sizes, EOS mid-window, admission window-flush, census exactness, buffer
donation, host-sync accounting, and the nearest-rank percentile fix."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serve.engine import (DecodeEngine, KVConfig, ServeConfig,
                                ServeStats, SpecConfig, _percentile)

ARCHS = ["codeqwen1.5-7b",        # dense transformer
         "granite-moe-1b-a400m",  # MoE
         "xlstm-1.3b",            # recurrent (ssm)
         "zamba2-7b",             # hybrid
         "seamless-m4t-medium"]   # enc-dec

# skewed: more requests than slots so admission happens mid-flight
PROMPTS = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7],
           [8, 9, 10, 11, 12], [6] * 9, [13, 14]]


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64,
                                         vocab=64)
            model = build_model(cfg)
            cache[arch] = (model, model.init(jax.random.key(0)))
        return cache[arch]
    return get


def _gen(model, params, sync_every, page_size=0, slots=2, max_new=6,
         **kw):
    eng = DecodeEngine(model, params, ServeConfig(
        max_len=48, batch_slots=slots, prefill_chunk=8,
        sync_every=sync_every, kv=KVConfig(page_size=page_size),
        debug_invariants=True, **kw))
    outs = eng.generate(PROMPTS, max_new_tokens=max_new)
    return outs, eng.stats


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("page_size", [0, 8])
def test_megastep_byte_parity(models, arch, page_size):
    """The hard contract: byte-identical greedy completions across
    megastep boundaries, every family × contiguous/paged KV ×
    sync_every ∈ {1, 4, 16}."""
    model, params = models(arch)
    base, s1 = _gen(model, params, 1, page_size)
    for n in (4, 16):
        got, sn = _gen(model, params, n, page_size)
        assert got == base, f"{arch} ps={page_size} sync_every={n}"
        assert sn.megasteps > 0
        assert sn.steps == s1.steps        # logical steps preserved
        assert sn.host_syncs < s1.host_syncs


def test_megastep_spec_mode_stays_single_step(models):
    """Speculative windows are scheduling events: with spec on the
    engine never fuses (megasteps == 0) and output still matches the
    single-step speculative run."""
    model, params = models("codeqwen1.5-7b")
    base, _ = _gen(model, params, 1, spec=SpecConfig(k=3, drafter_bits=24))
    got, st = _gen(model, params, 8, spec=SpecConfig(k=3, drafter_bits=24))
    assert got == base
    assert st.megasteps == 0


def test_megastep_eos_mid_window(models):
    """A slot hitting EOS inside a fused window must stop exactly where
    the single-step loop stops (no tokens past EOS, EOS emitted)."""
    model, params = models("codeqwen1.5-7b")
    base, _ = _gen(model, params, 1, eos_token=7, max_new=16)
    got, st = _gen(model, params, 16, eos_token=7, max_new=16)
    assert got == base
    assert st.megasteps > 0


def test_megastep_admission_flush(models):
    """More requests than slots: a retirement inside a window must hand
    the freed slot back at the same step boundary the single-step
    scheduler admits at (flush-on-retire), keeping greedy output and
    the logical step count identical."""
    model, params = models("codeqwen1.5-7b")
    base, s1 = _gen(model, params, 1, slots=2, max_new=10)
    got, st = _gen(model, params, 16, slots=2, max_new=10)
    assert got == base
    assert st.steps == s1.steps
    assert st.megasteps > 0


def test_megastep_sampled_parity(models):
    """temperature > 0: the device loop splits the PRNG key once per
    iteration exactly like the host loop, so sampled completions are
    bit-identical too (windows only run when the queue is empty)."""
    model, params = models("codeqwen1.5-7b")
    base, _ = _gen(model, params, 1, temperature=1.0)
    got, st = _gen(model, params, 8, temperature=1.0)
    assert got == base
    assert st.megasteps > 0


@pytest.mark.parametrize("page_size", [0, 8])
def test_megastep_census_exact(models, page_size):
    """Measured census (pJ/token) must equal the single-step path — the
    loop carry threads the per-iteration bit counts exactly."""
    model, params = models("codeqwen1.5-7b")
    _, s1 = _gen(model, params, 1, page_size, estimate_energy=True)
    _, s8 = _gen(model, params, 8, page_size, estimate_energy=True)
    assert s1.phase_census == s8.phase_census
    assert s1.measured_pj == s8.measured_pj


def test_host_syncs_bounded(models):
    """host_syncs ≤ logical_steps / sync_every + scheduling events: the
    fused windows really do collapse the per-token round trips."""
    model, params = models("codeqwen1.5-7b")
    _, s1 = _gen(model, params, 1, max_new=16)
    _, sn = _gen(model, params, 16, max_new=16)
    assert s1.host_syncs == s1.steps          # one pull per step
    # schedule events: prefill steps + one flush window per retirement
    events = sn.prefill_steps + sn.n_requests
    assert sn.host_syncs <= -(-sn.steps // 16) + events
    assert sn.megasteps >= 1
    assert sn.dispatch_wait_s >= 0.0
    assert sn.host_sched_s >= 0.0
    assert len(sn.tok_lat_s) == sn.tokens_out
    assert sn.p99_tok_lat_s >= sn.p50_tok_lat_s >= 0.0


def test_cache_donated_no_per_step_copy(models):
    """Every phase jit donates the KV cache: after a step the input
    cache's buffers are deleted (XLA reused them in place) — the pool
    is never copied per dispatch."""
    model, params = models("codeqwen1.5-7b")
    eng = DecodeEngine(model, params,
                       ServeConfig(max_len=48, batch_slots=2))
    cache = model.init_cache(2, 48)
    leaves = [x for x in jax.tree.leaves(cache)
              if hasattr(x, "is_deleted")]
    toks = jnp.zeros((2, 1), jnp.int32)
    _, cache2 = eng._step(eng._phase_params["decode"], cache, toks)
    assert leaves and all(x.is_deleted() for x in leaves)
    # and the returned cache is immediately usable for the next step
    _, cache3 = eng._step(eng._phase_params["decode"], cache2, toks)
    assert jax.tree.leaves(cache3)[0].shape is not None


def test_generate_after_generate_memory_stable(models):
    """Back-to-back generates under debug_invariants: donation keeps
    the engine from accumulating live pool copies (outputs identical
    run to run, page accounting intact)."""
    model, params = models("codeqwen1.5-7b")
    eng = DecodeEngine(model, params, ServeConfig(
        max_len=48, batch_slots=2, prefill_chunk=8, sync_every=8,
        kv=KVConfig(page_size=8), debug_invariants=True))
    first = eng.generate(PROMPTS, max_new_tokens=6)
    for _ in range(2):
        assert eng.generate(PROMPTS, max_new_tokens=6) == first


def test_percentile_nearest_rank_regression():
    """The nearest-rank fix (ceil(q*n) - 1): on a known 100-sample list
    p99 is the 99th smallest (index 98) and p50 the 50th (index 49) —
    the old round(q*(n-1)) form returned index 50 for p50 (banker's
    rounding of 49.5) and biased small-sample percentiles low."""
    vals = [float(i + 1) for i in range(100)]   # 1.0 .. 100.0
    st = ServeStats(ttft_s={i: v for i, v in enumerate(vals)})
    assert st.ttft_percentile(0.99) == 99.0     # ceil(99) - 1 = idx 98
    assert st.ttft_percentile(0.50) == 50.0     # ceil(50) - 1 = idx 49
    assert st.ttft_percentile(1.00) == 100.0
    assert st.ttft_percentile(0.0) == 1.0
    # small-sample bias: p99 of 10 samples is the max, not the 9th
    assert _percentile(vals[:10], 0.99) == 10.0
    assert _percentile([], 0.5) == 0.0
    st.tok_lat_s = vals[:10]
    assert st.p99_tok_lat_s == 10.0
    assert st.p50_tok_lat_s == 5.0


def test_sync_every_validation():
    with pytest.raises(ValueError):
        ServeConfig(sync_every=0)
    with pytest.raises(ValueError):
        ServeConfig(sync_every=4, engine="wave")
    ServeConfig(sync_every=4)                   # continuous: fine
