"""Paged KV cache + packed ragged prefill: paged-vs-contiguous parity
across every model family (with mid-flight retire/readmit so pages are
really recycled), packed-prefill parity vs the (B, C) rectangle, page
allocator exhaustion/backpressure, and the SJF page-availability
tie-break."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import DecodeEngine, ServeConfig
from repro.serve.engine import KVConfig, PageAllocator, Request

# one arch per family: dense, moe, recurrent (ssm), hybrid, encdec
ARCHS = ["codeqwen1.5-7b", "granite-moe-1b-a400m", "xlstm-1.3b",
         "zamba2-7b", "seamless-m4t-medium"]

# skewed lengths straddle page (8) and chunk {1, 7, 32} boundaries
PROMPTS = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7],
           [8, 9, 10, 11, 12], [6] * 9, [13, 14]]


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64,
                                         vocab=64)
            model = build_model(cfg)
            cache[arch] = (model, model.init(jax.random.key(0)))
        return cache[arch]

    return get


def _engine(model, params, *, slots=2, max_len=48, **kw):
    return DecodeEngine(model, params,
                        ServeConfig(max_len=max_len, batch_slots=slots,
                                    engine="continuous", **kw))


def _wave(model, params, *, slots=2, max_len=48, **kw):
    return DecodeEngine(model, params,
                        ServeConfig(max_len=max_len, batch_slots=slots,
                                    engine="wave", **kw))


# ---------------------------------------------------------------------------
# paged-vs-contiguous parity, every family, pages recycled mid-flight
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_paged_engine_matches_wave_greedy(arch, models):
    """The paged continuous engine reproduces wave-engine greedy
    completions exactly. 2 slots x 8 requests forces mid-flight
    retire/readmit, and the small pool forces freed pages to be
    *recycled* by later requests — any stale-table or recycled-page
    leak would change the logits."""
    model, params = models(arch)
    wave = _wave(model, params).generate(PROMPTS, max_new_tokens=6)
    eng = _engine(model, params, prefill_chunk=7, page_size=8,
                  kv_pages=6)
    got = eng.generate(PROMPTS, max_new_tokens=6)
    assert got == wave
    assert all(len(o) == 6 for o in got)
    if model.paged_kv:
        assert eng.stats.pool_pages == 6
        assert 0 < eng.stats.peak_resident_pages <= 6


@pytest.mark.parametrize("arch", ARCHS)
def test_pages_per_block_parity_all_families(arch, models):
    """Multi-page KV blocks are a pure dispatch-shape change: serve
    completions are byte-identical across pages_per_block ∈ {1, 2, 4}
    on the paged layout, and match the contiguous layout's."""
    model, params = models(arch)
    contig = _engine(model, params, prefill_chunk=7).generate(
        PROMPTS, max_new_tokens=6)
    for ppb in (1, 2, 4):
        eng = _engine(model, params, prefill_chunk=7,
                      kv=KVConfig(page_size=8, pages_per_block=ppb))
        got = eng.generate(PROMPTS, max_new_tokens=6)
        assert got == contig, f"pages_per_block={ppb} diverged"


def test_pages_per_block_validation():
    """The serving knob rejects inconsistent geometry with actionable
    errors instead of silently clamping."""
    with pytest.raises(ValueError, match="requires the paged KV layout"):
        ServeConfig(kv=KVConfig(page_size=0, pages_per_block=2))
    with pytest.raises(ValueError, match="exceeds max_len"):
        ServeConfig(max_len=48, kv=KVConfig(page_size=16,
                                            pages_per_block=4))
    with pytest.raises(ValueError, match="pages_per_block must be >= 1"):
        ServeConfig(kv=KVConfig(page_size=8, pages_per_block=0),
                    max_len=48)


@pytest.mark.parametrize("chunk", [1, 7, 32])
@pytest.mark.parametrize("arch", ARCHS)
def test_packed_prefill_matches_rectangle(arch, chunk, models):
    """Packed (ΣC,) prefill == the PR-4 (B, C) rectangle path at every
    chunk size: same greedy completions, same prompt-token accounting."""
    model, params = models(arch)
    rect = _engine(model, params, prefill_chunk=chunk)
    packed = _engine(model, params, prefill_chunk=chunk, page_size=8)
    o_rect = rect.generate(PROMPTS, max_new_tokens=6)
    o_pack = packed.generate(PROMPTS, max_new_tokens=6)
    assert o_pack == o_rect
    assert packed.stats.prefill_tokens == rect.stats.prefill_tokens
    assert packed.stats.tokens_out == rect.stats.tokens_out


def test_packed_step_matches_rectangle_step(models):
    """One mixed step, called directly: the packed stream (decoding slot
    as a single row, prefilling slot as a ragged run, plus a padding
    row) produces the same logits and cache as the (B, C) rectangle."""
    model, params = models("codeqwen1.5-7b")
    B, max_len, ps, P = 2, 16, 4, 9
    prompts = [[5, 9, 2, 7, 11], [1, 2]]

    dense = model.init_cache(B, max_len)
    toks = np.zeros((B, 5), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    lg_rect, dense = model.prefill_chunk(
        params, dense, jnp.asarray(toks), jnp.asarray([5, 2], jnp.int32))

    paged = model.init_paged_cache(B, max_len, ps, P)
    tbl = np.full((B, max_len // ps), P, np.int32)
    tbl[0, :2] = [3, 5]
    tbl[1, :1] = [1]
    paged["block_tables"] = jnp.asarray(tbl)
    # slot-interleaved stream + one padding row (slot == B)
    stream_t = jnp.asarray([5, 1, 9, 2, 2, 7, 11, 0], jnp.int32)
    stream_s = jnp.asarray([0, 1, 0, 0, 1, 0, 0, 2], jnp.int32)
    stream_q = jnp.asarray([0, 0, 1, 2, 1, 3, 4, 0], jnp.int32)
    last = jnp.asarray([6, 4], jnp.int32)
    lg_pack, paged = model.prefill_packed(params, paged, stream_t,
                                          stream_s, stream_q, last, 8)
    np.testing.assert_array_equal(np.asarray(paged["pos"]), [5, 2])
    np.testing.assert_allclose(np.asarray(lg_pack), np.asarray(lg_rect),
                               rtol=2e-5, atol=2e-5)
    # and the caches agree through a decode step (KV really landed on
    # the right pages)
    tok = jnp.argmax(lg_rect[:, -1], -1).astype(jnp.int32)[:, None]
    ld, _ = model.decode_step(params, dense, tok)
    lp, _ = model.decode_step(params, paged, tok)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ld),
                               rtol=2e-5, atol=2e-5)


def test_recycled_page_never_leaks_previous_request(models):
    """One slot + a pool barely larger than one request: every request
    after the first runs entirely on recycled pages, and must match the
    completion it gets from a fresh engine."""
    model, params = models("codeqwen1.5-7b")
    eng = _engine(model, params, slots=1, page_size=4, kv_pages=5,
                  prefill_chunk=7)
    together = eng.generate(PROMPTS, max_new_tokens=6)
    for p, got in zip(PROMPTS, together):
        alone = _engine(model, params, slots=1, page_size=4, kv_pages=5,
                        prefill_chunk=7).generate([p], max_new_tokens=6)
        assert got == alone[0]


# ---------------------------------------------------------------------------
# allocator: exhaustion, backpressure, concurrency
# ---------------------------------------------------------------------------

def test_page_allocator_unit():
    a = PageAllocator(4)
    p1 = a.alloc(3)
    assert p1 == [0, 1, 2] and a.free_pages == 1 and a.used_pages == 3
    assert a.alloc(2) is None and a.free_pages == 1   # no partial takes
    a.free(p1)
    assert a.alloc(2) == [3, 0]                       # FIFO recycling
    assert a.free_pages == 2


def test_pool_exhaustion_sheds_capacity(models):
    """A request whose worst case cannot ever fit the pool is retired
    with a structured ``shed_capacity`` status (empty completion)
    instead of raising — and every other request in the batch still
    completes byte-identically to an unpoisoned run."""
    model, params = models("codeqwen1.5-7b")
    ref = _engine(model, params, page_size=4, kv_pages=6).generate(
        PROMPTS, max_new_tokens=6)
    eng = _engine(model, params, page_size=4, kv_pages=6)
    # tail keep=37, +10 budget => 12 pages worst case > the 6-page pool
    outs = eng.generate(PROMPTS + [[1] * 44],
                        max_new_tokens=[6] * len(PROMPTS) + [10])
    assert outs[-1] == []
    assert eng.stats.status[len(PROMPTS)] == "shed_capacity"
    assert eng.stats.shed_capacity == 1
    assert outs[:len(PROMPTS)] == ref
    for i in range(len(PROMPTS)):
        assert eng.stats.status[i].split("_")[0] in ("ok", "preempted")


def test_backpressure_blocks_admission_not_correctness(models):
    """A pool far smaller than slots x max_len serves the same greedy
    completions — admission simply waits for pages (more steps), and
    resident pages never exceed the pool."""
    model, params = models("codeqwen1.5-7b")
    ref = _engine(model, params, slots=4).generate(PROMPTS,
                                                   max_new_tokens=6)
    tight = _engine(model, params, slots=4, page_size=4, kv_pages=6)
    got = tight.generate(PROMPTS, max_new_tokens=6)
    assert got == ref
    assert tight.stats.peak_resident_pages <= 6
    roomy = _engine(model, params, slots=4, page_size=4)
    roomy.generate(PROMPTS, max_new_tokens=6)
    assert tight.stats.steps > roomy.stats.steps   # waiting costs steps


def test_fixed_pool_doubles_concurrency(models):
    """At fixed KV memory the paged engine admits >= 2x the contiguous
    layout's slot count: 4 slots x 48 tokens == 48 pages x 4 tokens,
    but short requests reserve only what they need."""
    model, params = models("codeqwen1.5-7b")
    prompts = [[(3 * i + j) % 60 for j in range(4 if i % 4 else 20)]
               for i in range(16)]
    dense = _engine(model, params, slots=4)
    ref = dense.generate(prompts, max_new_tokens=5)
    paged = _engine(model, params, slots=16, page_size=4, kv_pages=48)
    got = paged.generate(prompts, max_new_tokens=5)
    assert got == ref
    assert paged.stats.peak_active_requests >= 8   # 2x the 4-slot cap
    assert paged.stats.steps < dense.stats.steps


# ---------------------------------------------------------------------------
# SJF page-availability tie-break
# ---------------------------------------------------------------------------

def test_sjf_tie_break_orders_by_pages_needed(models):
    """Equal prefill-step keys order by KV-page demand: a short-prompt
    request with a huge completion budget (cheap to prefill, expensive
    to hold) sorts after an equally-cheap request that needs fewer
    pages; arrival order breaks remaining ties (stable sort)."""
    model, params = models("codeqwen1.5-7b")
    eng = _engine(model, params, admission="sjf", prefill_chunk=8,
                  page_size=8, max_len=64)
    queue = [Request(0, [1] * 4, 40),   # 1 step, ceil(44/8) = 6 pages
             Request(1, [2] * 5, 4),    # 1 step, ceil(9/8)  = 2 pages
             Request(2, [3] * 3, 4),    # 1 step, ceil(7/8)  = 1 page
             Request(3, [4] * 2, 4)]    # 1 step, ceil(6/8)  = 1 page
    order = [r.rid for r in eng._admission_order(queue)]
    assert order == [2, 3, 1, 0]
    # without paging the tie-break vanishes: pure arrival order
    plain = _engine(model, params, admission="sjf", prefill_chunk=8)
    assert [r.rid for r in plain._admission_order(queue)] == [0, 1, 2, 3]


def test_blocked_head_is_bypassed_by_cheaper_request(models):
    """Bounded bypass: when the queue head cannot get its page
    reservation, a later request needing strictly fewer pages is
    admitted instead of convoying — and completions still match the
    contiguous engine (greedy outputs are admission-order
    independent)."""
    model, params = models("codeqwen1.5-7b")
    # R (need 2) runs; A (need 3) blocks on the 2 free pages; B (need 2)
    # bypasses A. pool = 4 pages of 4 tokens.
    reqs = [[1] * 4, [2] * 8, [3] * 3]
    budgets = [4, 4, 4]
    ref = _engine(model, params).generate(reqs, max_new_tokens=budgets)
    eng = _engine(model, params, page_size=4, kv_pages=4)
    got = eng.generate(reqs, max_new_tokens=budgets)
    assert got == ref
    # B (rid 2) really went first: its first token landed before A's
    assert eng.stats.ttft_s[2] < eng.stats.ttft_s[1]
