"""Particlefilter (Rodinia): 2-D object tracking, double precision
(paper sets the double optimization target here; Table II: 53^10).

Scopes: propagate, likelihood, normalize, estimate. Resampling uses
integer indices (not intercepted). Requires x64 — run the exploration
under ``jax.experimental.enable_x64``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.registry import App, app_registry
from repro.core.scope import pscope

T = 8      # time steps
P = 512    # particles


def _propagate(parts, noise):
    with pscope("propagate"):
        return parts + 0.8 * noise + 0.15


def _likelihood(parts, obs):
    with pscope("likelihood"):
        d2 = jnp.sum((parts - obs[None, :]) ** 2, axis=-1)
        return jnp.exp(-0.5 * d2)


def _normalize(w):
    with pscope("normalize"):
        return w / jnp.sum(w)


def _estimate(parts, w):
    with pscope("estimate"):
        return jnp.sum(parts * w[:, None], axis=0)


def particle_filter(init_parts, noises, observations):
    """init_parts: (P,2) f64; noises: (T,P,2); observations: (T,2)."""
    parts = init_parts
    est = []
    for t in range(T):
        parts = _propagate(parts, noises[t])
        w = _likelihood(parts, observations[t])
        w = _normalize(w)
        est.append(_estimate(parts, w))
        # systematic resampling (integer gather, not intercepted)
        cum = jnp.cumsum(w)
        u = (jnp.arange(P) + 0.5) / P
        idx = jnp.searchsorted(cum, u)
        parts = parts[jnp.clip(idx, 0, P - 1)]
    return jnp.stack(est)


def make_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    init = jax.random.normal(k1, (P, 2), jnp.float64)
    noises = jax.random.normal(k2, (T, P, 2), jnp.float64) * 0.3
    truth = jnp.cumsum(jnp.full((T, 2), 0.95, jnp.float64), axis=0)
    obs = truth + jax.random.normal(k3, (T, 2), jnp.float64) * 0.2
    return (init, noises, obs)


app_registry.register("particlefilter", App(
    name="particlefilter", fn=particle_filter, make_inputs=make_inputs,
    target="double"))
