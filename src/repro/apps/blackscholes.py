"""Blackscholes (Parsec): closed-form European option pricing.

Paper Table II: 4 FLOP functions -> config space 24^4. Scopes: cndf,
d_terms, call_price, put_price.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.registry import App, app_registry
from repro.core.scope import pscope

INV_SQRT2 = 0.7071067811865476


def _cndf(x):
    with pscope("cndf"):
        return 0.5 * (1.0 + jax.lax.erf(x * INV_SQRT2))


def _d_terms(spot, strike, rate, vol, t):
    with pscope("d_terms"):
        sig_sqrt = vol * jnp.sqrt(t)
        d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * t) / sig_sqrt
        d2 = d1 - sig_sqrt
        return d1, d2


def price(spot, strike, rate, vol, t):
    d1, d2 = _d_terms(spot, strike, rate, vol, t)
    disc = jnp.exp(-rate * t)
    with pscope("call_price"):
        call = spot * _cndf(d1) - strike * disc * _cndf(d2)
    with pscope("put_price"):
        put = strike * disc * _cndf(-d2) - spot * _cndf(-d1)
    return call, put


def make_inputs(key, n: int = 4096):
    ks = jax.random.split(key, 5)
    spot = jax.random.uniform(ks[0], (n,), jnp.float32, 10.0, 200.0)
    strike = jax.random.uniform(ks[1], (n,), jnp.float32, 10.0, 200.0)
    rate = jax.random.uniform(ks[2], (n,), jnp.float32, 0.005, 0.1)
    vol = jax.random.uniform(ks[3], (n,), jnp.float32, 0.05, 0.9)
    t = jax.random.uniform(ks[4], (n,), jnp.float32, 0.1, 3.0)
    return (spot, strike, rate, vol, t)


app_registry.register("blackscholes", App(
    name="blackscholes", fn=price, make_inputs=make_inputs))
