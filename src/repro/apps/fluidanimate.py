"""Fluidanimate (Parsec): SPH fluid step — density estimation, pressure +
viscosity forces, symplectic integration. Scopes: density, forces,
integrate. Memory-intensive FLOP functions (the paper's Fig. 7 shows
fluidanimate saving >60% memory energy)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.registry import App, app_registry
from repro.core.scope import pscope

NPART = 256
H = 0.6          # smoothing radius
STEPS = 3
DT = 0.01


def _density(pos):
    with pscope("density"):
        diff = pos[:, None, :] - pos[None, :, :]
        r2 = jnp.sum(diff * diff, axis=-1)
        w = jnp.maximum(H * H - r2, 0.0)
        return jnp.sum(w * w * w, axis=-1)        # poly6 kernel (unnorm.)


def _forces(pos, vel, rho):
    with pscope("forces"):
        diff = pos[:, None, :] - pos[None, :, :]
        r2 = jnp.sum(diff * diff, axis=-1)
        r = jnp.sqrt(jnp.maximum(r2, 1e-12))
        near = (r < H) & (r > 1e-6)
        press = 0.5 * (rho[:, None] + rho[None, :]) - 1.0   # stiffness=1
        spiky = jnp.where(near, (H - r) ** 2 / r, 0.0)
        f_press = -jnp.sum((press * spiky)[..., None] * diff, axis=1)
        dvel = vel[None, :, :] - vel[:, None, :]
        visc = jnp.where(near, H - r, 0.0)
        f_visc = 0.1 * jnp.sum(visc[..., None] * dvel, axis=1)
        grav = jnp.array([0.0, -9.8, 0.0])
        return f_press + f_visc + grav[None, :]


def _integrate(pos, vel, force, rho):
    with pscope("integrate"):
        acc = force / jnp.maximum(rho, 1e-6)[:, None]
        vel = vel + acc * DT
        pos = pos + vel * DT
        # box walls with damping
        vel = jnp.where((pos < 0.0) | (pos > 4.0), -0.5 * vel, vel)
        pos = jnp.clip(pos, 0.0, 4.0)
        return pos, vel


def fluid(pos, vel):
    for _ in range(STEPS):
        rho = _density(pos)
        f = _forces(pos, vel, rho)
        pos, vel = _integrate(pos, vel, f, rho)
    return pos, vel


def make_inputs(key):
    k1, k2 = jax.random.split(key)
    pos = jax.random.uniform(k1, (NPART, 3), jnp.float32, 0.5, 3.5)
    vel = jax.random.normal(k2, (NPART, 3), jnp.float32) * 0.1
    return (pos, vel)


app_registry.register("fluidanimate", App(
    name="fluidanimate", fn=fluid, make_inputs=make_inputs))
