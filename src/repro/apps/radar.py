"""Radar (paper Fig. 3/9): embedded ground-moving-target pipeline with a
low-pass filter (LPF) and pulse compression (PC), BOTH calling one shared
FFT routine — the paper's motivating example for FCS placement: under CIP
the FFT gets one FPI everywhere; under FCS the LPF's FFT and the PC's FFT
can differ.

The FFT is a real split-complex radix-2 implementation so every butterfly
is visible float arithmetic (interceptable FLOPs, exactly like the
compiled C binary Pin instruments).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.apps.registry import App, app_registry
from repro.core.scope import pscope

N = 256    # pulse length (power of two)
PULSES = 8


def _fft(re, im, inverse: bool = False):
    """Iterative radix-2 DIT FFT over the last axis (split complex)."""
    with pscope("fft"):
        n = re.shape[-1]
        bits = int(math.log2(n))
        # bit-reversal permutation (static integer gather)
        idx = jnp.arange(n)
        rev = jnp.zeros_like(idx)
        for b in range(bits):
            rev = rev | (((idx >> b) & 1) << (bits - 1 - b))
        re = jnp.take(re, rev, axis=-1)
        im = jnp.take(im, rev, axis=-1)
        sign = 1.0 if inverse else -1.0
        for s in range(1, bits + 1):
            m = 1 << s
            half = m // 2
            k = jnp.arange(n) % m
            ang = sign * 2.0 * math.pi * (k % half) / m
            wr = jnp.cos(ang).astype(re.dtype)
            wi = jnp.sin(ang).astype(re.dtype)
            is_hi = (k >= half)
            partner = jnp.where(is_hi, jnp.arange(n) - half,
                                jnp.arange(n) + half)
            pr = jnp.take(re, partner, axis=-1)
            pi = jnp.take(im, partner, axis=-1)
            # hi lanes hold the twiddled term
            tr = jnp.where(is_hi, re * wr - im * wi, pr * wr - pi * wi)
            ti = jnp.where(is_hi, re * wi + im * wr, pr * wi + pi * wr)
            re = jnp.where(is_hi, pr - tr, re + tr)
            im = jnp.where(is_hi, pi - ti, im + ti)
        if inverse:
            re = re / n
            im = im / n
        return re, im


def _lpf(re, im, response):
    """Low-pass filter: FFT -> multiply response -> IFFT."""
    with pscope("lpf"):
        fr, fi = _fft(re, im)
        fr = fr * response
        fi = fi * response
        return _fft(fr, fi, inverse=True)


def _pulse_compress(re, im, chirp_re, chirp_im):
    """Matched filter: FFT -> multiply conj(chirp spectrum) -> IFFT."""
    with pscope("pc"):
        fr, fi = _fft(re, im)
        cr, ci = _fft(chirp_re, chirp_im)
        mr = fr * cr + fi * ci           # x * conj(c)
        mi = fi * cr - fr * ci
        return _fft(mr, mi, inverse=True)


def radar(re, im, response, chirp_re, chirp_im):
    """re/im: (PULSES, N) echo pulses."""
    lr, li = _lpf(re, im, response)
    pr, pi = _pulse_compress(lr, li, chirp_re, chirp_im)
    with pscope("detect"):
        power = pr * pr + pi * pi
        return power


def make_inputs(key):
    ks = jax.random.split(key, 3)
    t = jnp.arange(N, dtype=jnp.float32) / N
    # linear chirp
    chirp_re = jnp.cos(2 * math.pi * (20 * t + 40 * t * t))
    chirp_im = jnp.sin(2 * math.pi * (20 * t + 40 * t * t))
    delay = jax.random.randint(ks[0], (PULSES,), 10, N // 2)
    amp = jax.random.uniform(ks[1], (PULSES, 1), jnp.float32, 0.5, 2.0)
    base = jnp.stack([jnp.roll(chirp_re, int(d)) for d in delay])
    re = amp * base + jax.random.normal(ks[2], (PULSES, N)) * 0.1
    im = jnp.zeros_like(re)
    freq = jnp.fft.fftfreq(N)
    response = (jnp.abs(freq) < 0.25).astype(jnp.float32)
    return (re, im, response,
            jnp.broadcast_to(chirp_re, (PULSES, N)),
            jnp.broadcast_to(chirp_im, (PULSES, N)))


app_registry.register("radar", App(
    name="radar", fn=radar, make_inputs=make_inputs))
