"""Heartwall (Rodinia): template tracking by normalized cross-correlation.
The paper notes heartwall has only two FLOP functions and both are very
bit-width sensitive (NEAT cannot push FPU energy below 71% at sane error)
— the normalization division amplifies truncation error. Scopes:
correlate, normalize."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.registry import App, app_registry
from repro.core.scope import pscope

IMG = 48
TPL = 9


def _correlate(image, template):
    with pscope("correlate"):
        out = jax.lax.conv_general_dilated(
            image[None, :, :, None], template[:, :, None, None],
            window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))[0, :, :, 0]
        return out


def _normalize(corr, image, template):
    with pscope("normalize"):
        ones = jnp.ones((TPL, TPL, 1, 1), image.dtype)
        local_sum = jax.lax.conv_general_dilated(
            image[None, :, :, None], ones, (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))[0, :, :, 0]
        local_sq = jax.lax.conv_general_dilated(
            (image * image)[None, :, :, None], ones, (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))[0, :, :, 0]
        n = TPL * TPL
        t_mean = jnp.mean(template)
        t_var = jnp.sum((template - t_mean) ** 2)
        num = corr - local_sum * t_mean
        den = jnp.sqrt(jnp.maximum(
            (local_sq - local_sum * local_sum / n) * t_var, 1e-8))
        return num / den


def heartwall(image, template):
    corr = _correlate(image, template)
    ncc = _normalize(corr, image, template)
    return ncc


def make_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    template = jax.random.normal(k1, (TPL, TPL), jnp.float32)
    image = jax.random.normal(k2, (IMG, IMG), jnp.float32) * 0.3
    r, c = jax.random.randint(k3, (2,), 5, IMG - TPL - 5)
    image = jax.lax.dynamic_update_slice(
        image, template + image[r:r + TPL, c:c + TPL] * 0.0, (r, c))
    return (image, template)


app_registry.register("heartwall", App(
    name="heartwall", fn=heartwall, make_inputs=make_inputs))
