"""Kmeans (Rodinia): Lloyd iterations on synthetic clusters.

Scopes: distance (the FLOP-dominant function), update, inertia.
Assignment (argmin) is integer — not intercepted, as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.registry import App, app_registry
from repro.core.scope import pscope

K = 8
ITERS = 6


def _distances(points, centroids):
    with pscope("distance"):
        diff = points[:, None, :] - centroids[None, :, :]
        return jnp.sum(diff * diff, axis=-1)


def _update(points, assign):
    with pscope("update"):
        onehot = jax.nn.one_hot(assign, K, dtype=points.dtype)
        sums = onehot.T @ points
        counts = jnp.maximum(onehot.sum(0)[:, None], 1.0)
        return sums / counts


def kmeans(points, centroids):
    for _ in range(ITERS):
        d = _distances(points, centroids)
        assign = jnp.argmin(d, axis=-1)
        centroids = _update(points, assign)
    with pscope("inertia"):
        d = _distances(points, centroids)
        inertia = jnp.sum(jnp.min(d, axis=-1))
    return centroids, inertia


def make_inputs(key, n: int = 2048, dim: int = 8):
    k1, k2, k3 = jax.random.split(key, 3)
    true_c = jax.random.normal(k1, (K, dim), jnp.float32) * 4.0
    label = jax.random.randint(k2, (n,), 0, K)
    pts = true_c[label] + jax.random.normal(k3, (n, dim), jnp.float32)
    init = true_c + 0.5   # deterministic perturbed init
    return (pts, init)


app_registry.register("kmeans", App(
    name="kmeans", fn=kmeans, make_inputs=make_inputs))
