"""Ferret (Parsec): content-based image similarity — feature extraction in
*single* precision, ranking distances in *double* (the paper's Fig. 4
shows ferret carrying an even float/double mix; Fig. 8 studies which
optimization target pays more). Requires x64 for the double half.

Scopes: features (f32), project (f32), rank (f64).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.registry import App, app_registry
from repro.core.scope import pscope

NIMG = 24
DIMG = 64
DFEAT = 32


def _features(images, proj):
    with pscope("features"):
        f = jnp.tanh(images @ proj)        # f32 extraction
        return f / (1e-6 + jnp.linalg.norm(f, axis=-1, keepdims=True))


def _rank(feats, query):
    with pscope("rank"):
        f64 = feats.astype(jnp.float64)
        q64 = query.astype(jnp.float64)
        d = jnp.sum((f64 - q64[None, :]) ** 2, axis=-1)
        scores = jnp.exp(-d)
        return scores / jnp.sum(scores)


def ferret(images, proj, query_image):
    feats = _features(images, proj)
    q = _features(query_image[None, :], proj)[0]
    return _rank(feats, q)


def make_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    images = jax.random.normal(k1, (NIMG, DIMG), jnp.float32)
    proj = jax.random.normal(k2, (DIMG, DFEAT), jnp.float32) / 8.0
    query = images[0] + jax.random.normal(k3, (DIMG,), jnp.float32) * 0.1
    return (images, proj, query)


app_registry.register("ferret", App(
    name="ferret", fn=ferret, make_inputs=make_inputs))
