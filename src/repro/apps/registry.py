from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax

from repro.core.explorer import ExplorationTask, default_error_fn
from repro.utils.registry import Registry


@dataclasses.dataclass
class App:
    name: str
    fn: Callable                        # pure: (*inputs) -> outputs
    make_inputs: Callable               # (key) -> input tuple
    error_fn: Callable = default_error_fn
    target: str = "single"              # paper's optimization target
    n_train: int = 5                    # paper: multiple train/test inputs
    n_test: int = 5


app_registry: Registry[App] = Registry("app")


def get_app(name: str) -> App:
    return app_registry.get(name)


def make_task(app: App, *, seed: int = 0, n_train: Optional[int] = None,
              n_test: Optional[int] = None) -> ExplorationTask:
    key = jax.random.key(seed)
    nt = n_train if n_train is not None else app.n_train
    nv = n_test if n_test is not None else app.n_test
    keys = jax.random.split(key, nt + nv)
    train = [app.make_inputs(k) for k in keys[:nt]]
    test = [app.make_inputs(k) for k in keys[nt:]]
    return ExplorationTask(name=app.name, fn=app.fn, train_inputs=train,
                           test_inputs=test, error_fn=app.error_fn,
                           target=app.target)
