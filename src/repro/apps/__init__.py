"""JAX ports of the paper's benchmark suite (Parsec/Rodinia analogues).

Each app is a pure numerical JAX program with ``pscope``-annotated
functions — the exact structure NEAT instruments: blackscholes (finance),
kmeans (clustering), particlefilter (tracking, double precision), radar
(LPF + pulse compression sharing one FFT — the FCS showcase),
fluidanimate (SPH), heartwall (template correlation, accuracy-critical),
ferret (mixed float/double — the optimization-target study), and the
LeNet-5 CNN case study.
"""
from repro.apps.registry import App, app_registry, get_app, make_task
from repro.apps import (  # noqa: F401  (importing registers)
    blackscholes, kmeans, particlefilter, radar, fluidanimate, heartwall,
    ferret,
)
