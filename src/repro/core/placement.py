"""Programmable placement rules, paper §III-B4 + Table I.

A rule maps *program state* — here the scope/call stack, the op class and
the dtype — to the FPI used for that FLOP. The paper ships WP, CIP and FCS;
for CNNs it adds PLC (per layer category) and PLI (per layer instance).
Rules compose; users can subclass ``PlacementRule`` with arbitrary logic
(paper: "Sets of rules are specified as C++ routines that accept the
program state as input and return a single FPI").
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.fpi import FpImplementation, IDENTITY, MantissaTrunc
from repro.utils.registry import Registry

selector_registry: Registry["PlacementRule"] = Registry("fp_selector")


def _is_target_dtype(dtype, target: str) -> bool:
    d = jnp.dtype(dtype)
    if target == "single":
        return d == jnp.dtype(jnp.float32)
    if target == "double":
        return d == jnp.dtype(jnp.float64)
    if target == "half":
        return d in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16))
    if target == "any":
        return jnp.issubdtype(d, jnp.floating)
    raise ValueError(f"unknown optimization target {target!r}")


@dataclasses.dataclass
class PlacementRule:
    """Base rule: identity everywhere.

    ``target`` is the paper's FP optimization target (§IV step 2): only
    FLOPs of the targeted precision are replaced.
    """
    target: str = "single"

    def select(self, stack: Tuple[str, ...], op_class: str,
               dtype) -> FpImplementation:
        if not _is_target_dtype(dtype, self.target):
            return IDENTITY
        return self._select(stack, op_class)

    def _select(self, stack: Tuple[str, ...], op_class: str) -> FpImplementation:
        return IDENTITY

    # names this rule can assign distinct FPIs to (genome layout for search)
    def tunable_sites(self) -> Tuple[str, ...]:
        return ()


@dataclasses.dataclass
class WholeProgram(PlacementRule):
    """WP: one FPI for every FLOP in the program (tradeoff space 24/53)."""
    fpi: FpImplementation = IDENTITY

    def _select(self, stack, op_class):
        return self.fpi

    def tunable_sites(self):
        return ("__program__",)


@dataclasses.dataclass
class CurrentScope(PlacementRule):
    """CIP: FPI keyed by the currently-in-progress function = the innermost
    scope frame. Unmapped scopes use ``default``."""
    mapping: Dict[str, FpImplementation] = dataclasses.field(default_factory=dict)
    default: FpImplementation = IDENTITY

    def _select(self, stack, op_class):
        if stack and stack[-1] in self.mapping:
            return self.mapping[stack[-1]]
        return self.default

    def tunable_sites(self):
        return tuple(self.mapping)


@dataclasses.dataclass
class CallStack(PlacementRule):
    """FCS: walk the call stack from the most recent frame outward; the
    first frame present in the mapping selects the FPI (paper Fig. 3: the
    FFT inherits the FPI of its caller — LPF vs PC)."""
    mapping: Dict[str, FpImplementation] = dataclasses.field(default_factory=dict)
    default: FpImplementation = IDENTITY

    def _select(self, stack, op_class):
        for frame in reversed(stack):
            if frame in self.mapping:
                return self.mapping[frame]
        return self.default

    def tunable_sites(self):
        return tuple(self.mapping)


def default_categorizer(stack: Tuple[str, ...]) -> str:
    """Layer category = innermost frame with instance digits stripped
    ("conv1" -> "conv", "layer03.attn" -> "layer.attn")."""
    if not stack:
        return ""
    return re.sub(r"\d+", "", stack[-1])


@dataclasses.dataclass
class LayerCategory(PlacementRule):
    """PLC: one FPI per layer *category* (all conv layers share one FPI)."""
    mapping: Dict[str, FpImplementation] = dataclasses.field(default_factory=dict)
    default: FpImplementation = IDENTITY
    categorize: Callable[[Tuple[str, ...]], str] = default_categorizer

    def _select(self, stack, op_class):
        return self.mapping.get(self.categorize(stack), self.default)

    def tunable_sites(self):
        return tuple(self.mapping)


@dataclasses.dataclass
class LayerInstance(PlacementRule):
    """PLI: one FPI per layer *instance*, keyed by the full scope path
    (longest-prefix match, so "model/conv1" covers everything beneath)."""
    mapping: Dict[str, FpImplementation] = dataclasses.field(default_factory=dict)
    default: FpImplementation = IDENTITY

    def _select(self, stack, op_class):
        path = "/".join(stack)
        best, best_len = None, -1
        for key, fpi in self.mapping.items():
            if (path == key or path.startswith(key + "/")
                    or ("/" not in key and key in stack)):
                if len(key) > best_len:
                    best, best_len = fpi, len(key)
        return best if best is not None else self.default

    def tunable_sites(self):
        return tuple(self.mapping)


# ---------------------------------------------------------------------------
# Genome <-> rule bridging for the NSGA-II explorer.
# ---------------------------------------------------------------------------

RULE_FAMILIES = ("wp", "cip", "fcs", "plc", "pli")


def site_index_for_stack(family: str, site_idx: Dict[str, int],
                         stack: Tuple[str, ...]) -> Optional[int]:
    """Resolve a scope stack to its genome site index under `family`.

    This is the single source of truth for genome-indexed placement: the
    dynamic-bits interpreter uses it to pick which entry of the traced
    bits vector governs a FLOP, and the tensorized energy model uses it
    to assign each profiled scope its coefficient column — keeping the
    two views of "which site owns this FLOP" identical by construction.
    Mirrors the per-family ``PlacementRule`` matching (CIP innermost
    frame, FCS outward stack walk, PLC category, PLI longest prefix);
    ``"__default__"`` (CIP/FCS) catches unmatched stacks. Returns None
    when no site applies (identity / full precision).
    """
    if family == "wp":
        return 0
    default_idx = site_idx.get("__default__")
    if family == "cip":
        if stack and stack[-1] in site_idx:
            return site_idx[stack[-1]]
        return default_idx
    if family == "fcs":
        for frame in reversed(stack):
            if frame in site_idx:
                return site_idx[frame]
        return default_idx
    if family == "plc":
        return site_idx.get(default_categorizer(stack))
    if family == "pli":
        path = "/".join(stack)
        best, best_len = None, -1
        for key, i in site_idx.items():
            if (path == key or path.startswith(key + "/")
                    or ("/" not in key and key in stack)):
                if len(key) > best_len:
                    best, best_len = i, len(key)
        return best
    raise ValueError(f"unknown rule family {family!r}")


def rule_from_genome(family: str, sites: Sequence[str], bits: Sequence[int],
                     *, target: str = "single", mode: str = "rne",
                     default: FpImplementation = IDENTITY) -> PlacementRule:
    """Build a placement rule from an integer genome of mantissa widths.

    WP uses a single gene; the per-function/per-layer families map
    ``sites[i] -> MantissaTrunc(bits[i])``. A ``"__default__"`` site sets
    the rule's default FPI (applied to unmatched FLOPs).
    """
    if family == "wp":
        return WholeProgram(target=target, fpi=MantissaTrunc(int(bits[0]), mode))
    pairs = dict(zip(sites, bits))
    if "__default__" in pairs:
        default = MantissaTrunc(int(pairs.pop("__default__")), mode)
    mapping = {s: MantissaTrunc(int(b), mode) for s, b in pairs.items()}
    if family == "cip":
        return CurrentScope(target=target, mapping=mapping, default=default)
    if family == "fcs":
        return CallStack(target=target, mapping=mapping, default=default)
    if family == "plc":
        return LayerCategory(target=target, mapping=mapping, default=default)
    if family == "pli":
        return LayerInstance(target=target, mapping=mapping, default=default)
    raise ValueError(f"unknown rule family {family!r}")


def register_fp_selector(name: str, rule: PlacementRule) -> PlacementRule:
    """Paper §IV step 4: Register_FP_selector. Registered rules are
    addressable by name (the paper's --fp_selector_name flag; our launch
    scripts expose the same flag)."""
    selector_registry.register(name, rule)
    return rule
