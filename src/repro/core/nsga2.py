"""NSGA-II (Deb et al. [18]) — the paper's exploration engine (§IV step 5).

From-scratch implementation specialized to integer genomes (per-site
mantissa widths). Both objectives are minimized: (energy, error). The
evaluation budget matches the paper: at most ~400 configurations per
experiment.

The engine is an **ask/tell** class (``NSGA2``): ``ask()`` returns a
deduplicated batch of not-yet-evaluated genomes (the whole initial
population, then each generation's offspring), ``tell()`` ingests their
objective vectors. This lets callers evaluate a full population in one
device-parallel call (see ``core/explorer.py``). The module-level
``nsga2()`` keeps the original serial-callback signature as a thin
wrapper and is draw-for-draw identical to the historical implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

Genome = Tuple[int, ...]


@dataclasses.dataclass
class Evaluated:
    genome: Genome
    objectives: Tuple[float, ...]   # (energy, error), minimized


@dataclasses.dataclass
class NSGA2Result:
    population: List[Evaluated]          # final population
    evaluated: List[Evaluated]           # every unique config evaluated
    n_evals: int

    def front(self) -> List[Evaluated]:
        return pareto_front(self.evaluated)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def pareto_front(points: List[Evaluated]) -> List[Evaluated]:
    front: List[Evaluated] = []
    for p in points:
        if not any(dominates(q.objectives, p.objectives)
                   for q in points if q is not p):
            if not any(q.objectives == p.objectives for q in front):
                front.append(p)
    return sorted(front, key=lambda e: e.objectives)


def fast_non_dominated_sort(objs: np.ndarray) -> List[np.ndarray]:
    """Return index arrays per front, best first. objs: (n, m)."""
    n = objs.shape[0]
    S: List[List[int]] = [[] for _ in range(n)]
    counts = np.zeros(n, dtype=np.int64)
    fronts: List[List[int]] = [[]]
    for p in range(n):
        for q in range(n):
            if p == q:
                continue
            if dominates(objs[p], objs[q]):
                S[p].append(q)
            elif dominates(objs[q], objs[p]):
                counts[p] += 1
        if counts[p] == 0:
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt: List[int] = []
        for p in fronts[i]:
            for q in S[p]:
                counts[q] -= 1
                if counts[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return [np.array(f, dtype=np.int64) for f in fronts if len(f)]


def crowding_distance(objs: np.ndarray) -> np.ndarray:
    n, m = objs.shape
    dist = np.zeros(n)
    if n <= 2:
        return np.full(n, np.inf)
    for k in range(m):
        order = np.argsort(objs[:, k])
        dist[order[0]] = dist[order[-1]] = np.inf
        span = objs[order[-1], k] - objs[order[0], k]
        if span <= 0:
            continue
        dist[order[1:-1]] += (objs[order[2:], k] - objs[order[:-2], k]) / span
    return dist


def _tournament(rng, ranks, crowd):
    i, j = rng.integers(0, len(ranks), size=2)
    if ranks[i] != ranks[j]:
        return i if ranks[i] < ranks[j] else j
    return i if crowd[i] >= crowd[j] else j


class NSGA2:
    """Ask/tell NSGA-II over integer genomes in ``[low, high]^n_genes``.

    Protocol::

        opt = NSGA2(n_genes=4, low=1, high=24, pop_size=16)
        while not opt.done:
            batch = opt.ask()                 # deduplicated, within budget
            opt.tell(batch, [f(g) for g in batch])
        result = opt.result()

    ``ask()`` returns only genomes that have not been evaluated yet
    (memoization) and never more than the remaining ``max_evals`` budget,
    so the budget counts *unique* configurations, as in the paper's "at
    most 400 configurations ... evaluated". Genomes dropped on the budget
    floor are ranked with an ``inf`` sentinel, matching the historical
    serial implementation draw-for-draw: ``nsga2(f, ...)`` and an ask/tell
    drive with the same seed evaluate the identical genome sequence.
    """

    def __init__(self, n_genes: int, low: int, high: int, *,
                 pop_size: int = 40, n_gen: int = 9, max_evals: int = 400,
                 p_crossover: float = 0.9, p_mutate: float | None = None,
                 seed: int = 0, seed_genomes: Sequence[Sequence[int]] = ()):
        self.n_genes = n_genes
        self.low = low
        self.high = high
        self.pop_size = pop_size
        self.n_gen = n_gen
        self.max_evals = max_evals
        self.p_crossover = p_crossover
        self.p_mut = (p_mutate if p_mutate is not None
                      else 1.0 / max(n_genes, 1))
        self.rng = np.random.default_rng(seed)
        self.seed_genomes = [tuple(int(v) for v in s) for s in seed_genomes]
        self.cache: Dict[Genome, Tuple[float, ...]] = {}
        self.order: List[Evaluated] = []
        self._final_pop: List[Genome] = []
        self._driver: Iterator[Tuple[Genome, ...]] = self._evolve()
        self._pending: Optional[Tuple[Genome, ...]] = None
        self._advance()

    # -- public protocol -----------------------------------------------------
    @property
    def done(self) -> bool:
        return self._pending is None

    def ask(self) -> List[Genome]:
        """The current batch of genomes awaiting evaluation (deduplicated,
        truncated to the remaining budget). Idempotent until ``tell``."""
        return list(self._pending) if self._pending is not None else []

    def tell(self, genomes: Sequence[Sequence[int]],
             objectives: Sequence[Sequence[float]]) -> None:
        """Ingest objective vectors for the genomes handed out by ``ask``."""
        if self._pending is None:
            raise RuntimeError("tell() called on a finished NSGA2 run")
        if len(genomes) != len(objectives):
            raise ValueError(
                f"{len(genomes)} genomes but {len(objectives)} objectives")
        got: Dict[Genome, Tuple[float, ...]] = {}
        for g, obj in zip(genomes, objectives):
            got[tuple(int(v) for v in g)] = tuple(float(v) for v in obj)
        missing = [g for g in self._pending if g not in got]
        unknown = [g for g in got if g not in self._pending]
        if missing or unknown:
            raise ValueError(
                f"tell() batch mismatch: missing {missing[:3]}, "
                f"unknown {unknown[:3]}")
        # record in ask-order so `evaluated` stays deterministic
        for g in self._pending:
            self.cache[g] = got[g]
            self.order.append(Evaluated(g, got[g]))
        self._advance()

    def result(self) -> NSGA2Result:
        if not self.done:
            raise RuntimeError("result() before the run finished; "
                               "drive ask()/tell() until .done")
        final = [Evaluated(g, self.cache[g])
                 for g in self._final_pop if g in self.cache]
        return NSGA2Result(population=final, evaluated=list(self.order),
                           n_evals=len(self.cache))

    # -- internals -----------------------------------------------------------
    def _advance(self) -> None:
        try:
            self._pending = next(self._driver)
        except StopIteration:
            self._pending = None

    def _request(self, genomes: Sequence[Genome]):
        """Yield (once) the deduplicated uncached slice of `genomes` that
        fits the remaining budget."""
        seen: set = set()
        batch: List[Genome] = []
        budget = self.max_evals - len(self.cache)
        for g in genomes:
            if g not in self.cache and g not in seen:
                seen.add(g)
                if len(batch) < budget:
                    batch.append(g)
        if batch:
            yield tuple(batch)

    def _obj(self, g: Genome) -> Tuple[float, ...]:
        if g in self.cache:
            return self.cache[g]
        # over-budget sentinel: dominated by everything
        if self.order:
            return tuple(float("inf") for _ in self.order[0].objectives)
        return (float("inf"), float("inf"))

    def _evolve(self) -> Iterator[Tuple[Genome, ...]]:
        rng = self.rng
        # init population: seeds + full-precision + random
        pop: List[Genome] = list(self.seed_genomes)
        pop.append(tuple([self.high] * self.n_genes))    # exact baseline
        while len(pop) < self.pop_size:
            pop.append(tuple(int(v) for v in
                             rng.integers(self.low, self.high + 1,
                                          self.n_genes)))
        pop = pop[:self.pop_size]
        yield from self._request(pop)
        objs = np.array([self._obj(g) for g in pop])

        for _ in range(self.n_gen):
            if len(self.cache) >= self.max_evals:
                break
            fronts = fast_non_dominated_sort(objs)
            ranks = np.zeros(len(pop), dtype=np.int64)
            crowd = np.zeros(len(pop))
            for r, f in enumerate(fronts):
                ranks[f] = r
                crowd[f] = crowding_distance(objs[f])
            children: List[Genome] = []
            while len(children) < self.pop_size:
                a = pop[_tournament(rng, ranks, crowd)]
                b = pop[_tournament(rng, ranks, crowd)]
                if rng.random() < self.p_crossover:
                    mask = rng.random(self.n_genes) < 0.5
                    child = tuple(int(x if m else y)
                                  for x, y, m in zip(a, b, mask))
                else:
                    child = a
                child = tuple(
                    int(rng.integers(self.low, self.high + 1))
                    if rng.random() < self.p_mut else v
                    for v in child)
                children.append(child)
            yield from self._request(children)
            union = pop + children
            union_objs = np.array([self._obj(g) for g in union])
            # environmental selection
            fronts = fast_non_dominated_sort(union_objs)
            new_idx: List[int] = []
            for f in fronts:
                if len(new_idx) + len(f) <= self.pop_size:
                    new_idx.extend(f.tolist())
                else:
                    cd = crowding_distance(union_objs[f])
                    keep = f[np.argsort(-cd)][: self.pop_size - len(new_idx)]
                    new_idx.extend(keep.tolist())
                    break
            pop = [union[i] for i in new_idx]
            objs = union_objs[new_idx]

        self._final_pop = pop


def nsga2(
    eval_fn: Callable[[Genome], Tuple[float, ...]],
    n_genes: int,
    low: int,
    high: int,
    *,
    pop_size: int = 40,
    n_gen: int = 9,
    max_evals: int = 400,
    p_crossover: float = 0.9,
    p_mutate: float | None = None,
    seed: int = 0,
    seed_genomes: Sequence[Sequence[int]] = (),
) -> NSGA2Result:
    """Run NSGA-II over integer genomes in [low, high]^n_genes.

    Thin serial wrapper over the ask/tell :class:`NSGA2` engine.
    ``eval_fn`` maps a genome to the objective tuple (minimized); it is
    called exactly once per unique configuration, in the same order as the
    historical serial implementation.
    """
    opt = NSGA2(n_genes, low, high, pop_size=pop_size, n_gen=n_gen,
                max_evals=max_evals, p_crossover=p_crossover,
                p_mutate=p_mutate, seed=seed, seed_genomes=seed_genomes)
    while not opt.done:
        batch = opt.ask()
        opt.tell(batch, [eval_fn(g) for g in batch])
    return opt.result()
