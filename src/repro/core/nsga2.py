"""NSGA-II (Deb et al. [18]) — the paper's exploration engine (§IV step 5).

From-scratch implementation specialized to integer genomes (per-site
mantissa widths). Both objectives are minimized: (energy, error). The
evaluation budget matches the paper: at most ~400 configurations per
experiment.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Evaluated:
    genome: Tuple[int, ...]
    objectives: Tuple[float, ...]   # (energy, error), minimized


@dataclasses.dataclass
class NSGA2Result:
    population: List[Evaluated]          # final population
    evaluated: List[Evaluated]           # every unique config evaluated
    n_evals: int

    def front(self) -> List[Evaluated]:
        return pareto_front(self.evaluated)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def pareto_front(points: List[Evaluated]) -> List[Evaluated]:
    front: List[Evaluated] = []
    for p in points:
        if not any(dominates(q.objectives, p.objectives)
                   for q in points if q is not p):
            if not any(q.objectives == p.objectives for q in front):
                front.append(p)
    return sorted(front, key=lambda e: e.objectives)


def fast_non_dominated_sort(objs: np.ndarray) -> List[np.ndarray]:
    """Return index arrays per front, best first. objs: (n, m)."""
    n = objs.shape[0]
    S: List[List[int]] = [[] for _ in range(n)]
    counts = np.zeros(n, dtype=np.int64)
    fronts: List[List[int]] = [[]]
    for p in range(n):
        for q in range(n):
            if p == q:
                continue
            if dominates(objs[p], objs[q]):
                S[p].append(q)
            elif dominates(objs[q], objs[p]):
                counts[p] += 1
        if counts[p] == 0:
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt: List[int] = []
        for p in fronts[i]:
            for q in S[p]:
                counts[q] -= 1
                if counts[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return [np.array(f, dtype=np.int64) for f in fronts if len(f)]


def crowding_distance(objs: np.ndarray) -> np.ndarray:
    n, m = objs.shape
    dist = np.zeros(n)
    if n <= 2:
        return np.full(n, np.inf)
    for k in range(m):
        order = np.argsort(objs[:, k])
        dist[order[0]] = dist[order[-1]] = np.inf
        span = objs[order[-1], k] - objs[order[0], k]
        if span <= 0:
            continue
        dist[order[1:-1]] += (objs[order[2:], k] - objs[order[:-2], k]) / span
    return dist


def _tournament(rng, ranks, crowd):
    i, j = rng.integers(0, len(ranks), size=2)
    if ranks[i] != ranks[j]:
        return i if ranks[i] < ranks[j] else j
    return i if crowd[i] >= crowd[j] else j


def nsga2(
    eval_fn: Callable[[Tuple[int, ...]], Tuple[float, ...]],
    n_genes: int,
    low: int,
    high: int,
    *,
    pop_size: int = 40,
    n_gen: int = 9,
    max_evals: int = 400,
    p_crossover: float = 0.9,
    p_mutate: float | None = None,
    seed: int = 0,
    seed_genomes: Sequence[Sequence[int]] = (),
) -> NSGA2Result:
    """Run NSGA-II over integer genomes in [low, high]^n_genes.

    ``eval_fn`` maps a genome to the objective tuple (minimized). Results
    are memoized so the ``max_evals`` budget counts unique configurations,
    as in the paper's "at most 400 configurations ... evaluated".
    """
    rng = np.random.default_rng(seed)
    p_mut = p_mutate if p_mutate is not None else 1.0 / max(n_genes, 1)
    cache: Dict[Tuple[int, ...], Tuple[float, ...]] = {}
    order: List[Evaluated] = []

    def evaluate(g: Tuple[int, ...]) -> Tuple[float, ...]:
        if g not in cache:
            if len(cache) >= max_evals:
                # budget exhausted: return a dominated sentinel
                return tuple(float("inf") for _ in order[0].objectives) \
                    if order else (float("inf"), float("inf"))
            cache[g] = tuple(float(v) for v in eval_fn(g))
            order.append(Evaluated(g, cache[g]))
        return cache[g]

    # init population: seeds + full-precision + random
    pop: List[Tuple[int, ...]] = [tuple(int(v) for v in s) for s in seed_genomes]
    pop.append(tuple([high] * n_genes))                 # exact baseline
    while len(pop) < pop_size:
        pop.append(tuple(int(v) for v in rng.integers(low, high + 1, n_genes)))
    pop = pop[:pop_size]
    objs = np.array([evaluate(g) for g in pop])

    for _ in range(n_gen):
        if len(cache) >= max_evals:
            break
        fronts = fast_non_dominated_sort(objs)
        ranks = np.zeros(len(pop), dtype=np.int64)
        crowd = np.zeros(len(pop))
        for r, f in enumerate(fronts):
            ranks[f] = r
            crowd[f] = crowding_distance(objs[f])
        children: List[Tuple[int, ...]] = []
        while len(children) < pop_size:
            a = pop[_tournament(rng, ranks, crowd)]
            b = pop[_tournament(rng, ranks, crowd)]
            if rng.random() < p_crossover:
                mask = rng.random(n_genes) < 0.5
                child = tuple(int(x if m else y)
                              for x, y, m in zip(a, b, mask))
            else:
                child = a
            child = tuple(
                int(rng.integers(low, high + 1)) if rng.random() < p_mut else v
                for v in child)
            children.append(child)
        union = pop + children
        union_objs = np.array([evaluate(g) for g in union])
        # environmental selection
        fronts = fast_non_dominated_sort(union_objs)
        new_idx: List[int] = []
        for f in fronts:
            if len(new_idx) + len(f) <= pop_size:
                new_idx.extend(f.tolist())
            else:
                cd = crowding_distance(union_objs[f])
                keep = f[np.argsort(-cd)][: pop_size - len(new_idx)]
                new_idx.extend(keep.tolist())
                break
        pop = [union[i] for i in new_idx]
        objs = union_objs[new_idx]

    final = [Evaluated(g, cache[g]) for g in pop if g in cache]
    return NSGA2Result(population=final, evaluated=order, n_evals=len(cache))
