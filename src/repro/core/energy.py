"""The NEAT energy model, paper §III-C ("Outputs") + Fig. 1.

Two estimators, matching the paper:

* **FPU energy** — per-FLOP energy-per-instruction (EPI) from McKeown et
  al. [54] / Fig. 1, scaled by the number of *manipulated mantissa bits*
  (trailing-zero counting on the truncated representation). With mantissa
  truncation to `b` bits the manipulated-bit count is upper-bounded by `b`,
  so the static estimator (flops-per-scope x EPI(bits)) is exact for the
  FPI family the paper evaluates; the dynamic estimator counts bits of the
  actual values (used for the small apps, where some values need fewer
  bits than the FPI grants).
* **Memory energy** — bits moved x 1.5 nJ/byte (Borkar [8]); reduced
  mantissa reduces the bits transmitted per element.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.fpi import FpImplementation, IDENTITY
from repro.core.placement import (PlacementRule, _is_target_dtype,
                                  site_index_for_stack)
from repro.core.profiler import Profile
from repro.utils.numerics import bits_for_storage, float_spec, manipulated_bits

# Energy per instruction, picojoules — Fig. 1 (64-bit 32 nm core, [54]).
# mul values are interpolated within Fig. 1's add..div band (documented
# estimate; the paper prints the plot, not the table).
EPI_PJ: Dict[Tuple[str, str], float] = {
    ("add", "float64"): 400.0, ("sub", "float64"): 400.0,
    ("mul", "float64"): 500.0, ("div", "float64"): 680.0,
    ("add", "float32"): 350.0, ("sub", "float32"): 350.0,
    ("mul", "float32"): 400.0, ("div", "float32"): 420.0,
    # TPU-relevant reduced widths (linear-in-width extrapolation)
    ("add", "bfloat16"): 175.0, ("sub", "bfloat16"): 175.0,
    ("mul", "bfloat16"): 200.0, ("div", "bfloat16"): 210.0,
    ("add", "float16"): 175.0, ("sub", "float16"): 175.0,
    ("mul", "float16"): 200.0, ("div", "float16"): 210.0,
}
# dot/conv are streams of mul+add pairs; transcendental ~ TRANSCENDENTAL_COST
# adds. Resolved in _epi().
MEM_PJ_PER_BYTE = 1500.0   # 1.5 nJ/byte read [8]


def _epi(op_class: str, dtype: str) -> float:
    if op_class in ("dot", "conv"):
        return 0.5 * (EPI_PJ.get(("mul", dtype), 400.0)
                      + EPI_PJ.get(("add", dtype), 350.0))
    if op_class == "transcendental":
        return EPI_PJ.get(("add", dtype), 350.0)
    return EPI_PJ.get((op_class, dtype), 400.0)


@dataclasses.dataclass
class EnergyReport:
    fpu_pj: float
    mem_pj: float

    @property
    def total_pj(self) -> float:
        return self.fpu_pj + self.mem_pj

    def normalized(self, baseline: "EnergyReport") -> "EnergyReport":
        return EnergyReport(
            fpu_pj=self.fpu_pj / max(baseline.fpu_pj, 1e-30),
            mem_pj=self.mem_pj / max(baseline.mem_pj, 1e-30))


def _full_bits(dtype: str) -> int:
    return float_spec(jnp.dtype(dtype)).mantissa_bits


def static_energy(prof: Profile, rule: Optional[PlacementRule] = None) -> EnergyReport:
    """Static estimator: FLOP census x EPI scaled by the FPI's mantissa
    width per scope; memory bits scaled by stored-bit reduction."""
    fpu = 0.0
    mem = 0.0
    for path, st in prof.scopes.items():
        stack = tuple(path.split("/")) if path else ()
        for op_class, flops in st.by_op.items():
            for dtype, _ in st.by_dtype.items():
                # apportion op flops across dtypes by dtype share
                share = st.by_dtype[dtype] / max(st.flops, 1)
                n = flops * share
                fpi = (rule.select(stack, op_class, jnp.dtype(dtype))
                       if rule is not None else IDENTITY)
                bits = fpi.mantissa_bits(jnp.dtype(dtype))
                full = _full_bits(dtype)
                fpu += n * _epi(op_class, dtype) * (bits / full)
        # memory: scale moved bytes by the scope's storage-bit reduction
        # (weighted over dtypes present in the scope)
        scale = 0.0
        wsum = 0.0
        for dtype, f in st.by_dtype.items():
            fpi = (rule.select(stack, "mul", jnp.dtype(dtype))
                   if rule is not None else IDENTITY)
            bits = fpi.mantissa_bits(jnp.dtype(dtype))
            spec = float_spec(jnp.dtype(dtype))
            scale += f * (bits_for_storage(bits, jnp.dtype(dtype))
                          / spec.total_bits)
            wsum += f
        scale = scale / wsum if wsum else 1.0
        mem += st.bytes * scale * MEM_PJ_PER_BYTE
    return EnergyReport(fpu_pj=fpu, mem_pj=mem)


# ---------------------------------------------------------------------------
# Tensorized population energy (the batched explorer's estimator).
#
# For a genome-indexed MantissaTrunc rule every static_energy term is
# affine in the *clamped* site width min(b_site, full_dtype):
#
#   FPU:  n * EPI(op, dtype) * min(b, full) / full
#   MEM:  bytes * share * (1 + exp + min(b, full) - 1) / total      (b >= 1)
#
# so the whole profile collapses into one constant plus an (n_sites,
# n_widths) coefficient matrix per estimator, and a population's energy is
# a single einsum over the genome matrix.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EnergyCoeffs:
    """Per-(site, full-width) energy coefficients precomputed from a
    :class:`Profile` for one placement family + site list.

    ``fulls`` enumerates the distinct full mantissa widths among the
    profiled target dtypes (usually just ``[24]``); the clamp
    ``min(bits, full)`` reproduces ``MantissaTrunc.mantissa_bits``.
    Assumes genome bits >= 1 (the explorer's search floor).
    """
    sites: Tuple[str, ...]
    fulls: np.ndarray        # (D,) distinct full mantissa widths
    fpu_lin: np.ndarray      # (S, D) pJ per clamped mantissa bit
    fpu_const: float         # pJ from FLOPs no site governs
    mem_lin: np.ndarray      # (S, D)
    mem_const: float

    def baseline(self) -> EnergyReport:
        """Identity-rule energy (== static_energy(prof, None))."""
        full = np.broadcast_to(self.fulls, self.fpu_lin.shape)
        return EnergyReport(
            fpu_pj=self.fpu_const + float(np.sum(self.fpu_lin * full)),
            mem_pj=self.mem_const + float(np.sum(self.mem_lin * full)))


def energy_coeffs(prof: Profile, family: str, sites: Sequence[str], *,
                  target: str = "single",
                  op_classes: Optional[frozenset] = None,
                  epi_fn=None,
                  mem_pj_per_byte: float = MEM_PJ_PER_BYTE) -> EnergyCoeffs:
    """Build the coefficient tensor: one pass over the profile census,
    amortized across every genome the search will ever evaluate.

    ``op_classes`` restricts the FPU terms to the given op classes (an
    FPU-only residual view — memory terms stay zero); the dynamic
    estimator uses it to keep the static genome-scaled charge for
    governed FLOPs the interpreter does not intercept (transcendentals
    unless ``include_transcendental``). ``epi_fn`` / ``mem_pj_per_byte``
    swap the per-FLOP and per-byte charges (default: the paper's EPI
    table and Borkar's 1.5 nJ/byte) — the measured-power estimator
    substitutes roofline execution time x device TDP."""
    epi_of = epi_fn or _epi
    site_idx = {s: i for i, s in enumerate(sites)}
    n_sites = len(sites)
    fulls = sorted({_full_bits(dt) for st in prof.scopes.values()
                    for dt in st.by_dtype
                    if _is_target_dtype(jnp.dtype(dt), target)}) or [24]
    d_idx = {f: i for i, f in enumerate(fulls)}
    fpu_lin = np.zeros((n_sites, len(fulls)))
    mem_lin = np.zeros((n_sites, len(fulls)))
    fpu_const = 0.0
    mem_const = 0.0

    for path, st in prof.scopes.items():
        stack = tuple(path.split("/")) if path else ()
        s_i = site_index_for_stack(family, site_idx, stack)
        for op_class, flops in st.by_op.items():
            if op_classes is not None and op_class not in op_classes:
                continue
            for dtype in st.by_dtype:
                share = st.by_dtype[dtype] / max(st.flops, 1)
                n = flops * share
                epi = epi_of(op_class, dtype)
                full = _full_bits(dtype)
                if s_i is not None and _is_target_dtype(jnp.dtype(dtype),
                                                        target):
                    fpu_lin[s_i, d_idx[full]] += n * epi / full
                else:
                    fpu_const += n * epi
        if op_classes is not None:   # FPU-only residual view
            continue
        wsum = sum(st.by_dtype.values())
        if not wsum:
            mem_const += st.bytes * mem_pj_per_byte
            continue
        for dtype, f in st.by_dtype.items():
            spec = float_spec(jnp.dtype(dtype))
            amount = st.bytes * (f / wsum) * mem_pj_per_byte
            if s_i is not None and _is_target_dtype(jnp.dtype(dtype), target):
                # bits_for_storage(min(b, full)) == exp + min(b, full), b >= 1
                mem_lin[s_i, d_idx[spec.mantissa_bits]] += \
                    amount / spec.total_bits
                mem_const += amount * spec.exp_bits / spec.total_bits
            else:
                # identity storage is the full element: factor 1
                mem_const += amount
    return EnergyCoeffs(sites=tuple(sites), fulls=np.asarray(fulls, float),
                        fpu_lin=fpu_lin, fpu_const=fpu_const,
                        mem_lin=mem_lin, mem_const=mem_const)


def population_energy(coeffs: EnergyCoeffs,
                      bits_matrix) -> Tuple[np.ndarray, np.ndarray]:
    """(fpu_pj, mem_pj) for a whole population at once.

    ``bits_matrix``: (P, n_sites) integer genome matrix. Equals the scalar
    path ``static_energy(prof, rule_from_genome(...))`` row by row (to
    float round-off); validated in tests/test_population.py.
    """
    bits = np.atleast_2d(np.asarray(bits_matrix, np.float64))
    if bits.shape[1] != len(coeffs.sites):
        raise ValueError(f"bits_matrix has {bits.shape[1]} genes; "
                         f"coeffs expect {len(coeffs.sites)}")
    clamped = np.minimum(bits[:, :, None], coeffs.fulls[None, None, :])
    fpu = coeffs.fpu_const + np.einsum("psd,sd->p", clamped, coeffs.fpu_lin)
    mem = coeffs.mem_const + np.einsum("psd,sd->p", clamped, coeffs.mem_lin)
    return fpu, mem


def census_energy(census: Mapping[Tuple[str, str, str], int],
                  rule: Optional[PlacementRule] = None) -> EnergyReport:
    """Energy from an interpreter census {(path, op, dtype): flops}."""
    fpu = 0.0
    for (path, op_class, dtype), flops in census.items():
        stack = tuple(path.split("/")) if path else ()
        fpi = (rule.select(stack, op_class, jnp.dtype(dtype))
               if rule is not None else IDENTITY)
        bits = fpi.mantissa_bits(jnp.dtype(dtype))
        fpu += flops * _epi(op_class, dtype) * (bits / _full_bits(dtype))
    return EnergyReport(fpu_pj=fpu, mem_pj=0.0)


def dynamic_fpu_energy(values, op_class: str = "mul") -> float:
    """Paper-faithful dynamic estimator: count manipulated mantissa bits of
    concrete values (trailing-zero counting, §III-C) and charge
    EPI x bits/full per scalar FLOP.

    Two input forms:

    * ``Mapping[str, tensor]`` (scope path -> tensor): the historical
      per-tensor form — every element counts as one FLOP of ``op_class``.
    * an iterable of census records (``interpreter.BitsRecord`` /
      ``capture_bit_census`` output): each record carries its own op
      class, dtype, pre-summed bit count and scalar-FLOPs-per-element
      weight. This is the host-side reference the device-resident
      dynamic estimator is validated against (f64 reduction of exact
      integer counts).
    """
    if isinstance(values, Mapping):
        total = 0.0
        for path, x in values.items():
            if not jnp.issubdtype(x.dtype, jnp.floating):
                continue
            bits = manipulated_bits(x)
            full = float_spec(x.dtype).mantissa_bits
            dtype = str(jnp.dtype(x.dtype))
            total += float(jnp.sum(bits) / full) * _epi(op_class, dtype)
        return total
    total = 0.0
    for rec in values:
        total += (_epi(rec.op_class, rec.dtype) * rec.weight
                  * float(rec.count) / _full_bits(rec.dtype))
    return total
