"""The NEAT jaxpr interpreter — the Pin-tool analogue (paper-faithful mode).

``neat_transform(fn, rule)`` returns a function computing ``fn`` with every
intercepted floating-point primitive replaced by the FPI the placement rule
assigns, given the equation's *name stack* (recorded by ``pscope`` /
``jax.named_scope`` at trace time). This reproduces Pin's per-FLOP dynamic
replacement: CIP consults the innermost frame, FCS walks the stack outward
— exactly the paper's semantics, at jaxpr granularity.

Higher-order primitives (scan/while/cond/pjit/custom_jvp/...) are handled
by re-emitting them with interpreted bodies, so the transform composes with
``jax.jit`` and control flow.

**Bit-census accumulators** (the dynamic energy estimator's input): with
``collect_bits=True`` the interpreter also emits, per intercepted
genome-governed op, one exact int32 counter — the manipulated-mantissa-bit
census of the quantized result (``kernels.bit_census``, the fused Pallas
reduction on TPU). Each counter's static metadata (site index, op class,
dtype, scalar-FLOPs-per-element weight) is a :class:`BitChannel`; the
traced counters ride the evaluator's existing dispatch as one extra
``(n_channels,)`` output, vmapped per genome like everything else. Scan
bodies thread their per-iteration counts out through the scan's stacked
outputs and fold them (sum over iterations == the profiler's
``length``-multiplied census); while bodies thread one accumulator per
channel through the **loop carry**, so data-dependent trip counts are
measured too (note the static model charges whiles at the profiler's
one-iteration estimate, so a multi-trip loop's measured energy may
legitimately exceed its static charge). Cond branches are **measured by
branch**: every branch's channels join the union suffix and the
``lax.switch`` selects the taken branch's exact counts (zeros for the
others), replacing the old static largest-branch bound — so, like
whiles, a taken branch bigger than the static model's
most-equations branch can legitimately exceed its static charge. Only
while *cond* bodies keep the static genome-scaled bound
``numel * min(b, full)`` (their sole product is the loop predicate —
no value census can thread out).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.extend import core as jcore

try:  # DropVar has no jax.extend home yet
    from jax._src.core import DropVar as _DropVar
except ImportError:  # pragma: no cover
    class _DropVar:  # fallback: nothing matches
        pass

from repro.core.fpi import FpImplementation
from repro.core.placement import PlacementRule
from repro.core.scope import parse_name_stack

# jax primitive name -> NEAT op class (paper: SSE ADDSS/SUBSS/MULSS/DIVSS +
# their fp64 twins; dot/conv represent the same scalar madd streams a C
# binary would execute — see DESIGN.md "changed assumptions").
PRIM_OP_CLASS: Dict[str, str] = {
    "add": "add",
    "add_any": "add",
    "sub": "sub",
    "mul": "mul",
    "div": "div",
    "dot_general": "dot",
    "conv_general_dilated": "conv",
}

TRANSCENDENTALS = {
    "exp", "log", "tanh", "logistic", "sqrt", "rsqrt", "pow", "integer_pow",
    "erf", "sin", "cos", "log1p", "expm1", "cbrt", "atan2",
}

DEFAULT_INTERCEPT = tuple(PRIM_OP_CLASS)


def _op_class(prim_name: str, include_transcendental: bool) -> str | None:
    cls = PRIM_OP_CLASS.get(prim_name)
    if cls is None and include_transcendental and prim_name in TRANSCENDENTALS:
        return "transcendental"
    return cls


def _read(env, var):
    if isinstance(var, jcore.Literal):
        return var.val
    return env[var]


@dataclasses.dataclass(frozen=True)
class BitChannel:
    """Static metadata of one bit-census counter: which genome site owns
    the intercepted op, and how its exact bit count converts to energy.
    ``weight`` is scalar FLOPs charged per counted output element
    (``eqn_flops / numel`` — a dot's 2·M·N·K madds share the census of its
    M·N outputs), keeping the dynamic estimator on the static model's FLOP
    accounting."""
    site: int
    op_class: str
    dtype: str
    weight: float
    #: static upper bound on the counter's value per evaluation
    #: (numel × mantissa bits × control-flow trip multiplier) — scan
    #: folds consult it to pick an accumulator that stays exact
    max_count: int = 0


@dataclasses.dataclass(frozen=True)
class BitsRecord:
    """One host-side census record (a :class:`BitChannel` plus its
    concrete count) — the input of ``energy.dynamic_fpu_energy``."""
    site: int
    op_class: str
    dtype: str
    weight: float
    count: int


def _float_out(outvars) -> bool:
    for v in outvars:
        aval = v.aval
        if hasattr(aval, "dtype") and jnp.issubdtype(aval.dtype, jnp.floating):
            return True
    return False


class NeatInterpreter:
    def __init__(self, rule: PlacementRule, *,
                 include_transcendental: bool = False):
        self.rule = rule
        self.include_transcendental = include_transcendental
        # census of intercepted flops per (scope-path, op_class, dtype) —
        # filled during interpretation, used by the dynamic energy model
        self.census: Dict[Tuple[str, str, str], int] = {}
        # bit-census accumulators (dynamic energy): parallel lists of
        # static channel metadata and traced int32 counters
        self.collect_bits: bool = False
        self.bit_channels: List[BitChannel] = []
        self.bit_counts: List = []

    # -- interception hook (overridden by the dynamic-bits interpreter) ------
    def intercept(self, stack: Tuple[str, ...], op_class: str,
                  out_dtype) -> FpImplementation | None:
        return self.rule.select(stack, op_class, out_dtype)

    # -- bit-census hooks -----------------------------------------------------
    def _census_site(self, stack: Tuple[str, ...], op_class: str,
                     out_dtype) -> int | None:
        """Genome site owning this op for census purposes (None = skip)."""
        return None

    def _count_bits(self, x):
        """Scalar int32 manipulated-bit count of one tensor."""
        from repro.kernels.ops import bit_census
        return bit_census(x)

    def _post_intercept(self, stack, op_class, eqn, outvals) -> None:
        """Record one census channel per float output of an intercepted
        (already quantized) op. Only called when ``collect_bits``."""
        site = self._census_site(stack, op_class, eqn.outvars[0].aval.dtype)
        if site is None:
            return
        from repro.core.profiler import eqn_flops
        flops = eqn_flops(eqn)
        for v, o in zip(eqn.outvars, outvals):
            aval = v.aval
            if not (hasattr(aval, "dtype")
                    and jnp.issubdtype(aval.dtype, jnp.floating)):
                continue
            numel = max(int(np.prod(aval.shape)) if aval.shape else 1, 1)
            from repro.utils.numerics import float_spec
            self.bit_channels.append(BitChannel(
                site=site, op_class=op_class,
                dtype=str(jnp.dtype(aval.dtype)), weight=flops / numel,
                max_count=numel * float_spec(aval.dtype).mantissa_bits))
            self.bit_counts.append(self._count_bits(o))

    # -- sub-jaxpr helpers ---------------------------------------------------
    def _closed_runner(self, closed: jcore.ClosedJaxpr,
                       prefix: Tuple[str, ...]) -> Callable:
        def run(*args):
            return self.eval_jaxpr(closed.jaxpr, closed.consts, args, prefix)
        return run

    def _merge_stack(self, prefix: Tuple[str, ...],
                     inner: Tuple[str, ...]) -> Tuple[str, ...]:
        # inner name stacks of sub-jaxprs may or may not already carry the
        # outer frames; avoid duplicating a shared prefix.
        if prefix and inner[:len(prefix)] == prefix:
            return inner
        return prefix + inner

    # -- the interpreter ------------------------------------------------------
    def eval_jaxpr(self, jaxpr: jcore.Jaxpr, consts, args,
                   prefix: Tuple[str, ...] = ()):
        env: Dict = {}
        for v, c in zip(jaxpr.constvars, consts):
            env[v] = c
        for v, a in zip(jaxpr.invars, args):
            env[v] = a

        for eqn in jaxpr.eqns:
            invals = [_read(env, v) for v in eqn.invars]
            prim = eqn.primitive
            name = prim.name
            stack = self._merge_stack(
                prefix, parse_name_stack(eqn.source_info.name_stack))

            if name == "pjit":
                closed = eqn.params["jaxpr"]
                outvals = self.eval_jaxpr(closed.jaxpr, closed.consts,
                                          invals, stack)
            elif name in ("custom_jvp_call", "custom_vjp_call",
                          "custom_vjp_call_jaxpr"):
                closed = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
                outvals = self.eval_jaxpr(closed.jaxpr, closed.consts,
                                          invals, stack)
            elif name == "remat2" or name == "checkpoint":
                inner = eqn.params["jaxpr"]  # plain Jaxpr, no consts
                outvals = self.eval_jaxpr(inner, (), invals, stack)
            elif name == "scan":
                outvals = self._eval_scan(eqn, invals, stack)
            elif name == "while":
                outvals = self._eval_while(eqn, invals, stack)
            elif name == "cond":
                outvals = self._eval_cond(eqn, invals, stack)
            else:
                op_class = _op_class(name, self.include_transcendental)
                fpi: FpImplementation | None = None
                if op_class is not None and _float_out(eqn.outvars):
                    out_dtype = eqn.outvars[0].aval.dtype
                    fpi = self.intercept(stack, op_class, out_dtype)
                    if fpi is not None:
                        invals = list(fpi.quantize_operands(op_class, invals))
                    self._record(stack, op_class, out_dtype, eqn)
                ans = prim.bind(*invals, **eqn.params)
                outvals = list(ans) if prim.multiple_results else [ans]
                if fpi is not None:
                    outvals = [
                        fpi.perform_operation(op_class, invals, o)
                        if jnp.issubdtype(jnp.result_type(o), jnp.floating) else o
                        for o in outvals
                    ]
                    if self.collect_bits:
                        self._post_intercept(stack, op_class, eqn, outvals)

            if not prim.multiple_results and not isinstance(outvals, (list, tuple)):
                outvals = [outvals]
            for v, o in zip(eqn.outvars, outvals):
                if not isinstance(v, _DropVar):
                    env[v] = o

        return [_read(env, v) for v in jaxpr.outvars]

    # -- higher-order re-emission ---------------------------------------------
    def _eval_scan(self, eqn, invals, stack):
        p = eqn.params
        num_consts, num_carry = p["num_consts"], p["num_carry"]
        closed = p["jaxpr"]
        consts = invals[:num_consts]
        init = invals[num_consts:num_consts + num_carry]
        xs = invals[num_consts + num_carry:]
        body = self._closed_runner(closed, stack)
        # census counters minted inside the body belong to the scan trace:
        # route them out through the scan's stacked outputs and fold each
        # channel over the iteration axis (the dynamic analogue of the
        # profiler's `flops * length`). The marks also make body re-traces
        # idempotent — each trace rebuilds the same channel suffix.
        cmark = len(self.bit_channels)
        vmark = len(self.bit_counts)

        def f(carry, x):
            del self.bit_channels[cmark:]
            del self.bit_counts[vmark:]
            outs = body(*consts, *carry, *x)
            step_counts = tuple(self.bit_counts[vmark:])
            del self.bit_counts[vmark:]
            return (tuple(outs[:num_carry]),
                    (tuple(outs[num_carry:]), step_counts))

        carry, (ys, counts) = lax.scan(
            f, tuple(init), tuple(xs), length=p["length"],
            reverse=p["reverse"], unroll=p.get("unroll", 1))
        # fold each channel over the iteration axis with an accumulator
        # its static bound (channel max_count x length) keeps exact:
        # int32 when provably safe, int64 when the runtime has it, else
        # an f32 fold (approximate but identical on the host-reference
        # path, which shares this code). max_count is bumped so nested
        # scans compound the bound correctly.
        length = max(int(p["length"]), 1)
        for k, c in enumerate(counts):
            ch = self.bit_channels[cmark + k]
            bound = length * max(ch.max_count, 1)
            if bound <= np.iinfo(np.int32).max:
                s = jnp.sum(c, dtype=jnp.int32)
            elif jax.config.jax_enable_x64:
                s = jnp.sum(c, dtype=jnp.int64)
            else:
                s = jnp.sum(c.astype(jnp.float32))
            self.bit_counts.append(s)
            self.bit_channels[cmark + k] = dataclasses.replace(
                ch, max_count=bound)
        return list(carry) + list(ys)

    @contextlib.contextmanager
    def _suspend_census(self):
        prev = self.collect_bits
        self.collect_bits = False
        try:
            yield
        finally:
            self.collect_bits = prev

    def _census_bits_bound(self, stack, op_class, out_dtype,
                           site: int):
        """Static manipulated-bit bound per element, ``min(b_site, full)``
        (traced or concrete), for the while/cond fallback. None = no
        fallback (the base interpreter collects nothing)."""
        return None

    def _static_census_jaxpr(self, jaxpr: jcore.Jaxpr,
                             stack: Tuple[str, ...], mult: int = 1) -> None:
        """Static census fallback for control-flow bodies the value
        census cannot thread counts out of (while *cond* bodies — while
        bodies are measured through the loop carry and cond branches
        through the switch's union counts vector; nested conds *inside*
        a while-cond body stay static, largest branch, via the cond
        case below): charge each governed float eqn its static bound
        ``numel * min(b, full)`` manipulated bits — exactly its
        static-model term, so ``dyn <= static`` holds with equality for
        these FLOPs. Keep
        primitive coverage and trip counts in sync with
        ``profiler._walk`` (one while iteration, the largest cond
        branch, ``length`` for nested scans) — the invariant assumes
        both walkers count the same FLOPs."""
        from repro.core.profiler import eqn_flops
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            estack = self._merge_stack(
                stack, parse_name_stack(eqn.source_info.name_stack))
            if name == "pjit":
                self._static_census_jaxpr(eqn.params["jaxpr"].jaxpr,
                                          estack, mult)
                continue
            if name in ("custom_jvp_call", "custom_vjp_call",
                        "custom_vjp_call_jaxpr"):
                closed = (eqn.params.get("call_jaxpr")
                          or eqn.params.get("fun_jaxpr"))
                self._static_census_jaxpr(closed.jaxpr, estack, mult)
                continue
            if name in ("remat2", "checkpoint"):
                self._static_census_jaxpr(eqn.params["jaxpr"], estack, mult)
                continue
            if name == "scan":
                self._static_census_jaxpr(
                    eqn.params["jaxpr"].jaxpr, estack,
                    mult * int(eqn.params["length"]))
                continue
            if name == "while":
                self._static_census_jaxpr(eqn.params["cond_jaxpr"].jaxpr,
                                          estack, mult)
                self._static_census_jaxpr(eqn.params["body_jaxpr"].jaxpr,
                                          estack, mult)
                continue
            if name == "cond":
                br = max(eqn.params["branches"],
                         key=lambda b: len(b.jaxpr.eqns))
                self._static_census_jaxpr(br.jaxpr, estack, mult)
                continue
            op_class = _op_class(name, self.include_transcendental)
            if op_class is None or not _float_out(eqn.outvars):
                continue
            out_dtype = eqn.outvars[0].aval.dtype
            site = self._census_site(estack, op_class, out_dtype)
            if site is None:
                continue
            bits = self._census_bits_bound(estack, op_class, out_dtype,
                                           site)
            if bits is None:
                continue
            flops = eqn_flops(eqn)
            for v in eqn.outvars:
                aval = v.aval
                if not (hasattr(aval, "dtype")
                        and jnp.issubdtype(aval.dtype, jnp.floating)):
                    continue
                numel = max(int(np.prod(aval.shape)) if aval.shape else 1,
                            1)
                from repro.utils.numerics import float_spec
                full = float_spec(aval.dtype).mantissa_bits
                self.bit_channels.append(BitChannel(
                    site=site, op_class=op_class,
                    dtype=str(jnp.dtype(aval.dtype)),
                    weight=flops / numel,
                    max_count=numel * mult * full))
                self.bit_counts.append(
                    jnp.int32(numel * mult) * jnp.asarray(bits, jnp.int32))

    @staticmethod
    def _while_acc_dtype(count_dtype):
        """Accumulator dtype for one while-threaded census channel: a
        float fold (a nested scan's degraded accumulator) stays float;
        integer counts widen to int64 when the runtime has it, else stay
        int32 (exact until 2^31 manipulated bits per channel — the trip
        count is data-dependent, so no static bound can promote them the
        way scan folds are promoted)."""
        dt = jnp.dtype(count_dtype)
        if jnp.issubdtype(dt, jnp.floating):
            return dt
        return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32

    def _eval_while(self, eqn, invals, stack):
        """While loops with the census threaded through the carry.

        Counters minted inside the body join the loop carry as one
        accumulator per channel, so data-dependent trip counts are
        *measured* — each iteration folds its exact per-iteration census
        into the running sum (under vmap, lanes whose predicate has
        dropped keep their carry, so per-genome counts stop with their
        own loop). Channel ``max_count`` stays the per-iteration bound
        (no static trip multiplier exists). The cond body keeps the
        static genome-scaled bound as its fallback: its only output is
        the loop predicate, so no value census can thread out of it —
        and a body that mints no channels (ungoverned) degenerates to
        exactly the old behavior.

        The counts measure the *compiled* loop's values; XLA's
        value-changing loop fusions (mul+add -> fma) can flip low-order
        mantissa bits relative to an eagerly-executed reference, so
        full-precision trailing-zero counts carry a tiny
        compilation-context sensitivity that reduced-width truncation
        rounds away (tests/test_energy_dynamic.py pins the tolerance).
        """
        p = eqn.params
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        cond_consts = invals[:cn]
        body_consts = invals[cn:cn + bn]
        init = tuple(invals[cn + bn:])
        cond_run = self._closed_runner(p["cond_jaxpr"], stack)
        body_run = self._closed_runner(p["body_jaxpr"], stack)
        if not self.collect_bits:
            with self._suspend_census():
                out = lax.while_loop(
                    lambda c: cond_run(*cond_consts, *c)[0],
                    lambda c: tuple(body_run(*body_consts, *c)),
                    init)
            return list(out)

        self._static_census_jaxpr(p["cond_jaxpr"].jaxpr, stack)
        # pre-trace the body abstractly to mint the channel metadata: the
        # accumulator carry structure must be known before while_loop
        # traces. The pre-trace would double-record the FLOP census, so
        # snapshot/restore it; its abstract counts are dropped (the real
        # body trace re-mints both, idempotently, via the del marks).
        cmark = len(self.bit_channels)
        vmark = len(self.bit_counts)
        census_snapshot = dict(self.census)
        jax.eval_shape(lambda c: tuple(body_run(*body_consts, *c)), init)
        self.census = census_snapshot
        acc_dtypes = [self._while_acc_dtype(getattr(c, "dtype", jnp.int32))
                      for c in self.bit_counts[vmark:]]
        del self.bit_counts[vmark:]

        def cond_fn(carry):
            state, _ = carry
            with self._suspend_census():   # already statically charged
                return cond_run(*cond_consts, *state)[0]

        def body_fn(carry):
            state, accs = carry
            del self.bit_channels[cmark:]
            del self.bit_counts[vmark:]
            outs = body_run(*body_consts, *state)
            step = tuple(self.bit_counts[vmark:])
            del self.bit_counts[vmark:]
            new_accs = tuple(a + s.astype(dt) for a, s, dt
                             in zip(accs, step, acc_dtypes))
            return tuple(outs), new_accs

        init_accs = tuple(jnp.zeros((), dt) for dt in acc_dtypes)
        out, accs = lax.while_loop(cond_fn, body_fn, (init, init_accs))
        self.bit_counts.extend(accs)
        return list(out)

    def _eval_cond(self, eqn, invals, stack):
        """Cond with **measured** per-branch censuses.

        Each branch is pre-traced abstractly to mint its channel
        metadata (exactly the while-body approach); the union of all
        branches' channels becomes this cond's channel suffix, and each
        ``lax.switch`` branch returns, alongside its outputs, the union
        counts vector — its own segment measured, every other branch's
        segment zero. Selecting by the (data-dependent) branch index
        therefore selects the *taken* branch's exact census, replacing
        the old static largest-branch bound. Under vmap (the population
        evaluator) a batched index lowers to select-of-all-branches, so
        each genome lane keeps the census of the branch *it* took.

        Caveat (mirrors the while-loop one): the static model still
        charges the branch with the most equations, so a taken branch
        whose governed FLOPs exceed that branch's can push measured
        energy above the static charge — dyn <= static remains a
        convention of the static model's branch choice, not an
        invariant the measurement enforces. The while *cond* body keeps
        its static charge (its only product is the predicate)."""
        branches = eqn.params["branches"]
        index, *ops = invals
        fns = [self._closed_runner(br, stack) for br in branches]
        if not self.collect_bits:
            with self._suspend_census():
                return list(lax.switch(
                    index, [lambda *a, f=f: tuple(f(*a)) for f in fns],
                    *ops))

        # pre-trace every branch to mint the union channel metadata;
        # abstract counts are dropped (the real switch trace re-mints
        # them idempotently via the del marks), and the pre-trace must
        # not double-record the FLOP census
        cmark = len(self.bit_channels)
        vmark = len(self.bit_counts)
        census_snapshot = dict(self.census)
        seg_channels: List[List[BitChannel]] = []
        seg_dtypes: List[List] = []
        for f in fns:
            sub_cmark = len(self.bit_channels)
            jax.eval_shape(lambda *a, f=f: tuple(f(*a)), *ops)
            seg_channels.append(list(self.bit_channels[sub_cmark:]))
            seg_dtypes.append([
                self._while_acc_dtype(getattr(c, "dtype", jnp.int32))
                for c in self.bit_counts[vmark:]])
            del self.bit_counts[vmark:]
        self.census = census_snapshot
        del self.bit_channels[cmark:]
        union = [ch for seg in seg_channels for ch in seg]
        # one shared accumulator dtype per union slot (a branch only
        # fills its own segment; zeros elsewhere)
        union_dtypes = [dt for seg in seg_dtypes for dt in seg]
        offsets = np.cumsum([0] + [len(s) for s in seg_channels])

        def branch_fn(j, f):
            def run(*a):
                del self.bit_channels[cmark:]
                del self.bit_counts[vmark:]
                outs = f(*a)
                step = list(self.bit_counts[vmark:])
                del self.bit_counts[vmark:]
                counts = [jnp.zeros((), dt) for dt in union_dtypes]
                for k, c in enumerate(step):
                    counts[offsets[j] + k] = c.astype(
                        union_dtypes[offsets[j] + k])
                return tuple(outs), tuple(counts)
            return run

        # collect_bits must stay on inside the switch trace (the branch
        # bodies mint the measured counters); the FLOP census records
        # every traced branch, exactly like the collect_bits=False path
        # (_record is not gated by collect_bits), so the diagnostic is
        # mode-independent
        out, counts = lax.switch(
            index, [branch_fn(j, f) for j, f in enumerate(fns)], *ops)
        # drop the last-traced branch's re-mints; install the union
        del self.bit_channels[cmark:]
        del self.bit_counts[vmark:]
        self.bit_channels.extend(union)
        self.bit_counts.extend(counts)
        return list(out)

    # -- census ----------------------------------------------------------------
    def _record(self, stack, op_class, dtype, eqn):
        from repro.core.profiler import eqn_flops
        key = ("/".join(stack), op_class, str(jnp.dtype(dtype)))
        self.census[key] = self.census.get(key, 0) + eqn_flops(eqn)


class _DynFPI:
    """FPI stand-in whose mantissa width is a traced scalar (one entry of
    the genome bits vector). Result-quantization only."""

    def __init__(self, bits_scalar, mode: str):
        self.bits = bits_scalar
        self.mode = mode

    def quantize_operands(self, op_class, operands):
        return operands

    def perform_operation(self, op_class, operands, result):
        from repro.utils.numerics import truncate_mantissa_dynamic
        return truncate_mantissa_dynamic(result, self.bits, self.mode)


class DynamicNeatInterpreter(NeatInterpreter):
    """Interpreter whose placement decisions are static (stack matching at
    trace time) but whose mantissa widths come from a traced bits vector —
    one jit compile serves the whole NSGA-II run."""

    def __init__(self, family: str, sites: Sequence[str], *,
                 target: str = "single", mode: str = "rne",
                 include_transcendental: bool = False,
                 collect_bits: bool = False):
        from repro.core.placement import PlacementRule
        super().__init__(PlacementRule(target=target),
                         include_transcendental=include_transcendental)
        self.family = family
        self.sites = list(sites)
        self.site_idx = {s: i for i, s in enumerate(self.sites)}
        self.mode = mode
        self.target = target
        self.collect_bits = collect_bits
        self.bits_vec = None   # set per call by neat_transform_dynamic

    def _site_for(self, stack: Tuple[str, ...]) -> int | None:
        from repro.core.placement import site_index_for_stack
        return site_index_for_stack(self.family, self.site_idx, stack)

    def intercept(self, stack, op_class, out_dtype):
        from repro.core.placement import _is_target_dtype
        if not _is_target_dtype(out_dtype, self.target):
            return None
        idx = self._site_for(stack)
        if idx is None:
            return None
        return _DynFPI(self.bits_vec[idx], self.mode)

    def _census_site(self, stack, op_class, out_dtype):
        # also reached directly by the while/cond static fallback, so the
        # target-dtype filter cannot be left to intercept() alone
        from repro.core.placement import _is_target_dtype
        if not _is_target_dtype(out_dtype, self.target):
            return None
        return self._site_for(stack)

    def _census_bits_bound(self, stack, op_class, out_dtype, site):
        from repro.utils.numerics import float_spec
        full = float_spec(out_dtype).mantissa_bits
        return jnp.clip(self.bits_vec[site], 1, full)

    def stacked_counts(self) -> jnp.ndarray:
        """The traced ``(n_channels,)`` accumulator output — int32 in the
        common case; scan folds whose static bound exceeds int32 widen to
        int64 under x64 or degrade to an f32 fold (the whole vector
        promotes with them; the host reference shares the arithmetic)."""
        if not self.bit_counts:
            return jnp.zeros((0,), jnp.int32)
        return jnp.stack(self.bit_counts)


class BitCensusCapture(NeatInterpreter):
    """Host-side reference interpreter for the dynamic energy estimator.

    Runs a *concrete* placement rule (``rule_from_genome``) eagerly and
    records a :class:`BitsRecord` per governed FLOP using the independent
    jnp census (``utils.numerics.manipulated_bits``), mirroring the
    device path's site resolution exactly — the parity target for
    ``tests/test_energy_dynamic.py`` and the CI smoke gate.
    """

    def __init__(self, rule, family: str, sites: Sequence[str], *,
                 target: str = "single",
                 include_transcendental: bool = False):
        super().__init__(rule, include_transcendental=include_transcendental)
        self.family = family
        self.site_idx = {s: i for i, s in enumerate(sites)}
        self.target = target
        self.collect_bits = True

    def _census_site(self, stack, op_class, out_dtype):
        from repro.core.placement import _is_target_dtype, site_index_for_stack
        if not _is_target_dtype(out_dtype, self.target):
            return None
        return site_index_for_stack(self.family, self.site_idx, stack)

    def _count_bits(self, x):
        from repro.utils.numerics import manipulated_bits
        return jnp.sum(manipulated_bits(x)).astype(jnp.int32)

    def _census_bits_bound(self, stack, op_class, out_dtype, site):
        fpi = self.rule.select(stack, op_class, out_dtype)
        return jnp.int32(fpi.mantissa_bits(out_dtype))

    def records(self) -> List[BitsRecord]:
        return [BitsRecord(ch.site, ch.op_class, ch.dtype, ch.weight,
                           int(np.asarray(c)))
                for ch, c in zip(self.bit_channels, self.bit_counts)]


def _input_signature(args, kwargs) -> tuple:
    """Hashable (structure, shapes, dtypes) key of one input set —
    identical for a concrete input and its unbatched vmap tracers, so
    census-channel metadata recorded at trace time can be looked up from
    the host with the raw inputs."""
    return (jax.tree.structure((args, kwargs)), tuple(
        (getattr(x, "shape", None), str(getattr(x, "dtype", type(x))))
        for x in jax.tree.leaves((args, kwargs))))


def neat_transform_dynamic(fn: Callable, family: str, sites: Sequence[str],
                           *, target: str = "single", mode: str = "rne",
                           include_transcendental: bool = False,
                           collect_bits: bool = False) -> Callable:
    """Return ``g(bits, *args)`` == `fn(*args)` under `family` placement
    with per-site mantissa widths from the traced int vector ``bits``.

    Jit ``g`` once; every genome evaluation is then a compiled call.

    With ``collect_bits=True``, ``g`` returns ``(fn(*args), counts)``
    where ``counts`` is the ``(n_channels,)`` int32 bit-census
    accumulator vector. Channel metadata is per input signature (shapes
    enter the ``weight = flops/numel`` folding scales): fetch it with
    ``g.bit_channels_for(*args)`` — valid once that signature has been
    traced; ``g.bit_channels`` holds the most recent trace's channels.
    """
    cache: Dict = {}
    channels_by_sig: Dict = {}

    def g(bits, *args, **kwargs):
        interp = DynamicNeatInterpreter(
            family, sites, target=target, mode=mode,
            include_transcendental=include_transcendental,
            collect_bits=collect_bits)
        interp.bits_vec = jnp.asarray(bits, jnp.int32)
        key = _input_signature(args, kwargs)
        if key not in cache:
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(
                *args, **kwargs)
            cache[key] = (closed, jax.tree.structure(out_shape))
        closed, out_tree = cache[key]
        flat = jax.tree.leaves((args, kwargs))
        outs = interp.eval_jaxpr(closed.jaxpr, closed.consts, flat)
        result = jax.tree.unflatten(out_tree, outs)
        if collect_bits:
            g.bit_channels = tuple(interp.bit_channels)
            channels_by_sig[key] = g.bit_channels
            return result, interp.stacked_counts()
        return result

    def bit_channels_for(*args, **kwargs):
        """Census channels recorded at this input signature's trace
        (KeyError before the signature has been dispatched)."""
        return channels_by_sig[_input_signature(args, kwargs)]

    g.bit_channels = ()
    g.bit_channels_for = bit_channels_for
    return g


def neat_transform_population(fn: Callable, family: str,
                              sites: Sequence[str], *,
                              target: str = "single", mode: str = "rne",
                              include_transcendental: bool = False,
                              collect_bits: bool = False) -> Callable:
    """Population-batched evaluator: ``G(bits_matrix, *args)`` computes
    ``fn(*args)`` under every genome row of ``bits_matrix`` (P, n_sites)
    in ONE compiled call, by vmapping the dynamic-bits evaluator over the
    population axis. Output leaves gain a leading population axis.

    The bits matrix is the only batched input, so XLA compiles a single
    device-parallel program per input signature; jit ``G`` once and every
    NSGA-II generation becomes one dispatch instead of ``P``.

    With ``collect_bits=True`` the per-genome census accumulators come
    back as a second ``(P, n_channels)`` output in the same dispatch;
    channel metadata is on ``G.inner.bit_channels`` after the first call.
    """
    g = neat_transform_dynamic(
        fn, family, sites, target=target, mode=mode,
        include_transcendental=include_transcendental,
        collect_bits=collect_bits)

    def G(bits_matrix, *args):
        bits_matrix = jnp.asarray(bits_matrix, jnp.int32)
        in_axes = (0,) + (None,) * len(args)
        return jax.vmap(g, in_axes=in_axes)(bits_matrix, *args)

    G.inner = g
    return G


def capture_bit_census(fn: Callable, rule, family: str,
                       sites: Sequence[str], *, target: str = "single",
                       include_transcendental: bool = False) -> Callable:
    """Host-side dynamic-energy reference: return ``h(*args)`` ->
    ``(fn(*args), records)`` where ``records`` are the
    :class:`BitsRecord` census of every governed FLOP under the concrete
    ``rule`` — feed them to ``energy.dynamic_fpu_energy``."""
    def h(*args, **kwargs):
        interp = BitCensusCapture(
            rule, family, sites, target=target,
            include_transcendental=include_transcendental)
        closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(
            *args, **kwargs)
        flat = jax.tree.leaves((args, kwargs))
        outs = interp.eval_jaxpr(closed.jaxpr, closed.consts, flat)
        return (jax.tree.unflatten(jax.tree.structure(out_shape), outs),
                interp.records())
    return h


def neat_transform(fn: Callable, rule: PlacementRule, *,
                   include_transcendental: bool = False) -> Callable:
    """Return `fn` with NEAT placement-rule enforcement (paper mode).

    The returned callable also exposes ``.last_census`` — the FLOP census of
    the most recent call, keyed by (scope path, op class, dtype) — which the
    energy model consumes.
    """
    cache: Dict = {}

    def wrapped(*args, **kwargs):
        interp = NeatInterpreter(
            rule, include_transcendental=include_transcendental)
        key = jax.tree.structure((args, kwargs)), tuple(
            (getattr(x, "shape", None), str(getattr(x, "dtype", type(x))))
            for x in jax.tree.leaves((args, kwargs)))
        if key not in cache:
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(
                *args, **kwargs)
            cache[key] = (closed, jax.tree.structure(out_shape))
        closed, out_tree = cache[key]
        flat = jax.tree.leaves((args, kwargs))
        outs = interp.eval_jaxpr(closed.jaxpr, closed.consts, flat)
        wrapped.last_census = dict(interp.census)
        return jax.tree.unflatten(out_tree, outs)

    wrapped.last_census = {}
    return wrapped
