"""The NEAT jaxpr interpreter — the Pin-tool analogue (paper-faithful mode).

``neat_transform(fn, rule)`` returns a function computing ``fn`` with every
intercepted floating-point primitive replaced by the FPI the placement rule
assigns, given the equation's *name stack* (recorded by ``pscope`` /
``jax.named_scope`` at trace time). This reproduces Pin's per-FLOP dynamic
replacement: CIP consults the innermost frame, FCS walks the stack outward
— exactly the paper's semantics, at jaxpr granularity.

Higher-order primitives (scan/while/cond/pjit/custom_jvp/...) are handled
by re-emitting them with interpreted bodies, so the transform composes with
``jax.jit`` and control flow.
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.extend import core as jcore

try:  # DropVar has no jax.extend home yet
    from jax._src.core import DropVar as _DropVar
except ImportError:  # pragma: no cover
    class _DropVar:  # fallback: nothing matches
        pass

from repro.core.fpi import FpImplementation
from repro.core.placement import PlacementRule
from repro.core.scope import parse_name_stack

# jax primitive name -> NEAT op class (paper: SSE ADDSS/SUBSS/MULSS/DIVSS +
# their fp64 twins; dot/conv represent the same scalar madd streams a C
# binary would execute — see DESIGN.md "changed assumptions").
PRIM_OP_CLASS: Dict[str, str] = {
    "add": "add",
    "add_any": "add",
    "sub": "sub",
    "mul": "mul",
    "div": "div",
    "dot_general": "dot",
    "conv_general_dilated": "conv",
}

TRANSCENDENTALS = {
    "exp", "log", "tanh", "logistic", "sqrt", "rsqrt", "pow", "integer_pow",
    "erf", "sin", "cos", "log1p", "expm1", "cbrt", "atan2",
}

DEFAULT_INTERCEPT = tuple(PRIM_OP_CLASS)


def _op_class(prim_name: str, include_transcendental: bool) -> str | None:
    cls = PRIM_OP_CLASS.get(prim_name)
    if cls is None and include_transcendental and prim_name in TRANSCENDENTALS:
        return "transcendental"
    return cls


def _read(env, var):
    if isinstance(var, jcore.Literal):
        return var.val
    return env[var]


def _float_out(outvars) -> bool:
    for v in outvars:
        aval = v.aval
        if hasattr(aval, "dtype") and jnp.issubdtype(aval.dtype, jnp.floating):
            return True
    return False


class NeatInterpreter:
    def __init__(self, rule: PlacementRule, *,
                 include_transcendental: bool = False):
        self.rule = rule
        self.include_transcendental = include_transcendental
        # census of intercepted flops per (scope-path, op_class, dtype) —
        # filled during interpretation, used by the dynamic energy model
        self.census: Dict[Tuple[str, str, str], int] = {}

    # -- interception hook (overridden by the dynamic-bits interpreter) ------
    def intercept(self, stack: Tuple[str, ...], op_class: str,
                  out_dtype) -> FpImplementation | None:
        return self.rule.select(stack, op_class, out_dtype)

    # -- sub-jaxpr helpers ---------------------------------------------------
    def _closed_runner(self, closed: jcore.ClosedJaxpr,
                       prefix: Tuple[str, ...]) -> Callable:
        def run(*args):
            return self.eval_jaxpr(closed.jaxpr, closed.consts, args, prefix)
        return run

    def _merge_stack(self, prefix: Tuple[str, ...],
                     inner: Tuple[str, ...]) -> Tuple[str, ...]:
        # inner name stacks of sub-jaxprs may or may not already carry the
        # outer frames; avoid duplicating a shared prefix.
        if prefix and inner[:len(prefix)] == prefix:
            return inner
        return prefix + inner

    # -- the interpreter ------------------------------------------------------
    def eval_jaxpr(self, jaxpr: jcore.Jaxpr, consts, args,
                   prefix: Tuple[str, ...] = ()):
        env: Dict = {}
        for v, c in zip(jaxpr.constvars, consts):
            env[v] = c
        for v, a in zip(jaxpr.invars, args):
            env[v] = a

        for eqn in jaxpr.eqns:
            invals = [_read(env, v) for v in eqn.invars]
            prim = eqn.primitive
            name = prim.name
            stack = self._merge_stack(
                prefix, parse_name_stack(eqn.source_info.name_stack))

            if name == "pjit":
                closed = eqn.params["jaxpr"]
                outvals = self.eval_jaxpr(closed.jaxpr, closed.consts,
                                          invals, stack)
            elif name in ("custom_jvp_call", "custom_vjp_call",
                          "custom_vjp_call_jaxpr"):
                closed = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
                outvals = self.eval_jaxpr(closed.jaxpr, closed.consts,
                                          invals, stack)
            elif name == "remat2" or name == "checkpoint":
                inner = eqn.params["jaxpr"]  # plain Jaxpr, no consts
                outvals = self.eval_jaxpr(inner, (), invals, stack)
            elif name == "scan":
                outvals = self._eval_scan(eqn, invals, stack)
            elif name == "while":
                outvals = self._eval_while(eqn, invals, stack)
            elif name == "cond":
                outvals = self._eval_cond(eqn, invals, stack)
            else:
                op_class = _op_class(name, self.include_transcendental)
                fpi: FpImplementation | None = None
                if op_class is not None and _float_out(eqn.outvars):
                    out_dtype = eqn.outvars[0].aval.dtype
                    fpi = self.intercept(stack, op_class, out_dtype)
                    if fpi is not None:
                        invals = list(fpi.quantize_operands(op_class, invals))
                    self._record(stack, op_class, out_dtype, eqn)
                ans = prim.bind(*invals, **eqn.params)
                outvals = list(ans) if prim.multiple_results else [ans]
                if fpi is not None:
                    outvals = [
                        fpi.perform_operation(op_class, invals, o)
                        if jnp.issubdtype(jnp.result_type(o), jnp.floating) else o
                        for o in outvals
                    ]

            if not prim.multiple_results and not isinstance(outvals, (list, tuple)):
                outvals = [outvals]
            for v, o in zip(eqn.outvars, outvals):
                if not isinstance(v, _DropVar):
                    env[v] = o

        return [_read(env, v) for v in jaxpr.outvars]

    # -- higher-order re-emission ---------------------------------------------
    def _eval_scan(self, eqn, invals, stack):
        p = eqn.params
        num_consts, num_carry = p["num_consts"], p["num_carry"]
        closed = p["jaxpr"]
        consts = invals[:num_consts]
        init = invals[num_consts:num_consts + num_carry]
        xs = invals[num_consts + num_carry:]
        body = self._closed_runner(closed, stack)

        def f(carry, x):
            outs = body(*consts, *carry, *x)
            return tuple(outs[:num_carry]), tuple(outs[num_carry:])

        carry, ys = lax.scan(f, tuple(init), tuple(xs), length=p["length"],
                             reverse=p["reverse"], unroll=p.get("unroll", 1))
        return list(carry) + list(ys)

    def _eval_while(self, eqn, invals, stack):
        p = eqn.params
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        cond_consts = invals[:cn]
        body_consts = invals[cn:cn + bn]
        init = tuple(invals[cn + bn:])
        cond_run = self._closed_runner(p["cond_jaxpr"], stack)
        body_run = self._closed_runner(p["body_jaxpr"], stack)
        out = lax.while_loop(
            lambda c: cond_run(*cond_consts, *c)[0],
            lambda c: tuple(body_run(*body_consts, *c)),
            init)
        return list(out)

    def _eval_cond(self, eqn, invals, stack):
        branches = eqn.params["branches"]
        index, *ops = invals
        fns = [self._closed_runner(br, stack) for br in branches]
        out = lax.switch(index, [lambda *a, f=f: tuple(f(*a)) for f in fns], *ops)
        return list(out)

    # -- census ----------------------------------------------------------------
    def _record(self, stack, op_class, dtype, eqn):
        from repro.core.profiler import eqn_flops
        key = ("/".join(stack), op_class, str(jnp.dtype(dtype)))
        self.census[key] = self.census.get(key, 0) + eqn_flops(eqn)


class _DynFPI:
    """FPI stand-in whose mantissa width is a traced scalar (one entry of
    the genome bits vector). Result-quantization only."""

    def __init__(self, bits_scalar, mode: str):
        self.bits = bits_scalar
        self.mode = mode

    def quantize_operands(self, op_class, operands):
        return operands

    def perform_operation(self, op_class, operands, result):
        from repro.utils.numerics import truncate_mantissa_dynamic
        return truncate_mantissa_dynamic(result, self.bits, self.mode)


class DynamicNeatInterpreter(NeatInterpreter):
    """Interpreter whose placement decisions are static (stack matching at
    trace time) but whose mantissa widths come from a traced bits vector —
    one jit compile serves the whole NSGA-II run."""

    def __init__(self, family: str, sites: Sequence[str], *,
                 target: str = "single", mode: str = "rne",
                 include_transcendental: bool = False):
        from repro.core.placement import PlacementRule
        super().__init__(PlacementRule(target=target),
                         include_transcendental=include_transcendental)
        self.family = family
        self.sites = list(sites)
        self.site_idx = {s: i for i, s in enumerate(self.sites)}
        self.mode = mode
        self.target = target
        self.bits_vec = None   # set per call by neat_transform_dynamic

    def _site_for(self, stack: Tuple[str, ...]) -> int | None:
        from repro.core.placement import site_index_for_stack
        return site_index_for_stack(self.family, self.site_idx, stack)

    def intercept(self, stack, op_class, out_dtype):
        from repro.core.placement import _is_target_dtype
        if not _is_target_dtype(out_dtype, self.target):
            return None
        idx = self._site_for(stack)
        if idx is None:
            return None
        return _DynFPI(self.bits_vec[idx], self.mode)


def neat_transform_dynamic(fn: Callable, family: str, sites: Sequence[str],
                           *, target: str = "single", mode: str = "rne",
                           include_transcendental: bool = False) -> Callable:
    """Return ``g(bits, *args)`` == `fn(*args)` under `family` placement
    with per-site mantissa widths from the traced int vector ``bits``.

    Jit ``g`` once; every genome evaluation is then a compiled call.
    """
    cache: Dict = {}

    def g(bits, *args, **kwargs):
        interp = DynamicNeatInterpreter(
            family, sites, target=target, mode=mode,
            include_transcendental=include_transcendental)
        interp.bits_vec = jnp.asarray(bits, jnp.int32)
        key = (jax.tree.structure((args, kwargs)), tuple(
            (getattr(x, "shape", None), str(getattr(x, "dtype", type(x))))
            for x in jax.tree.leaves((args, kwargs))))
        if key not in cache:
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(
                *args, **kwargs)
            cache[key] = (closed, jax.tree.structure(out_shape))
        closed, out_tree = cache[key]
        flat = jax.tree.leaves((args, kwargs))
        outs = interp.eval_jaxpr(closed.jaxpr, closed.consts, flat)
        return jax.tree.unflatten(out_tree, outs)

    return g


def neat_transform_population(fn: Callable, family: str,
                              sites: Sequence[str], *,
                              target: str = "single", mode: str = "rne",
                              include_transcendental: bool = False
                              ) -> Callable:
    """Population-batched evaluator: ``G(bits_matrix, *args)`` computes
    ``fn(*args)`` under every genome row of ``bits_matrix`` (P, n_sites)
    in ONE compiled call, by vmapping the dynamic-bits evaluator over the
    population axis. Output leaves gain a leading population axis.

    The bits matrix is the only batched input, so XLA compiles a single
    device-parallel program per input signature; jit ``G`` once and every
    NSGA-II generation becomes one dispatch instead of ``P``.
    """
    g = neat_transform_dynamic(
        fn, family, sites, target=target, mode=mode,
        include_transcendental=include_transcendental)

    def G(bits_matrix, *args):
        bits_matrix = jnp.asarray(bits_matrix, jnp.int32)
        in_axes = (0,) + (None,) * len(args)
        return jax.vmap(g, in_axes=in_axes)(bits_matrix, *args)

    return G


def neat_transform(fn: Callable, rule: PlacementRule, *,
                   include_transcendental: bool = False) -> Callable:
    """Return `fn` with NEAT placement-rule enforcement (paper mode).

    The returned callable also exposes ``.last_census`` — the FLOP census of
    the most recent call, keyed by (scope path, op class, dtype) — which the
    energy model consumes.
    """
    cache: Dict = {}

    def wrapped(*args, **kwargs):
        interp = NeatInterpreter(
            rule, include_transcendental=include_transcendental)
        key = jax.tree.structure((args, kwargs)), tuple(
            (getattr(x, "shape", None), str(getattr(x, "dtype", type(x))))
            for x in jax.tree.leaves((args, kwargs)))
        if key not in cache:
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(
                *args, **kwargs)
            cache[key] = (closed, jax.tree.structure(out_shape))
        closed, out_tree = cache[key]
        flat = jax.tree.leaves((args, kwargs))
        outs = interp.eval_jaxpr(closed.jaxpr, closed.consts, flat)
        wrapped.last_census = dict(interp.census)
        return jax.tree.unflatten(out_tree, outs)

    wrapped.last_census = {}
    return wrapped
