"""Quantization entry points used by models (scope mode).

``neat_quantize`` is the straight-through-estimator truncation used inside
differentiable model code: forward pass truncates mantissa bits, backward
pass is identity (standard QAT practice) so NEAT placements can be applied
to training as well as inference.

Scope mode: model layers call ``quantize_here(x, op_class)``, which
consults the active placement rule (installed with ``use_rule``) against
the current ``pscope`` stack. With no active rule this is the identity and
compiles away entirely.
"""
from __future__ import annotations

import contextlib
import threading
from functools import partial
from typing import Iterator, Optional

import jax
import jax.numpy as jnp

from repro.core.fpi import FpImplementation, IDENTITY
from repro.core.placement import PlacementRule
from repro.core.scope import current_stack
from repro.utils.numerics import float_spec, truncate_mantissa


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ste_truncate(x, bits: int, mode: str = "rne"):
    """Mantissa truncation with straight-through gradient."""
    return truncate_mantissa(x, bits, mode)


def _ste_fwd(x, bits, mode):
    return truncate_mantissa(x, bits, mode), None


def _ste_bwd(bits, mode, res, g):
    return (g,)


ste_truncate.defvjp(_ste_fwd, _ste_bwd)


def neat_quantize(x: jnp.ndarray, fpi: FpImplementation,
                  *, ste: bool = True) -> jnp.ndarray:
    """Apply an FPI's result transform to a tensor (STE by default)."""
    if fpi is IDENTITY or not (hasattr(x, "dtype")
                               and jnp.issubdtype(x.dtype, jnp.floating)):
        return x
    bits = fpi.mantissa_bits(x.dtype)
    if bits >= float_spec(x.dtype).mantissa_bits:
        return x
    mode = getattr(fpi, "mode", "rne")
    if ste:
        return ste_truncate(x, bits, mode)
    return fpi.quantize(x)


# ---------------------------------------------------------------------------
# Active-rule context (scope mode).
# ---------------------------------------------------------------------------

_tls = threading.local()


def active_rule() -> Optional[PlacementRule]:
    return getattr(_tls, "rule", None)


@contextlib.contextmanager
def use_rule(rule: Optional[PlacementRule]) -> Iterator[None]:
    """Install `rule` as the active placement rule for scope-mode code."""
    prev = getattr(_tls, "rule", None)
    _tls.rule = rule
    try:
        yield
    finally:
        _tls.rule = prev


def quantize_here(x: jnp.ndarray, op_class: str = "dot",
                  *, ste: bool = True) -> jnp.ndarray:
    """Quantize `x` per the active rule at the current scope stack.

    This is the scope-mode enforcement point models embed at layer
    boundaries; identity (and zero compiled cost) when no rule is active.
    """
    rule = active_rule()
    if rule is None or not (hasattr(x, "dtype")
                            and jnp.issubdtype(x.dtype, jnp.floating)):
        return x
    fpi = rule.select(current_stack(), op_class, x.dtype)
    return neat_quantize(x, fpi, ste=ste)
