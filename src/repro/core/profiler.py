"""FLOP/byte profiling per scope — paper §IV step 1 ("Profile the Program").

Walks a jaxpr (recursing into higher-order primitives) and produces a
census: FLOPs and bytes moved per (scope path, op class, dtype). This is
the analogue of NEAT's profiling mode, which the user runs before precision
tuning to find the top-N FLOP-intensive functions.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core as jcore

from repro.core.scope import parse_name_stack
from repro.core.interpreter import PRIM_OP_CLASS, TRANSCENDENTALS

# estimated elementwise-op multiplier for transcendentals (a polynomial/
# Newton implementation executes ~8 FLOPs per element)
TRANSCENDENTAL_COST = 8


def _numel(aval) -> int:
    return int(np.prod(aval.shape)) if aval.shape else 1


def eqn_flops(eqn) -> int:
    """FLOPs executed by one jaxpr equation (scalar-op convention, matching
    the paper's per-instruction counting: a dot is 2*M*N*K scalar FLOPs)."""
    name = eqn.primitive.name
    out = eqn.outvars[0].aval
    if name == "dot_general":
        dnums = eqn.params["dimension_numbers"]
        (lc, _), (lb, _) = dnums
        lhs = eqn.invars[0].aval
        k = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
        return 2 * _numel(out) * k
    if name == "conv_general_dilated":
        rhs = eqn.invars[1].aval           # kernel
        # flops = 2 * out_numel * (kernel spatial * in_channels)
        k = _numel(rhs) // max(rhs.shape[eqn.params["dimension_numbers"]
                               .rhs_spec[0]], 1)
        return 2 * _numel(out) * max(k, 1)
    if name in TRANSCENDENTALS:
        return TRANSCENDENTAL_COST * _numel(out)
    return _numel(out)


def eqn_bytes(eqn) -> int:
    """Bytes touched by one equation (operands read + results written)."""
    total = 0
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = v.aval if not isinstance(v, jcore.Literal) else None
        if aval is not None and hasattr(aval, "dtype"):
            total += _numel(aval) * jnp.dtype(aval.dtype).itemsize
    return total


@dataclasses.dataclass
class ScopeStats:
    flops: int = 0
    bytes: int = 0
    by_op: Dict[str, int] = dataclasses.field(default_factory=dict)
    by_dtype: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, op_class: str, dtype: str, flops: int, nbytes: int):
        self.flops += flops
        self.bytes += nbytes
        self.by_op[op_class] = self.by_op.get(op_class, 0) + flops
        self.by_dtype[dtype] = self.by_dtype.get(dtype, 0) + flops


@dataclasses.dataclass
class Profile:
    """Result of profiling: per-scope stats + global totals."""
    scopes: Dict[str, ScopeStats]

    @property
    def total_flops(self) -> int:
        return sum(s.flops for s in self.scopes.values())

    @property
    def total_bytes(self) -> int:
        return sum(s.bytes for s in self.scopes.values())

    def top_functions(self, n: int = 10) -> List[str]:
        """Paper default: the top-N FLOP-intensive functions (scopes).

        Scope paths are reduced to their innermost frame (the "function"),
        aggregating across call sites; '' (unscoped) is excluded.
        """
        agg: Dict[str, int] = defaultdict(int)
        for path, st in self.scopes.items():
            leaf = path.split("/")[-1] if path else ""
            if leaf:
                agg[leaf] += st.flops
        return [k for k, _ in
                sorted(agg.items(), key=lambda kv: -kv[1])[:n]]

    def top_paths(self, n: int = 10) -> List[str]:
        items = [(p, s.flops) for p, s in self.scopes.items() if p]
        return [k for k, _ in sorted(items, key=lambda kv: -kv[1])[:n]]

    def dtype_breakdown(self) -> Dict[str, int]:
        """Fig. 4 analogue: FLOPs per float dtype."""
        agg: Dict[str, int] = defaultdict(int)
        for st in self.scopes.values():
            for dt, f in st.by_dtype.items():
                agg[dt] += f
        return dict(agg)

    def coverage(self, functions: List[str]) -> float:
        """Fraction of FLOPs inside the given functions (paper: >=98% for
        the top-10)."""
        covered = 0
        for path, st in self.scopes.items():
            leaf = path.split("/")[-1] if path else ""
            if any(f == leaf or f in path.split("/") for f in functions):
                covered += st.flops
        t = self.total_flops
        return covered / t if t else 0.0


def _walk(jaxpr: jcore.Jaxpr, scopes: Dict[str, ScopeStats],
          prefix: Tuple[str, ...], mult: int,
          include_transcendental: bool) -> None:
    # keep primitive coverage and trip-count heuristics in sync with
    # interpreter._static_census_jaxpr — the dynamic estimator's
    # dyn <= static invariant assumes both walkers count the same FLOPs
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        raw = parse_name_stack(eqn.source_info.name_stack)
        stack = raw if (prefix and raw[:len(prefix)] == prefix) else prefix + raw
        inner_mult = mult
        sub = None
        if name == "pjit":
            sub = [eqn.params["jaxpr"]]
        elif name in ("custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr"):
            sub = [eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")]
        elif name in ("remat2", "checkpoint"):
            inner = eqn.params["jaxpr"]
            _walk(inner, scopes, stack, mult, include_transcendental)
            continue
        elif name == "scan":
            sub = [eqn.params["jaxpr"]]
            inner_mult = mult * int(eqn.params["length"])
        elif name == "while":
            # unknown trip count: count one iteration (documented)
            sub = [eqn.params["cond_jaxpr"], eqn.params["body_jaxpr"]]
        elif name == "cond":
            # count the largest branch
            sub = [max(eqn.params["branches"],
                       key=lambda b: len(b.jaxpr.eqns))]
        if sub is not None:
            for closed in sub:
                _walk(closed.jaxpr, scopes, stack, inner_mult,
                      include_transcendental)
            continue

        op_class = PRIM_OP_CLASS.get(name)
        if op_class is None and include_transcendental and name in TRANSCENDENTALS:
            op_class = "transcendental"
        if op_class is None:
            continue
        out = eqn.outvars[0].aval
        if not (hasattr(out, "dtype")
                and jnp.issubdtype(out.dtype, jnp.floating)):
            continue
        path = "/".join(stack)
        st = scopes.setdefault(path, ScopeStats())
        st.add(op_class, str(jnp.dtype(out.dtype)),
               eqn_flops(eqn) * mult, eqn_bytes(eqn) * mult)


def profile(fn: Callable, *args, include_transcendental: bool = True,
            **kwargs) -> Profile:
    """Trace `fn` on the given inputs and census FLOPs/bytes per scope."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    scopes: Dict[str, ScopeStats] = {}
    _walk(closed.jaxpr, scopes, (), 1, include_transcendental)
    return Profile(scopes=scopes)
