"""Precision policies — NEAT genomes as a first-class serving surface.

A :class:`PrecisionPolicy` maps ``(phase, layer) -> (bits, mode)``:
phases are the engine's step kinds ({prefill, decode, draft, verify},
``core.scope.PHASES``), layers are addressed through the existing
placement-rule site machinery (``LayerCategory`` / ``LayerInstance`` /
``CurrentScope`` / ``CallStack`` / ``WholeProgram`` — the same families
the explorer searches). One policy therefore carries everything the
serving engine needs to apply a NEAT genome:

* **activation truncation** — each phase resolves to a
  :class:`~repro.core.placement.PlacementRule`; the engine installs ONE
  ambient :class:`PolicyRule` that dispatches on
  :func:`~repro.core.scope.current_phase` at trace time, so the fused
  qk/pv kernel hooks (``_ambient_dot_bits``) and every
  ``quantize_here`` call site resolve per-phase precision with zero new
  plumbing;
* **weight views** — a phase marked ``weights=True`` serves through
  mantissa-truncated per-layer views of the params
  (:func:`policy_params`), generalizing the PR-6 drafter's uniform
  ``drafter_params`` to policy-keyed per-site truncation;
* **serialization** — policies round-trip through JSON
  (``policy.json`` artifacts the explorer emits and the launchers
  load), and ``signature()`` is the engine's compilation-cache key: one
  cached set of compiled step programs per distinct policy tier.

The three historical precision entry points collapse onto constructors
here: ``PrecisionPolicy.uniform(bits)`` (the launchers' ambient
``WholeProgram`` rule), ``PrecisionPolicy.drafter(bits)``
(``SpecConfig.drafter_bits``), and ``PrecisionPolicy.from_genome(report,
idx)`` (an exploration result applied to serving).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.fpi import IDENTITY, MantissaTrunc
from repro.core.placement import (PlacementRule, RULE_FAMILIES,
                                  rule_from_genome, site_index_for_stack)
from repro.core.scope import PHASES, current_phase

#: full effective mantissa width per optimization target (incl. the
#: implicit bit) — bits at or above this are the identity
FULL_BITS = {"single": 24, "double": 53, "half": 8, "any": 24}


@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """One phase's precision: a placement-family genome.

    ``family`` + ``sites`` + ``bits`` are exactly the explorer's genome
    layout (``rule_from_genome``); ``default_bits`` covers scopes no
    site matches (24 = identity). ``weights=True`` additionally serves
    the phase through mantissa-truncated weight views, each param leaf
    truncated to the bits of the site its tree path resolves to."""
    family: str = "wp"
    sites: Tuple[str, ...] = ("__program__",)
    bits: Tuple[int, ...] = (24,)
    default_bits: int = 24
    mode: str = "rne"
    target: str = "single"
    weights: bool = False

    def __post_init__(self):
        if self.family not in RULE_FAMILIES:
            raise ValueError(f"unknown placement family {self.family!r}; "
                             f"one of {RULE_FAMILIES}")
        if len(self.sites) != len(self.bits):
            raise ValueError(f"{len(self.sites)} sites vs "
                             f"{len(self.bits)} bits")
        object.__setattr__(self, "sites", tuple(self.sites))
        object.__setattr__(self, "bits",
                           tuple(int(b) for b in self.bits))

    @property
    def full_bits(self) -> int:
        return FULL_BITS.get(self.target, 24)

    def is_identity(self) -> bool:
        return (all(b >= self.full_bits for b in self.bits)
                and self.default_bits >= self.full_bits)

    def rule(self) -> Optional[PlacementRule]:
        """The phase's placement rule; None when identity (so callers
        can trace with no ambient rule at all — byte-identical to
        non-policy serving)."""
        if self.is_identity():
            return None
        default = (IDENTITY if self.default_bits >= self.full_bits
                   else MantissaTrunc(int(self.default_bits), self.mode))
        return rule_from_genome(self.family, list(self.sites),
                                list(self.bits), target=self.target,
                                mode=self.mode, default=default)

    def bits_for_stack(self, stack: Tuple[str, ...]) -> int:
        """Mantissa bits this spec assigns to a scope stack — the
        weight-view analogue of rule matching."""
        site_idx = {s: i for i, s in enumerate(self.sites)}
        i = site_index_for_stack(self.family, site_idx, stack)
        return self.bits[i] if i is not None else self.default_bits

    def to_dict(self) -> dict:
        return {"family": self.family, "sites": list(self.sites),
                "bits": list(self.bits),
                "default_bits": self.default_bits, "mode": self.mode,
                "target": self.target, "weights": self.weights}

    @classmethod
    def from_dict(cls, d: dict) -> "PhaseSpec":
        return cls(family=d.get("family", "wp"),
                   sites=tuple(d.get("sites", ("__program__",))),
                   bits=tuple(d.get("bits", (24,))),
                   default_bits=int(d.get("default_bits", 24)),
                   mode=d.get("mode", "rne"),
                   target=d.get("target", "single"),
                   weights=bool(d.get("weights", False)))


IDENTITY_SPEC = PhaseSpec()


@dataclasses.dataclass
class PrecisionPolicy:
    """(phase, layer) -> (bits, mode): the serving precision surface.

    ``phases`` maps phase names to :class:`PhaseSpec`; a missing phase
    is the identity (full precision). ``raw_rules`` carries arbitrary
    :class:`PlacementRule` objects for legacy callers
    (:meth:`from_rule`) — such policies serve but do not serialize."""
    phases: Dict[str, PhaseSpec] = dataclasses.field(default_factory=dict)
    name: str = ""
    raw_rules: Dict[str, PlacementRule] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def __post_init__(self):
        for ph in list(self.phases) + list(self.raw_rules):
            if ph not in PHASES:
                raise ValueError(f"unknown phase {ph!r}; one of {PHASES}")

    # -- constructors (the collapsed legacy entry points) -------------------
    @classmethod
    def uniform(cls, bits: int, mode: str = "rne", *,
                target: str = "single", weights: bool = False,
                name: str = "") -> "PrecisionPolicy":
        """One mantissa width for every FLOP of every phase — the
        launchers' historical ambient ``WholeProgram(MantissaTrunc)``
        rule as a policy."""
        spec = PhaseSpec(family="wp", sites=("__program__",),
                         bits=(int(bits),), mode=mode, target=target,
                         weights=weights)
        return cls(phases={ph: spec for ph in PHASES},
                   name=name or f"uniform{bits}")

    @classmethod
    def drafter(cls, bits: int, mode: str = "rne", *,
                target: str = "single", name: str = "") -> "PrecisionPolicy":
        """The PR-6 speculative drafter as a policy: the draft phase
        runs at ``bits`` with truncated weight views, every other phase
        stays exact (so verification — and therefore the emitted
        tokens — are byte-identical to non-speculative serving)."""
        spec = PhaseSpec(family="wp", sites=("__program__",),
                         bits=(int(bits),), mode=mode, target=target,
                         weights=True)
        return cls(phases={"draft": spec}, name=name or f"drafter{bits}")

    @classmethod
    def from_genome(cls, report, idx: Optional[int] = None, *,
                    phases: Sequence[str] = PHASES,
                    name: str = "") -> "PrecisionPolicy":
        """Lift an exploration result into a serving policy.

        ``report`` is an :class:`~repro.core.explorer.ExplorationReport`;
        ``idx`` indexes ``report.points`` (None picks the lowest-energy
        Pareto point). Serving-objective reports carry a ready policy
        dict in the payload; classic error/energy reports apply the
        genome's rule to ``phases`` (default: all four — the ambient-rule
        semantics the legacy launchers had)."""
        pts = report.points
        if not pts:
            raise ValueError("report has no evaluated points")
        if idx is None:
            from repro.core.pareto import pareto_points
            front = pareto_points(pts) or pts
            point = min(front, key=lambda p: p.energy)
        else:
            point = pts[idx]
        if "policy" in point.payload:
            pol = cls.from_dict(point.payload["policy"])
            if name:
                pol.name = name
            return pol
        genome = point.payload["genome"]
        spec = PhaseSpec(family=report.family,
                         sites=tuple(report.sites),
                         bits=tuple(int(b) for b in genome))
        return cls(phases={ph: spec for ph in phases},
                   name=name or f"{report.family}-genome")

    @classmethod
    def from_rule(cls, rule: Optional[PlacementRule], *,
                  name: str = "") -> "PrecisionPolicy":
        """Wrap a raw :class:`PlacementRule` (applied at every phase) —
        the compatibility shim behind ``DecodeEngine(..., rule=...)``.
        ``WholeProgram(MantissaTrunc)`` rules convert losslessly to a
        serializable uniform policy; anything else is carried as an
        opaque raw rule (serves fine, will not ``to_json``)."""
        from repro.core.placement import WholeProgram
        if rule is None:
            return cls(name=name)
        if (type(rule) is WholeProgram
                and isinstance(rule.fpi, MantissaTrunc)):
            spec = PhaseSpec(family="wp", sites=("__program__",),
                             bits=(rule.fpi.bits,),
                             mode=getattr(rule.fpi, "mode", "rne"),
                             target=rule.target)
            return cls(phases={ph: spec for ph in PHASES},
                       name=name or f"uniform{rule.fpi.bits}")
        return cls(raw_rules={ph: rule for ph in PHASES},
                   name=name or "raw-rule")

    # -- phase resolution ---------------------------------------------------
    def spec_for(self, phase: Optional[str]) -> PhaseSpec:
        """The phase's spec; unphased contexts (training, direct model
        calls) resolve to "decode", the canonical compute phase."""
        return self.phases.get(phase or "decode", IDENTITY_SPEC)

    def rule_for(self, phase: Optional[str]) -> Optional[PlacementRule]:
        """The placement rule serving ``phase``; None when identity."""
        phase = phase or "decode"
        if phase in self.raw_rules:
            return self.raw_rules[phase]
        return self.spec_for(phase).rule()

    def is_identity(self) -> bool:
        return (not self.raw_rules
                and all(s.is_identity() for s in self.phases.values()))

    def as_rule(self) -> Optional["PolicyRule"]:
        """One ambient rule covering every phase (dispatching on
        :func:`current_phase` at trace time); None for the identity
        policy, so callers compile with no rule at all."""
        if self.is_identity():
            return None
        return PolicyRule(policy=self)

    def with_phase(self, phase: str, spec: PhaseSpec) -> "PrecisionPolicy":
        phases = dict(self.phases)
        phases[phase] = spec
        return dataclasses.replace(self, phases=phases)

    # -- caching / serialization --------------------------------------------
    def signature(self) -> tuple:
        """Hashable key for the engine's compilation cache — equal
        signatures may share one set of compiled step programs."""
        parts = []
        for ph in PHASES:
            if ph in self.raw_rules:
                parts.append((ph, "raw", id(self.raw_rules[ph])))
            elif ph in self.phases:
                s = self.phases[ph]
                parts.append((ph, s.family, s.sites, s.bits,
                              s.default_bits, s.mode, s.target, s.weights))
        return tuple(parts)

    def to_dict(self) -> dict:
        if self.raw_rules:
            raise ValueError(
                "policy carries raw PlacementRule objects (from_rule on a "
                "non-WholeProgram rule) and cannot be serialized; rebuild "
                "it from PhaseSpecs or constructors")
        return {"name": self.name,
                "phases": {ph: s.to_dict()
                           for ph, s in self.phases.items()}}

    @classmethod
    def from_dict(cls, d: dict) -> "PrecisionPolicy":
        return cls(phases={ph: PhaseSpec.from_dict(sd)
                           for ph, sd in d.get("phases", {}).items()},
                   name=d.get("name", ""))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=kw.pop("indent", 2), **kw)

    @classmethod
    def from_json(cls, s: str) -> "PrecisionPolicy":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "PrecisionPolicy":
        with open(path) as f:
            return cls.from_json(f.read())


@dataclasses.dataclass
class PolicyRule(PlacementRule):
    """The ambient rule a policy installs: every ``select`` resolves the
    active phase first (a trace-time thread-local, like the scope
    stack), then delegates to that phase's own rule — so one
    ``use_rule(policy.as_rule())`` context serves all four phases and
    the per-phase precision is baked into each jitted step at trace
    time. Unphased FLOPs resolve as "decode"."""
    policy: Optional[PrecisionPolicy] = None

    def select(self, stack, op_class, dtype):
        rule = self.policy.rule_for(current_phase())
        if rule is None:
            return IDENTITY
        return rule.select(stack, op_class, dtype)

    def tunable_sites(self):
        sites = []
        for ph in PHASES:
            for s in self.policy.spec_for(ph).sites:
                sites.append(f"{ph}:{s}")
        return tuple(sites)


# ---------------------------------------------------------------------------
# Policy-keyed weight views — the per-layer generalization of PR 6's
# drafter_params.
# ---------------------------------------------------------------------------

def _stack_from_path(path) -> Tuple[str, ...]:
    """Map a param-tree path to the pscope stack its layer runs under:
    ``("layers", 3, "attn", "wq") -> ("model", "layer03", "attn", "wq")``
    — so weight-view site matching reuses the same family machinery
    (``site_index_for_stack``) as activation rules."""
    frames = ["model"]
    prev = None
    for k in path:
        if hasattr(k, "key"):            # DictKey
            frame = str(k.key)
        elif hasattr(k, "idx"):          # SequenceKey
            frame = (f"layer{k.idx:02d}" if prev == "layers"
                     else str(k.idx))
        elif hasattr(k, "name"):         # GetAttrKey
            frame = str(k.name)
        else:
            frame = str(k)
        if frame != "layers":
            frames.append(frame)
        prev = frame
    return tuple(frames)


def uniform_param_views(params, bits: int, mode: str = "rne"):
    """Every float leaf truncated to ``bits`` effective mantissa bits —
    the PR-6 ``drafter_params`` transform (``serve.drafter_params``
    delegates here)."""
    from repro.utils.numerics import truncate_mantissa

    def trunc(w):
        if hasattr(w, "dtype") and jnp.issubdtype(w.dtype, jnp.floating):
            return truncate_mantissa(w, bits, mode)
        return w

    return jax.tree.map(trunc, params)


def policy_params(params, spec: PhaseSpec):
    """Weight views for one phase: each float leaf truncated to the
    bits its tree path's site resolves to under the spec's family.
    Identity specs (and ``weights=False``) return ``params`` unchanged;
    uniform (wp) specs take the exact PR-6 path, so legacy
    ``SpecConfig.drafter_bits`` views stay byte-identical."""
    if not spec.weights or spec.is_identity():
        return params
    if spec.family == "wp":
        return uniform_param_views(params, spec.bits[0], spec.mode)
    from repro.utils.numerics import truncate_mantissa
    full = spec.full_bits

    def trunc(path, w):
        if not (hasattr(w, "dtype")
                and jnp.issubdtype(w.dtype, jnp.floating)):
            return w
        b = spec.bits_for_stack(_stack_from_path(path))
        return truncate_mantissa(w, b, spec.mode) if b < full else w

    return jax.tree_util.tree_map_with_path(trunc, params)
