"""The NEAT exploration driver — paper §IV steps 1-6 end to end.

Given an application (a pure JAX function with `pscope`-annotated regions
and train/test input sets), the explorer:

1. profiles it (FLOP census per scope, top-N function selection),
2. compiles one dynamic-bits evaluator per placement family,
3. runs NSGA-II over per-site mantissa widths (<= 400 unique configs),
4. reports the (error, energy) tradeoff points, lower convex hull and
   quantized savings, and
5. re-evaluates frontier configs on unseen test inputs for the paper's
   robustness correlations (Table III).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_mod
from repro.core.interpreter import neat_transform_dynamic
from repro.core.nsga2 import Evaluated, NSGA2Result, nsga2
from repro.core.pareto import (TradeoffPoint, correlation, lower_convex_hull,
                               pareto_points, savings_at_threshold)
from repro.core.placement import default_categorizer, rule_from_genome
from repro.core.profiler import Profile, profile
from repro.utils.numerics import float_spec


def default_error_fn(approx, exact) -> float:
    """Relative L2 error across all output leaves (paper's 'error rate':
    relative difference vs. the no-approximation baseline)."""
    num = 0.0
    den = 0.0
    for a, e in zip(jax.tree.leaves(approx), jax.tree.leaves(exact)):
        a = np.asarray(a, dtype=np.float64)
        e = np.asarray(e, dtype=np.float64)
        if not np.all(np.isfinite(a)):
            return float("inf")
        num += float(np.sum((a - e) ** 2))
        den += float(np.sum(e ** 2))
    return math.sqrt(num / max(den, 1e-300))


@dataclasses.dataclass
class ExplorationTask:
    name: str
    fn: Callable
    train_inputs: List[tuple]
    test_inputs: List[tuple]
    error_fn: Callable = default_error_fn
    target: str = "single"           # paper §IV step 2 optimization target
    mode: str = "rne"


@dataclasses.dataclass
class ExplorationReport:
    task: str
    family: str
    sites: List[str]
    points: List[TradeoffPoint]          # every evaluated config
    hull: List[TradeoffPoint]
    n_evals: int
    baseline_fpu_pj: float
    baseline_mem_pj: float
    flop_coverage: float                 # paper: >=98% for top-10
    robustness_error_r: float = 1.0
    robustness_energy_r: float = 1.0

    def savings(self, thr: float) -> float:
        return savings_at_threshold(self.points, thr)

    def mem_savings(self, thr: float) -> float:
        pts = [TradeoffPoint(p.error, p.payload["mem"], p.payload)
               for p in self.points]
        return savings_at_threshold(pts, thr)

    def best_genome(self, thr: float) -> Optional[Tuple[int, ...]]:
        ok = [p for p in self.points if p.error <= thr]
        if not ok:
            return None
        return min(ok, key=lambda p: p.energy).payload["genome"]


def sites_for_family(prof: Profile, family: str, n_sites: int) -> List[str]:
    if family == "wp":
        return ["__program__"]
    if family == "plc":
        cats = {}
        for path, st in prof.scopes.items():
            if not path:
                continue
            cat = default_categorizer(tuple(path.split("/")))
            # skip compiler-internal scopes (einsum specs etc.)
            if not cat or any(c in cat for c in "->,<(["):
                continue
            cats[cat] = cats.get(cat, 0) + st.flops
        return [k for k, _ in sorted(cats.items(), key=lambda kv: -kv[1])[:n_sites]]
    if family == "pli":
        return prof.top_paths(n_sites)
    # cip / fcs: top FLOP-intensive *functions* (innermost frames) plus the
    # rule's tunable default FPI (paper §III-B4: unmatched FLOPs use "a
    # default implementation") — this also makes the per-function space a
    # strict superset of WP.
    return prof.top_functions(n_sites) + ["__default__"]


def explore(task: ExplorationTask, *, family: str = "cip", n_sites: int = 10,
            pop_size: int = 40, n_gen: int = 9, max_evals: int = 400,
            seed: int = 0, robustness: bool = True,
            include_transcendental: bool = False) -> ExplorationReport:
    # 1. profile (paper step 1) -- census on the first training input
    prof = profile(task.fn, *task.train_inputs[0])
    sites = sites_for_family(prof, family, n_sites)
    coverage = prof.coverage(sites) if family in ("cip", "fcs") else 1.0

    full_bits = 53 if task.target == "double" else (
        8 if task.target == "half" else 24)

    # 2. exact baselines + energy baseline
    exact = [jax.tree.map(np.asarray, task.fn(*inp))
             for inp in task.train_inputs]
    base = energy_mod.static_energy(prof, None)

    # 3. one compiled dynamic-bits evaluator
    g = neat_transform_dynamic(task.fn, family, sites, target=task.target,
                               mode=task.mode,
                               include_transcendental=include_transcendental)
    g = jax.jit(g)

    extras: Dict[Tuple[int, ...], Dict] = {}

    def eval_genome(genome: Tuple[int, ...]) -> Tuple[float, float]:
        bits = jnp.asarray(genome, jnp.int32)
        errs = []
        for inp, ex in zip(task.train_inputs, exact):
            out = g(bits, *inp)
            errs.append(task.error_fn(jax.tree.map(np.asarray, out), ex))
        err = float(np.median(errs))
        rule = rule_from_genome(family, sites, genome, target=task.target,
                                mode=task.mode)
        rep = energy_mod.static_energy(prof, rule)
        e_fpu = rep.fpu_pj / max(base.fpu_pj, 1e-30)
        e_mem = rep.mem_pj / max(base.mem_pj, 1e-30)
        extras[tuple(genome)] = {"mem": e_mem, "genome": tuple(genome)}
        # clamp unusable configs so NSGA-II can still rank them
        if not math.isfinite(err):
            err = 1e9
        return (e_fpu, err)

    # Seed the population with the "diagonal" (uniform-bits) genomes: the
    # per-function families then strictly contain the whole-program
    # solutions, so CIP/FCS/PLC/PLI can never do worse than WP at equal
    # budget (the paper observes the GA occasionally losing to WP without
    # this — Fig. 5 Fluidanimate/Ferret/Radar).
    n_sites_eff = len(sites)
    diag_bits = [b for b in range(2, full_bits + 1, 2)] + [full_bits]
    diag_bits = sorted(set(diag_bits))[: max(4, max_evals // 6)]
    seeds = [(b,) * n_sites_eff for b in diag_bits]

    res: NSGA2Result = nsga2(
        eval_genome, n_genes=len(sites), low=1, high=full_bits,
        pop_size=pop_size, n_gen=n_gen, max_evals=max_evals, seed=seed,
        seed_genomes=seeds)

    points = [TradeoffPoint(error=e.objectives[1], energy=e.objectives[0],
                            payload=extras[e.genome])
              for e in res.evaluated]
    hull = lower_convex_hull(points)

    report = ExplorationReport(
        task=task.name, family=family, sites=sites, points=points,
        hull=hull, n_evals=res.n_evals,
        baseline_fpu_pj=base.fpu_pj, baseline_mem_pj=base.mem_pj,
        flop_coverage=coverage)

    # 5. robustness on unseen inputs (paper §V-G)
    if robustness and task.test_inputs:
        test_exact = [jax.tree.map(np.asarray, task.fn(*inp))
                      for inp in task.test_inputs]
        frontier = pareto_points(points)[:16]
        tr_err, te_err, tr_e, te_e = [], [], [], []
        for p in frontier:
            bits = jnp.asarray(p.payload["genome"], jnp.int32)
            errs = [task.error_fn(jax.tree.map(np.asarray, g(bits, *inp)), ex)
                    for inp, ex in zip(task.test_inputs, test_exact)]
            errs = [e if math.isfinite(e) else 1e9 for e in errs]
            tr_err.append(p.error)
            te_err.append(float(np.median(errs)))
            tr_e.append(p.energy)
            te_e.append(p.energy)   # static energy is input-independent
        report.robustness_error_r = correlation(tr_err, te_err)
        report.robustness_energy_r = correlation(tr_e, te_e)

    return report
