"""The NEAT exploration driver — paper §IV steps 1-6 end to end.

Given an application (a pure JAX function with `pscope`-annotated regions
and train/test input sets), the explorer:

1. profiles it (FLOP census per scope, top-N function selection),
2. compiles one dynamic-bits evaluator per placement family,
3. runs NSGA-II over per-site mantissa widths (<= 400 unique configs),
4. reports the (error, energy) tradeoff points, lower convex hull and
   quantized savings, and
5. re-evaluates frontier configs on unseen test inputs for the paper's
   robustness correlations (Table III).

The search is **population-batched**: NSGA-II is driven through its
ask/tell API and every generation's genome batch is evaluated in ONE
compiled call — ``jax.vmap`` over the bits axis (optionally sharded
across ``jax.devices()`` via ``launch/mesh.make_population_mesh``), with
the train inputs stacked and vmapped as a second batch axis. The energy
objective is a pluggable :class:`~repro.core.estimators.EnergyEstimator`
(``energy="static" | "dynamic"``): static energy is the precomputed
coefficient tensor (one einsum per batch); dynamic energy rides the same
dispatch as exact per-genome bit-census accumulators threaded through
the interpreter, so the trailing-zero estimator costs zero extra
dispatches. ``explore(..., batched=False)`` keeps the historical
one-genome-at-a-time path for benchmarking and parity tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import energy as energy_mod
from repro.core.estimators import StaticEnergyEstimator, make_estimator
from repro.core.interpreter import (neat_transform_dynamic,
                                    neat_transform_population)
from repro.core.nsga2 import NSGA2, NSGA2Result
from repro.core.pareto import (TradeoffPoint, correlation, lower_convex_hull,
                               pareto_points, savings_at_threshold)
from repro.core.placement import default_categorizer, rule_from_genome
from repro.core.profiler import Profile, profile
from repro.launch.mesh import make_population_mesh, population_sharding


def default_error_fn(approx, exact) -> float:
    """Relative L2 error across all output leaves (paper's 'error rate':
    relative difference vs. the no-approximation baseline)."""
    num = 0.0
    den = 0.0
    for a, e in zip(jax.tree.leaves(approx), jax.tree.leaves(exact)):
        a = np.asarray(a, dtype=np.float64)
        e = np.asarray(e, dtype=np.float64)
        if not np.all(np.isfinite(a)):
            return float("inf")
        num += float(np.sum((a - e) ** 2))
        den += float(np.sum(e ** 2))
    return math.sqrt(num / max(den, 1e-300))


def _rel_l2_multi(outs, exact):
    """On-device batched default_error_fn: output leaves (I, P, ...) vs
    exact leaves (I, ...) -> (I, P) float64 errors. Reduced in f64 (the
    call site traces under ``enable_x64``) so the result matches the host
    path's numpy-f64 reduction."""
    num, den, finite = 0.0, 0.0, True
    for a, e in zip(jax.tree.leaves(outs), jax.tree.leaves(exact)):
        a64 = a.astype(jnp.float64)
        e64 = e.astype(jnp.float64)
        red = tuple(range(2, a64.ndim))
        num = num + jnp.sum((a64 - jnp.expand_dims(e64, 1)) ** 2, axis=red)
        den = den + jnp.sum(e64 ** 2,
                            axis=tuple(range(1, e64.ndim)))[:, None]
        finite = finite & jnp.all(jnp.isfinite(a64), axis=red)
    err = jnp.sqrt(num / jnp.maximum(den, 1e-300))
    return jnp.where(finite, err, jnp.inf)


def _rel_l2_single(outs, exact):
    """Single-input variant: output leaves (P, ...) vs unbatched exact
    leaves -> (P,) float64 errors."""
    num, den, finite = 0.0, 0.0, True
    for a, e in zip(jax.tree.leaves(outs), jax.tree.leaves(exact)):
        a64 = a.astype(jnp.float64)
        e64 = e.astype(jnp.float64)
        red = tuple(range(1, a64.ndim))
        num = num + jnp.sum((a64 - e64[None]) ** 2, axis=red)
        den = den + jnp.sum(e64 ** 2)
        finite = finite & jnp.all(jnp.isfinite(a64), axis=red)
    err = jnp.sqrt(num / jnp.maximum(den, 1e-300))
    return jnp.where(finite, err, jnp.inf)


@dataclasses.dataclass
class ExplorationTask:
    name: str
    fn: Callable
    train_inputs: List[tuple]
    test_inputs: List[tuple]
    error_fn: Callable = default_error_fn
    target: str = "single"           # paper §IV step 2 optimization target
    mode: str = "rne"


@dataclasses.dataclass
class ServingTask:
    """Serving-objective exploration input: a model served through the
    continuous engine, genomes scored by (1 - draft acceptance) vs
    estimated pJ/token. ``explore(ServingTask(...))`` — or any task with
    ``objectives="serving"`` — selects this mode.

    Two search spaces:

    * ``bits_grid`` set — the legacy drafter-bits sweep: genome = one
      uniform drafter mantissa width, exhaustively enumerated (exactly
      the deprecated ``explore_serving`` behavior).
    * ``bits_grid`` None — NSGA-II over the full ``(phase, layer)``
      grid: genome = one mantissa width per (phase in ``phases``) ×
      (site of ``family``/``n_sites``, from an abstract profile of the
      decode cell), each genome compiled into a
      :class:`~repro.core.policy.PrecisionPolicy` and served end to end
      with ``estimate_energy=True``. Search budget lives HERE
      (``pop_size``/``n_gen``/``max_evals``), not on ``explore()``'s
      offline kwargs — every candidate policy costs an engine
      compilation, so defaults are deliberately small."""
    model: object
    params: object
    prompts: List[List[int]]
    serve_cfg: Optional[object] = None       # ServeConfig; None = default
    max_new_tokens: int = 32
    k: int = 4                               # speculation window
    phases: Tuple[str, ...] = ("draft",)     # genome's phase axis
    family: str = "plc"                      # genome's layer axis
    n_sites: int = 4
    mode: str = "rne"
    bits_grid: Optional[Sequence[int]] = None
    pop_size: int = 8
    n_gen: int = 2
    max_evals: int = 20
    name: str = "serving"


@dataclasses.dataclass
class ExplorationReport:
    task: str
    family: str
    sites: List[str]
    points: List[TradeoffPoint]          # every evaluated config
    hull: List[TradeoffPoint]
    n_evals: int
    baseline_fpu_pj: float
    baseline_mem_pj: float
    flop_coverage: float                 # paper: >=98% for top-10
    robustness_error_r: float = 1.0
    robustness_energy_r: float = 1.0
    n_dispatches: int = 0                # compiled evaluator calls issued
    batched: bool = True
    energy_estimator: str = "static"     # objective the search ranked on

    def savings(self, thr: float) -> float:
        return savings_at_threshold(self.points, thr)

    def mem_savings(self, thr: float) -> float:
        pts = [TradeoffPoint(p.error, p.payload["mem"], p.payload)
               for p in self.points]
        return savings_at_threshold(pts, thr)

    def best_genome(self, thr: float) -> Optional[Tuple[int, ...]]:
        ok = [p for p in self.points if p.error <= thr]
        if not ok:
            return None
        return min(ok, key=lambda p: p.energy).payload["genome"]


def sites_for_family(prof: Profile, family: str, n_sites: int) -> List[str]:
    if family == "wp":
        return ["__program__"]
    if family == "plc":
        cats = {}
        for path, st in prof.scopes.items():
            if not path:
                continue
            cat = default_categorizer(tuple(path.split("/")))
            # skip compiler-internal scopes (einsum specs etc.)
            if not cat or any(c in cat for c in "->,<(["):
                continue
            cats[cat] = cats.get(cat, 0) + st.flops
        return [k for k, _ in sorted(cats.items(), key=lambda kv: -kv[1])[:n_sites]]
    if family == "pli":
        return prof.top_paths(n_sites)
    # cip / fcs: top FLOP-intensive *functions* (innermost frames) plus the
    # rule's tunable default FPI (paper §III-B4: unmatched FLOPs use "a
    # default implementation") — this also makes the per-function space a
    # strict superset of WP.
    return prof.top_functions(n_sites) + ["__default__"]


class PopulationEvaluator:
    """Batched genome-error evaluation for one (task, family, sites).

    ``errors_matrix(genomes, inputs, exact)`` returns the (P, n_inputs)
    error matrix. In batched mode all genomes — and, when the inputs
    stack, all inputs — are evaluated by a single jitted vmapped call;
    genome batches are padded to a fixed bucket so the whole NSGA-II run
    reuses one compiled program, and the population axis is (optionally)
    sharded across ``jax.devices()``. ``n_dispatches`` counts compiled
    evaluator calls, the metric the batching exists to collapse.
    """

    def __init__(self, task: ExplorationTask, family: str,
                 sites: Sequence[str], *, include_transcendental: bool = False,
                 pop_hint: int = 40, shard: bool | str = "auto",
                 collect_bits: bool = False):
        self.task = task
        self.error_fn = task.error_fn
        # collect_bits: thread exact per-genome bit-census accumulators
        # (the dynamic energy estimator's input) through every dispatch
        self.collect_bits = collect_bits
        kw = dict(target=task.target, mode=task.mode,
                  include_transcendental=include_transcendental,
                  collect_bits=collect_bits)
        self._g_raw = neat_transform_dynamic(task.fn, family, sites, **kw)
        self.g = jax.jit(self._g_raw)
        pop = neat_transform_population(task.fn, family, sites, **kw)
        self._pop_raw = pop
        self._pop_call = jax.jit(pop)
        # census stash of the most recent dispatch, one entry per input:
        # channel metadata is per input *signature* (shapes enter the
        # weight = flops/numel scales), so heterogeneous-shape input
        # lists carry distinct channels per input
        self.last_bit_counts_list = None       # per input: (P, C_i) int64
        self.last_serial_bit_counts = None     # per input: (C_i,) int64
        self.last_serial_bit_channels = None   # per input: channel tuple
        self.bit_channels_list = None          # per input: channel tuple

        def multi(bits, *stacked):       # extra vmap over the input axis
            return jax.vmap(lambda *inp: pop(bits, *inp))(*stacked)

        self._multi_call = jax.jit(multi)
        self.n_dispatches = 0
        # the default relative-L2 reduction runs on-device (jit'd,
        # population-batched, f64 under enable_x64) so only the (P, I)
        # scalar error matrix leaves the device; custom error callables
        # keep the host path (full outputs transferred, then reduced).
        self._on_device_err = task.error_fn is default_error_fn
        self._err_multi = jax.jit(_rel_l2_multi)
        self._err_single = jax.jit(_rel_l2_single)
        # stacked-input memo: the train/test input lists are constant
        # across generations, so leaf-wise stacking + upload happens once
        # per list, not once per ask/tell round. Holding the inputs ref
        # keeps its id() valid for the lifetime of the entry.
        self._stack_cache: Dict[int, tuple] = {}
        self._exact_cache: Dict[tuple, tuple] = {}

        if shard == "auto":
            shard = len(jax.devices()) > 1
        self.mesh = make_population_mesh() if shard else None
        self._step = self.mesh.devices.size if self.mesh is not None else 1
        self._bucket = -(-max(pop_hint, 1) // self._step) * self._step

    # -- helpers -------------------------------------------------------------
    @staticmethod
    def stack_inputs(inputs: Sequence[tuple]):
        """Stack a homogeneous input list leaf-wise (axis 0 = input index);
        None when the inputs don't stack (ragged shapes/structures)."""
        if len(inputs) < 2:
            return None
        try:
            return jax.tree.map(lambda *xs: jnp.stack(xs), *inputs)
        except (ValueError, TypeError):
            return None

    def _padded_bits(self, genomes: Sequence[Sequence[int]]) -> jnp.ndarray:
        bits = np.asarray([[int(v) for v in g] for g in genomes], np.int32)
        n = len(bits)
        size = self._bucket if n <= self._bucket \
            else -(-n // self._step) * self._step
        if size > n:       # pad with copies of the first row, sliced off later
            bits = np.concatenate([bits, np.repeat(bits[:1], size - n, 0)])
        arr = jnp.asarray(bits)
        if self.mesh is not None:
            arr = jax.device_put(arr, population_sharding(self.mesh))
        return arr

    def _subtree(self, host, index) -> object:
        return jax.tree.map(lambda x: x[index], host)

    @property
    def bit_channels(self) -> tuple:
        """Channels of the last dispatch's first input — a convenience
        for homogeneous input lists, where every input shares them."""
        return self.bit_channels_list[0] if self.bit_channels_list else ()

    @property
    def last_bit_counts(self):
        """(P, I, C) stacked counts of the last dispatch — valid when the
        inputs share one census signature (homogeneous shapes, the common
        case); None before any collecting dispatch."""
        if self.last_bit_counts_list is None:
            return None
        return np.stack(self.last_bit_counts_list, axis=1)

    def _stacked_exact(self, exact: Sequence):
        """Device-resident leaf-wise stack of the exact baselines (axis 0
        = input index), memoized per exact list like the input stack."""
        key = ("stacked", id(exact))
        if key not in self._exact_cache:
            with enable_x64():   # don't downcast f64 baselines on upload
                dev = jax.tree.map(lambda *xs: jnp.stack(
                    [jnp.asarray(x) for x in xs]), *exact)
            self._exact_cache[key] = (exact, dev)
        return self._exact_cache[key][1]

    def _device_exact(self, exact: Sequence, i: int):
        """Device-resident copy of one exact baseline (the unstackable-
        inputs path), memoized so generations don't re-upload it."""
        key = ("single", id(exact))
        if key not in self._exact_cache:
            with enable_x64():
                dev = [jax.tree.map(jnp.asarray, e) for e in exact]
            self._exact_cache[key] = (exact, dev)
        return self._exact_cache[key][1][i]

    # -- batched path --------------------------------------------------------
    def errors_matrix(self, genomes: Sequence[Sequence[int]],
                      inputs: Sequence[tuple],
                      exact: Sequence) -> np.ndarray:
        """(len(genomes), len(inputs)) raw error matrix, one compiled call
        when the inputs stack, one per input otherwise. With the default
        error_fn the relative-L2 reduction also runs on-device, so only
        the scalar matrix crosses the host boundary."""
        n = len(genomes)
        if n == 0:
            if self.collect_bits:
                self.last_bit_counts_list = [np.zeros((0, 0), np.int64)
                                             for _ in inputs]
                self.bit_channels_list = [() for _ in inputs]
            return np.zeros((0, len(inputs)))
        bits = self._padded_bits(genomes)
        out = np.empty((n, len(inputs)))
        if id(inputs) not in self._stack_cache:
            self._stack_cache[id(inputs)] = (inputs,
                                             self.stack_inputs(inputs))
        _, stacked = self._stack_cache[id(inputs)]
        if stacked is not None:
            outs = self._multi_call(bits, *stacked)   # leaves (I, P, ...)
            self.n_dispatches += 1
            if self.collect_bits:                     # counts (I, Ppad, C)
                outs, counts = outs
                # stacked inputs share one signature: inputs[0]'s
                chans = self._pop_raw.inner.bit_channels_for(*inputs[0])
                cc = np.asarray(counts, np.int64)[:, :n]
                self.bit_channels_list = [chans] * len(inputs)
                self.last_bit_counts_list = [cc[i]
                                             for i in range(len(inputs))]
            if self._on_device_err:
                with enable_x64():
                    mat = self._err_multi(outs, self._stacked_exact(exact))
                out[:] = np.asarray(mat).T[:n]
            else:
                host = jax.tree.map(np.asarray, outs)
                for i in range(len(inputs)):
                    for p in range(n):
                        out[p, i] = self.error_fn(
                            self._subtree(host, (i, p)), exact[i])
        else:
            count_cols, chan_cols = [], []
            for i, inp in enumerate(inputs):
                outs = self._pop_call(bits, *inp)     # leaves (P, ...)
                self.n_dispatches += 1
                if self.collect_bits:                 # counts (Ppad, C_i)
                    outs, counts = outs
                    count_cols.append(np.asarray(counts, np.int64)[:n])
                    # per-input signature: channels can differ per input
                    chan_cols.append(
                        self._pop_raw.inner.bit_channels_for(*inp))
                if self._on_device_err:
                    with enable_x64():
                        col = self._err_single(outs,
                                               self._device_exact(exact, i))
                    out[:, i] = np.asarray(col)[:n]
                else:
                    host = jax.tree.map(np.asarray, outs)
                    for p in range(n):
                        out[p, i] = self.error_fn(self._subtree(host, p),
                                                  exact[i])
            if self.collect_bits:
                self.bit_channels_list = chan_cols
                self.last_bit_counts_list = count_cols
        return out

    # -- historical serial path (benchmarks / parity tests) ------------------
    def errors_serial(self, genome: Sequence[int], inputs: Sequence[tuple],
                      exact: Sequence) -> List[float]:
        bits = jnp.asarray([int(v) for v in genome], jnp.int32)
        errs = []
        count_rows, chan_rows = [], []
        for inp, ex in zip(inputs, exact):
            out = self.g(bits, *inp)
            self.n_dispatches += 1
            if self.collect_bits:
                out, counts = out
                count_rows.append(np.asarray(counts, np.int64))
                chan_rows.append(self._g_raw.bit_channels_for(*inp))
            errs.append(self.error_fn(jax.tree.map(np.asarray, out), ex))
        if self.collect_bits:
            self.last_serial_bit_counts = count_rows
            self.last_serial_bit_channels = chan_rows
        return errs


def _serial_eval(ev: PopulationEvaluator, genomes, inputs, exact,
                 collect_census: bool) -> np.ndarray:
    """Per-genome serial error evaluation; when the estimator needs the
    bit census, stack each genome's per-input counts into the evaluator's
    ``last_bit_counts`` (the same layout the batched dispatch produces)."""
    rows, pcounts = [], []
    for g in genomes:
        rows.append(ev.errors_serial(g, inputs, exact))
        if collect_census:
            pcounts.append(ev.last_serial_bit_counts)
    if collect_census:
        if pcounts:
            ev.last_bit_counts_list = [
                np.stack([pc[i] for pc in pcounts])      # (P, C_i)
                for i in range(len(inputs))]
            ev.bit_channels_list = list(ev.last_serial_bit_channels)
        else:
            ev.last_bit_counts_list = [np.zeros((0, 0), np.int64)
                                       for _ in inputs]
            ev.bit_channels_list = [() for _ in inputs]
    return np.asarray(rows) if rows else np.zeros((0, len(inputs)))


def explore(task, *, objectives: str = "error-energy",
            family: str = "cip", n_sites: int = 10,
            pop_size: int = 40, n_gen: int = 9, max_evals: int = 400,
            seed: int = 0, robustness: bool = True,
            include_transcendental: bool = False,
            batched: bool = True,
            shard: bool | str = "auto",
            energy="static") -> ExplorationReport:
    """The one exploration entry point.

    ``objectives`` selects the search mode: ``"error-energy"`` (default)
    is the paper's offline search over an :class:`ExplorationTask`;
    ``"serving"`` scores genomes by serving objectives — ``(1 -
    acceptance, estimated pJ/token)`` — over a :class:`ServingTask`
    (passing a ``ServingTask`` implies it). Both return the same
    :class:`ExplorationReport` shape.

    ``energy`` selects the offline energy objective: ``"static"``
    (coefficient tensor, input-independent), ``"dynamic"`` (trailing-zero
    bit census of the actual values, threaded through the same vmapped
    dispatch — zero extra dispatches per generation), a registered
    estimator name, or a ready-made
    :class:`~repro.core.estimators.EnergyEstimator`."""
    if objectives not in ("error-energy", "serving"):
        raise ValueError(f"unknown objectives {objectives!r}; one of "
                         "('error-energy', 'serving')")
    if isinstance(task, ServingTask) or objectives == "serving":
        if not isinstance(task, ServingTask):
            raise TypeError('objectives="serving" takes a ServingTask; '
                            f"got {type(task).__name__}")
        if task.bits_grid is not None:
            return _serving_grid(task, seed=seed)
        return _serving_nsga(task, seed=seed)
    # 1. profile (paper step 1) -- census on the first training input
    prof = profile(task.fn, *task.train_inputs[0])
    sites = sites_for_family(prof, family, n_sites)
    coverage = prof.coverage(sites) if family in ("cip", "fcs") else 1.0

    full_bits = 53 if task.target == "double" else (
        8 if task.target == "half" else 24)

    # 2. exact baselines + pluggable energy estimator (shared static
    #    identity baseline, so static/dynamic fronts share one axis)
    exact = [jax.tree.map(np.asarray, task.fn(*inp))
             for inp in task.train_inputs]
    estimator = make_estimator(energy, prof, family, sites,
                               target=task.target,
                               include_transcendental=include_transcendental)
    base = estimator.baseline()

    # 3. one compiled population evaluator
    ev = PopulationEvaluator(
        task, family, sites, include_transcendental=include_transcendental,
        pop_hint=pop_size, shard=shard if batched else False,
        collect_bits=estimator.needs_bit_census)

    # Seed the population with the "diagonal" (uniform-bits) genomes: the
    # per-function families then strictly contain the whole-program
    # solutions, so CIP/FCS/PLC/PLI can never do worse than WP at equal
    # budget (the paper observes the GA occasionally losing to WP without
    # this — Fig. 5 Fluidanimate/Ferret/Radar).
    n_sites_eff = len(sites)
    diag_bits = [b for b in range(2, full_bits + 1, 2)] + [full_bits]
    diag_bits = sorted(set(diag_bits))[: max(4, max_evals // 6)]
    seeds = [(b,) * n_sites_eff for b in diag_bits]

    # 4. NSGA-II through ask/tell: one evaluator dispatch per generation
    opt = NSGA2(n_genes=len(sites), low=1, high=full_bits,
                pop_size=pop_size, n_gen=n_gen, max_evals=max_evals,
                seed=seed, seed_genomes=seeds)
    extras: Dict[Tuple[int, ...], Dict] = {}
    while not opt.done:
        batch = opt.ask()
        if batched:
            err_mat = ev.errors_matrix(batch, task.train_inputs, exact)
            fpu, mem = estimator.population(batch, evaluator=ev)
            e_fpu = fpu / max(base.fpu_pj, 1e-30)
            e_mem = mem / max(base.mem_pj, 1e-30)
        elif type(estimator) is StaticEnergyEstimator:
            # historical per-genome path for the canonical static
            # estimator only (subclasses take the protocol branch):
            # scalar static_energy is the parity reference the batched
            # coefficient tensor is gated on
            err_mat = _serial_eval(ev, batch, task.train_inputs, exact,
                                   False)
            reps = [energy_mod.static_energy(
                        prof, rule_from_genome(family, sites, g,
                                               target=task.target,
                                               mode=task.mode))
                    for g in batch]
            e_fpu = np.asarray([r.fpu_pj for r in reps]) \
                / max(base.fpu_pj, 1e-30)
            e_mem = np.asarray([r.mem_pj for r in reps]) \
                / max(base.mem_pj, 1e-30)
        else:                      # serial dynamic / custom estimators
            err_mat = _serial_eval(ev, batch, task.train_inputs, exact,
                                   estimator.needs_bit_census)
            fpu, mem = estimator.population(batch, evaluator=ev)
            e_fpu = fpu / max(base.fpu_pj, 1e-30)
            e_mem = mem / max(base.mem_pj, 1e-30)
        objs = []
        for i, g in enumerate(batch):
            err = float(np.median(err_mat[i]))
            # clamp unusable configs so NSGA-II can still rank them
            if not math.isfinite(err):
                err = 1e9
            extras[tuple(g)] = {"mem": float(e_mem[i]), "genome": tuple(g)}
            objs.append((float(e_fpu[i]), err))
        opt.tell(batch, objs)
    res: NSGA2Result = opt.result()

    points = [TradeoffPoint(error=e.objectives[1], energy=e.objectives[0],
                            payload=extras[e.genome])
              for e in res.evaluated]
    hull = lower_convex_hull(points)

    report = ExplorationReport(
        task=task.name, family=family, sites=sites, points=points,
        hull=hull, n_evals=res.n_evals,
        baseline_fpu_pj=base.fpu_pj, baseline_mem_pj=base.mem_pj,
        flop_coverage=coverage, batched=batched,
        energy_estimator=estimator.name)

    # 5. robustness on unseen inputs (paper §V-G) — the frontier re-check
    #    is itself one batched call over (frontier genomes x test inputs)
    if robustness and task.test_inputs:
        test_exact = [jax.tree.map(np.asarray, task.fn(*inp))
                      for inp in task.test_inputs]
        frontier = pareto_points(points)[:16]
        genomes = [p.payload["genome"] for p in frontier]
        if batched:
            mat = ev.errors_matrix(genomes, task.test_inputs, test_exact)
        else:
            mat = _serial_eval(ev, genomes, task.test_inputs, test_exact,
                               estimator.needs_bit_census)
        # dynamic energy is input-dependent: re-estimate the frontier's
        # energy on the unseen inputs from the same dispatch's census
        te_energy = None
        if estimator.needs_bit_census and genomes:
            te_fpu = estimator.fpu_matrix(ev, genomes).mean(axis=1)
            te_energy = te_fpu / max(base.fpu_pj, 1e-30)
        tr_err, te_err, tr_e, te_e = [], [], [], []
        for j, (p, row) in enumerate(zip(frontier, mat)):
            errs = [e if math.isfinite(e) else 1e9 for e in row]
            tr_err.append(p.error)
            te_err.append(float(np.median(errs)))
            tr_e.append(p.energy)
            te_e.append(float(te_energy[j]) if te_energy is not None
                        else p.energy)   # static: input-independent
        report.robustness_error_r = correlation(tr_err, te_err)
        report.robustness_energy_r = correlation(tr_e, te_e)

    report.n_dispatches = ev.n_dispatches
    return report


def _serving_grid(task: ServingTask, *, seed: int = 0
                  ) -> ExplorationReport:
    """Serving-objective exploration, drafter-bits grid: genome = the
    speculative drafter's mantissa bits, objectives = (draft acceptance,
    drafter energy).

    Each genome serves the same workload through the continuous engine
    with a ``SpecConfig(drafter_bits=bits)`` drafter; the error axis is
    ``1 - acceptance_rate`` (the fraction of drafts the full-precision
    target rejected — the serving analogue of output error, since every
    rejection costs a wasted draft row) and the energy axis is the
    drafter's FPU+mem pJ **per speculation window** (one fused k-cell
    draft): the (B, 1) decode cell profiled **abstractly**
    (:func:`~repro.core.estimators.abstract_step_energy` — ``jaxpr``
    walk on ``ShapeDtypeStruct``s, zero device dispatches beyond the
    serve steps themselves, and exact for the ``MantissaTrunc`` family)
    times ``k``. Per-window — not run-total — energy is the genome's
    *intrinsic* cost: fewer bits cheapen every draft cell but lose
    acceptance, so the grid traces a genuine acceptance-vs-energy front
    (a run-total axis would fold the error objective back into energy,
    since rejections spawn extra windows). The run-level bill,
    ``energy * stats.draft_steps``, is in ``payload["total_pj"]``.
    Greedy outputs are byte-identical across genomes (verification is
    exact), which is why acceptance — not correctness — is the serving
    error axis.

    Returns the standard :class:`ExplorationReport` (``points`` carry
    ``payload["bits" | "acceptance" | "tokens_per_s" | "total_pj" |
    "stats"]``)."""
    import time as _time

    from repro.core.estimators import abstract_step_energy
    from repro.core.fpi import MantissaTrunc
    from repro.core.placement import WholeProgram
    from repro.core.policy import PrecisionPolicy
    from repro.serve.engine import DecodeEngine, ServeConfig, SpecConfig

    model, params, prompts = task.model, task.params, task.prompts
    bits_grid, k, mode = task.bits_grid, task.k, task.mode
    max_new_tokens = task.max_new_tokens
    base_cfg = (task.serve_cfg if task.serve_cfg is not None
                else ServeConfig())
    if base_cfg.engine != "continuous":
        raise ValueError("serving exploration requires the continuous "
                         "engine")

    # abstract decode-cell census: one trace, reused for every genome's
    # static charge (the contiguous cell — the drafter's arithmetic is
    # layout-independent, only the token plumbing differs)
    a_params = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), params)
    a_cache = jax.eval_shape(
        lambda: model.init_cache(base_cfg.batch_slots, base_cfg.max_len))
    a_toks = jax.ShapeDtypeStruct((base_cfg.batch_slots, 1), jnp.int32)

    def cell_energy(rule):
        return abstract_step_energy(
            lambda p, c, t: model.decode_step(p, c, t),
            a_params, a_cache, a_toks, rule=rule)

    base_rep = cell_energy(None)
    points: List[TradeoffPoint] = []
    for bits in bits_grid:
        cfg = dataclasses.replace(
            base_cfg, spec=SpecConfig(k=k, drafter_bits=int(bits),
                                      mode=mode))
        eng = DecodeEngine(model, params, cfg)
        t0 = _time.perf_counter()
        eng.generate(prompts, max_new_tokens=max_new_tokens)
        dt = _time.perf_counter() - t0
        st = eng.stats
        rule = WholeProgram(fpi=MantissaTrunc(bits=int(bits), mode=mode))
        rep = cell_energy(rule)
        points.append(TradeoffPoint(
            error=1.0 - st.acceptance_rate,
            energy=rep.total_pj * k,          # one draft window's pJ
            payload={"genome": (int(bits),), "bits": int(bits),
                     "mem": rep.mem_pj * k,
                     "acceptance": st.acceptance_rate,
                     "tokens_per_s": st.tokens_out / max(dt, 1e-9),
                     "total_pj": rep.total_pj * k * st.draft_steps,
                     "policy": PrecisionPolicy.drafter(
                         int(bits), mode,
                         name=f"drafter-{int(bits)}b").to_dict(),
                     "stats": st}))
    return ExplorationReport(
        task="serving-spec", family="wp", sites=["drafter_bits"],
        points=points, hull=lower_convex_hull(points),
        n_evals=len(points),
        baseline_fpu_pj=base_rep.fpu_pj, baseline_mem_pj=base_rep.mem_pj,
        flop_coverage=1.0, batched=False,
        energy_estimator="static-abstract")


def _serving_nsga(task: ServingTask, *, seed: int = 0
                  ) -> ExplorationReport:
    """Serving-objective exploration over the full ``(phase, layer)``
    grid: genome = one mantissa width per (phase, site) plus, for scoped
    families, a per-phase default width for ops outside every named
    site, compiled into a :class:`~repro.core.policy.PrecisionPolicy`
    and served end to end.

    Objectives per genome: ``error = 1 - acceptance_rate`` (the serving
    analogue of output error — greedy completions are byte-identical by
    construction, rejections are the cost) and ``energy = estimated
    pJ/token`` from the engine's per-phase row accounting times the
    abstract decode-cell cost under each phase's rule (zero extra
    device dispatches). The pJ/token axis — unlike the grid path's
    per-window axis — *does* fold rejection overhead back in: a genome
    that drafts cheap but gets rejected re-pays verify rows, which is
    exactly the serving trade the tiered engine cares about. Heterogen-
    eous seed genomes (uniform diagonals plus single-site-lowered
    variants) guarantee the population explores per-layer placement, the
    paper's core claim, not just the uniform diagonal.

    Every candidate payload carries ``payload["policy"]`` — the policy
    as a JSON-ready dict (:meth:`PrecisionPolicy.to_dict`), the
    serializable artifact ``launch/serve.py --policy`` consumes."""
    from repro.core.estimators import abstract_step_energy
    from repro.core.policy import PhaseSpec, PrecisionPolicy
    from repro.core.scope import PHASES
    from repro.serve.engine import DecodeEngine, ServeConfig, SpecConfig

    for ph in task.phases:
        if ph not in PHASES:
            raise ValueError(f"unknown phase {ph!r}; one of {PHASES}")
    base_cfg = (task.serve_cfg if task.serve_cfg is not None
                else ServeConfig())
    if base_cfg.engine != "continuous":
        raise ValueError("serving exploration requires the continuous "
                         "engine")
    if base_cfg.spec is not None:
        base_cfg = dataclasses.replace(base_cfg, spec=None)
    model, params = task.model, task.params

    # abstract decode-cell profile: site selection + per-rule energy,
    # one jaxpr walk each, zero device dispatches
    a_params = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), params)
    a_cache = jax.eval_shape(
        lambda: model.init_cache(base_cfg.batch_slots, base_cfg.max_len))
    a_toks = jax.ShapeDtypeStruct(
        (base_cfg.batch_slots, 1), jnp.int32)
    step = lambda p, c, t: model.decode_step(p, c, t)   # noqa: E731
    prof = profile(step, a_params, a_cache, a_toks)
    sites = sites_for_family(prof, task.family, task.n_sites)
    base_rep = abstract_step_energy(step, a_params, a_cache, a_toks,
                                    rule=None)
    # one gene per (phase, site) plus, for scoped families, a per-phase
    # default width covering ops outside every named site.  Without the
    # default gene uncovered ops stay at full precision, so no scoped
    # genome could even match a whole-program uniform's energy, let
    # alone beat it with per-site placement.
    has_default = task.family != "wp"
    stride = len(sites) + (1 if has_default else 0)
    n_genes = len(task.phases) * stride

    def policy_of(genome) -> PrecisionPolicy:
        phases = {}
        for j, ph in enumerate(task.phases):
            row = tuple(int(b) for b in
                        genome[j * stride:(j + 1) * stride])
            site_bits, default = ((row[:-1], row[-1]) if has_default
                                  else (row, 24))
            phases[ph] = PhaseSpec(
                family=task.family, sites=tuple(sites), bits=site_bits,
                default_bits=default,
                mode=task.mode, weights=(ph == "draft"))
        return PrecisionPolicy(
            phases=phases,
            name=f"{task.name}-" + "-".join(str(b) for b in genome))

    results: Dict[Tuple[int, ...], Tuple[float, float, Dict]] = {}

    def evaluate(genome) -> Tuple[float, float]:
        key = tuple(int(b) for b in genome)
        if key in results:
            return results[key][:2]
        pol = policy_of(key)
        cfg = dataclasses.replace(
            base_cfg, spec=SpecConfig(k=task.k, mode=task.mode),
            estimate_energy=True)
        eng = DecodeEngine(model, params, cfg, policy=pol)
        eng.generate(task.prompts, max_new_tokens=task.max_new_tokens)
        st = eng.stats
        err = 1.0 - st.acceptance_rate
        # energy axis: the *measured* token-stream census (the fused
        # kernel-epilogue §III-C counts — input-dependent, zero extra
        # dispatches), falling back to the abstract width-affine
        # estimate for families whose serving path has no censused
        # kernels (pure-recurrent decode)
        measured = st.measured_pj_per_token
        pj_tok = (measured if any(st.phase_census.values())
                  else st.est_pj_per_token)
        results[key] = (err, pj_tok, {
            "genome": key, "policy": pol.to_dict(),
            "acceptance": st.acceptance_rate,
            "tokens_per_s": st.tokens_per_s,
            "p50_ttft_s": st.p50_ttft_s, "p99_ttft_s": st.p99_ttft_s,
            "uniform": len(set(key)) == 1,
            "measured_pj_per_token": measured,
            "est_pj_per_token": st.est_pj_per_token,
            "mem": pj_tok, "stats": st})
        return err, pj_tok

    # seeds: the uniform diagonal (so heterogeneous placement strictly
    # contains the whole-program solutions) plus single-site-lowered
    # variants off the mid-diagonal uniforms — generation zero already
    # contains per-(phase, site) heterogeneity near the useful part of
    # the diagonal, not just at identity. Two lowering depths: the
    # measured energy axis prices rejection overhead, so the winning
    # placements often shave one site *mildly* (acceptance held) rather
    # than crater it — a delta-6 drop alone would skip that region.
    diag = sorted(set([4, 8, 12, 24]))
    seeds = [(b,) * n_genes for b in diag]
    for b in (8, 12):
        for i in range(min(n_genes, 10)):
            if has_default and i % stride == stride - 1:
                continue          # keep the per-phase default on-diagonal
            for delta in (2, 6):
                g = [b] * n_genes
                g[i] = max(1, b - delta)
                seeds.append(tuple(g))

    opt = NSGA2(n_genes=n_genes, low=1, high=24,
                pop_size=task.pop_size, n_gen=task.n_gen,
                max_evals=task.max_evals, seed=seed, seed_genomes=seeds)
    while not opt.done:
        batch = opt.ask()
        opt.tell(batch, [evaluate(g) for g in batch])
    res: NSGA2Result = opt.result()

    points = [TradeoffPoint(error=e.objectives[0], energy=e.objectives[1],
                            payload=results[tuple(e.genome)][2])
              for e in res.evaluated]
    return ExplorationReport(
        task=task.name,
        family=task.family,
        sites=[f"{ph}:{s}" for ph in task.phases
               for s in (list(sites) + ["__default__"] if has_default
                         else list(sites))],
        points=points, hull=lower_convex_hull(points),
        n_evals=res.n_evals,
        baseline_fpu_pj=base_rep.fpu_pj, baseline_mem_pj=base_rep.mem_pj,
        flop_coverage=1.0, batched=False,
        energy_estimator="serving-census")


def explore_serving(model, params, prompts, *,
                    bits_grid: Sequence[int] = (4, 6, 8, 10, 24),
                    k: int = 4, serve_cfg=None, max_new_tokens: int = 32,
                    mode: str = "rne") -> ExplorationReport:
    """Deprecated alias for ``explore(ServingTask(..., bits_grid=...),
    objectives="serving")`` — the historical drafter-bits grid sweep.
    Same report, byte for byte."""
    import warnings
    warnings.warn(
        "explore_serving() is deprecated; use explore(ServingTask(...), "
        'objectives="serving") — bits_grid selects this exact grid sweep',
        DeprecationWarning, stacklevel=2)
    return explore(ServingTask(
        model=model, params=params, prompts=list(prompts),
        serve_cfg=serve_cfg, max_new_tokens=max_new_tokens, k=k,
        mode=mode, bits_grid=tuple(bits_grid)), objectives="serving")
