"""Tradeoff-space analysis: lower convex hulls and quantized savings.

Reproduces the paper's reporting: Fig. 5/11a plot the lower convex hull of
(error rate, normalized energy); Figs. 6/7/11b quantize the hull at error
thresholds (1/5/10/20%) and report energy savings vs. the exact baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class TradeoffPoint:
    error: float            # relative error vs exact baseline (0 = exact)
    energy: float           # normalized energy (1 = exact baseline)
    payload: object = None  # e.g. the genome / rule


def pareto_points(points: Sequence[TradeoffPoint]) -> List[TradeoffPoint]:
    """Non-dominated subset (min error, min energy), sorted by error."""
    pts = sorted(points, key=lambda p: (p.error, p.energy))
    out: List[TradeoffPoint] = []
    best = float("inf")
    for p in pts:
        if p.energy < best - 1e-15:
            out.append(p)
            best = p.energy
    return out


def lower_convex_hull(points: Sequence[TradeoffPoint]) -> List[TradeoffPoint]:
    """Lower convex hull over (error, energy) — the paper's frontier plot."""
    pts = pareto_points(points)
    if len(pts) <= 2:
        return pts
    hull: List[TradeoffPoint] = []
    for p in pts:
        while len(hull) >= 2:
            (x1, y1), (x2, y2) = ((hull[-2].error, hull[-2].energy),
                                  (hull[-1].error, hull[-1].energy))
            # pop if hull[-1] is above the chord hull[-2]->p
            if (x2 - x1) * (p.energy - y1) - (p.error - x1) * (y2 - y1) <= 0:
                hull.pop()
            else:
                break
        hull.append(p)
    return hull


def energy_at_threshold(points: Sequence[TradeoffPoint],
                        max_error: float) -> float:
    """Minimum normalized energy among configs with error <= max_error.
    Returns 1.0 (baseline) if nothing qualifies."""
    ok = [p.energy for p in points if p.error <= max_error]
    return min(ok) if ok else 1.0


def savings_at_threshold(points: Sequence[TradeoffPoint],
                         max_error: float) -> float:
    """Energy savings (fraction) at an error budget — Figs. 6/7 bars."""
    return 1.0 - energy_at_threshold(points, max_error)


def harmonic_mean(xs: Iterable[float]) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return len(xs) / sum(1.0 / x for x in xs)


def correlation(xs: Sequence[float], ys: Sequence[float]) -> float:
    """Pearson R between train-predicted and test-achieved metrics
    (Table III)."""
    x, y = np.asarray(xs, float), np.asarray(ys, float)
    if len(x) < 2 or np.std(x) == 0 or np.std(y) == 0:
        return 1.0
    return float(np.corrcoef(x, y)[0, 1])
