"""Pluggable energy estimators — the explorer's energy objective.

The explorer no longer hardcodes the coefficient-tensor static model:
an :class:`EnergyEstimator` turns a generation's genome batch into the
``(P,)`` FPU/memory energy vectors NSGA-II ranks on. Three built-ins —
two matching the paper's §III-C estimators, one roofline-derived:

* ``"static"`` — the PR-1 coefficient tensor: energy is affine in the
  clamped per-site mantissa widths, so a population is one einsum.
  Input-independent.
* ``"dynamic"`` — the paper's trailing-zero estimator, device-resident:
  the dynamic-bits interpreter threads one exact int32 bit-census
  counter per governed op through the evaluator's existing vmapped
  dispatch (``kernels.bit_census`` — the fused Pallas reduction on TPU),
  and this estimator folds the counts into pJ on the host in float64.
  Per-FLOP charge: ``EPI(op, dtype) * manipulated_bits / full`` of the
  *quantized result*, with a dot's 2·M·N·K scalar madds sharing its
  M·N-element census (``BitChannel.weight``) — so dynamic energy is
  bounded above by the static model term by term, and the gap is the
  input-dependent savings the paper's data-dominated apps exhibit.
  FLOPs no genome site governs keep their static charge
  (``coeffs.fpu_const``); memory energy stays the static storage model.

* ``"measured-power"`` — per-op execution time x device TDP, from the
  roofline constants in ``launch/roofline.py``: dot/conv FLOPs stream
  through the MXU at peak, element-wise FLOPs at the VPU rate, and the
  per-FLOP time scales with the clamped mantissa width (the
  transprecision-FPU assumption: latency tracks the bits actually
  computed). Memory energy is bytes-moved / HBM bandwidth x TDP.
  Structurally it is the static coefficient tensor with the EPI table
  replaced by seconds x watts, so a population stays one einsum.

Custom estimators register via :func:`register_estimator`; anything
honouring the :class:`EnergyEstimator` protocol plugs into
``explore(..., energy=...)``. A factory marked ``needs_profile = True``
receives the profile/family/site context (keyword-only, no precomputed
coefficients) and builds its own coefficient view (``measured-power``
does).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core.energy import (EnergyCoeffs, EnergyReport, _epi, _full_bits,
                               energy_coeffs, population_energy)
from repro.core.interpreter import BitChannel
from repro.core.profiler import Profile


@runtime_checkable
class EnergyEstimator(Protocol):
    """What the explorer needs from an energy objective."""

    #: registry / report name
    name: str
    #: True when the evaluator must thread bit-census accumulators
    #: through its dispatches (``PopulationEvaluator(collect_bits=True)``)
    needs_bit_census: bool

    def baseline(self) -> EnergyReport:
        """Identity-rule energy used to normalize the objectives."""
        ...

    def population(self, bits_matrix, *, evaluator=None
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """(fpu_pj, mem_pj), each ``(P,)``, for one genome batch.

        ``evaluator`` is the :class:`~repro.core.explorer.PopulationEvaluator`
        whose most recent dispatch evaluated exactly this batch — dynamic
        estimators read its bit-census accumulators from it.
        """
        ...


def channel_scales(channels: Sequence[BitChannel]) -> np.ndarray:
    """pJ per counted bit for each census channel, float64:
    ``EPI(op, dtype) * weight / full_mantissa_bits``."""
    return np.asarray([_epi(ch.op_class, ch.dtype) * ch.weight
                       / _full_bits(ch.dtype) for ch in channels], float)


def fold_bit_counts(channels: Sequence[BitChannel], counts,
                    n_sites: int) -> np.ndarray:
    """Fold ``(..., n_channels)`` exact counts into ``(..., n_sites)``
    per-site dynamic FPU pJ (float64 host reduction)."""
    counts = np.asarray(counts, np.float64)
    out = np.zeros(counts.shape[:-1] + (n_sites,))
    scales = channel_scales(channels)
    for c, ch in enumerate(channels):
        out[..., ch.site] += counts[..., c] * scales[c]
    return out


@dataclasses.dataclass
class StaticEnergyEstimator:
    """PR-1 coefficient-tensor estimator: one einsum per generation."""
    coeffs: EnergyCoeffs
    name: str = "static"
    needs_bit_census: bool = False

    def baseline(self) -> EnergyReport:
        return self.coeffs.baseline()

    def population(self, bits_matrix, *, evaluator=None):
        return population_energy(self.coeffs, bits_matrix)


@dataclasses.dataclass
class DynamicEnergyEstimator:
    """Trailing-zero-census estimator, population-batched on device.

    FPU energy is the mean over the evaluated inputs (energy is additive
    per run, so the mean is the per-run expectation; the error objective
    keeps the paper's median). Memory energy and ungoverned FLOPs reuse
    the static coefficients, and governed FLOPs of op classes the
    interpreter does not intercept (transcendentals unless
    ``include_transcendental``) keep their static genome-scaled charge
    via the FPU-only ``resid`` coefficient view — they run and are
    modeled at the genome's width, they just have no census channel.
    """
    coeffs: EnergyCoeffs
    resid: Optional[EnergyCoeffs] = None
    name: str = "dynamic"
    needs_bit_census: bool = True

    def baseline(self) -> EnergyReport:
        # normalize against the static identity baseline so static and
        # dynamic fronts share one energy axis (dynamic <= static)
        return self.coeffs.baseline()

    def governed_residual(self, bits_matrix) -> np.ndarray:
        """(P,) static genome-scaled FPU pJ of governed-but-uncensused op
        classes (the einsum part only — their ungoverned share is already
        in ``coeffs.fpu_const``)."""
        if self.resid is None:
            return np.zeros(len(bits_matrix))
        fpu, _ = population_energy(self.resid, bits_matrix)
        return fpu - self.resid.fpu_const

    def fpu_matrix(self, evaluator, bits_matrix) -> np.ndarray:
        """Per-(genome, input) dynamic FPU pJ (P, I) from the evaluator's
        most recent dispatch: folded census + ungoverned static constant
        + the genome-scaled uncensused residual. Each input folds with
        its own signature's channel scales — heterogeneous-shape input
        lists carry distinct channels per input."""
        counts_list = evaluator.last_bit_counts_list
        if counts_list is None:
            raise ValueError(
                "dynamic energy estimator needs the bit-census accumulators "
                "of the evaluator's most recent dispatch — construct the "
                "PopulationEvaluator with collect_bits=True and call "
                "errors_matrix first")
        cols = []
        for i, (counts, channels) in enumerate(
                zip(counts_list, evaluator.bit_channels_list)):
            scales = channel_scales(channels)
            if counts.shape[-1] != len(scales):
                raise ValueError(f"input {i}: accumulator width "
                                 f"{counts.shape[-1]} != {len(scales)} "
                                 f"census channels")
            if counts.shape[0] != len(bits_matrix):
                raise ValueError(f"stale accumulators: {counts.shape[0]} "
                                 f"genomes in last dispatch vs "
                                 f"{len(bits_matrix)} asked")
            cols.append(counts.astype(np.float64) @ scales)
        census = np.stack(cols, axis=1)
        return (self.coeffs.fpu_const + census
                + self.governed_residual(bits_matrix)[:, None])

    def population(self, bits_matrix, *, evaluator=None):
        if len(bits_matrix) == 0:
            return np.zeros(0), np.zeros(0)
        if evaluator is None:
            raise ValueError("dynamic energy estimator requires the "
                             "evaluator that ran this batch")
        fpu = self.fpu_matrix(evaluator, bits_matrix)
        _, mem = population_energy(self.coeffs, bits_matrix)
        return fpu.mean(axis=1), mem


@dataclasses.dataclass
class MeasuredPowerEstimator(StaticEnergyEstimator):
    """Roofline-timing estimator: pJ = seconds x TDP, affine in widths.

    ``coeffs`` is a time-based coefficient tensor (built by the
    ``measured-power`` factory), so ``baseline``/``population`` inherit
    the static estimator's one-einsum evaluation; the per-site linear
    terms model a transprecision FPU whose per-op latency scales with
    the clamped mantissa width."""
    name: str = "measured-power"


def _measured_power_epi(op_class: str, dtype: str) -> float:
    """pJ per full-width scalar FLOP: execution time x device TDP.
    dot/conv stream through the MXU at peak; everything else runs at the
    VPU's element-wise rate; transcendentals cost one VPU FLOP each (the
    profiler already charges their polynomial expansion as FLOPs)."""
    from repro.launch.roofline import PEAK_FLOPS, TDP_WATTS, VPU_FLOPS
    rate = PEAK_FLOPS if op_class in ("dot", "conv") else VPU_FLOPS
    return TDP_WATTS / rate * 1e12


def _measured_power_factory(*, prof: Profile, family: str,
                            sites: Sequence[str],
                            target: str) -> MeasuredPowerEstimator:
    from repro.launch.roofline import HBM_BW, TDP_WATTS
    tcoeffs = energy_coeffs(prof, family, sites, target=target,
                            epi_fn=_measured_power_epi,
                            mem_pj_per_byte=TDP_WATTS / HBM_BW * 1e12)
    return MeasuredPowerEstimator(tcoeffs)


_measured_power_factory.needs_profile = True

_ESTIMATORS: Dict[str, Callable[[EnergyCoeffs], EnergyEstimator]] = {
    "static": StaticEnergyEstimator,
    "dynamic": DynamicEnergyEstimator,
    "measured-power": _measured_power_factory,
}


def register_estimator(name: str,
                       factory: Callable[[EnergyCoeffs], EnergyEstimator]):
    """Register a custom estimator factory (``coeffs -> estimator``) under
    ``name`` for ``explore(..., energy=name)``."""
    _ESTIMATORS[name] = factory
    return factory


def make_estimator(kind, prof: Optional[Profile] = None,
                   family: str = "cip", sites: Sequence[str] = (), *,
                   target: str = "single",
                   include_transcendental: bool = False) -> EnergyEstimator:
    """Resolve ``explore``'s ``energy=`` argument: a registered name gets
    its coefficient tensor built from the profile; a ready-made estimator
    instance passes through. Census-based estimators (``needs_bit_census``
    with a ``resid`` attribute) additionally receive the FPU-only
    residual view of the op classes the interpreter will not intercept
    under ``include_transcendental``."""
    if not isinstance(kind, str):
        return kind
    try:
        factory = _ESTIMATORS[kind]
    except KeyError:
        raise ValueError(f"unknown energy estimator {kind!r}; registered: "
                         f"{sorted(_ESTIMATORS)}") from None
    if prof is None:
        raise ValueError("building a named estimator requires a Profile")
    if getattr(factory, "needs_profile", False):
        # builds its own coefficient view — don't waste a census pass
        est = factory(prof=prof, family=family, sites=sites, target=target)
    else:
        est = factory(energy_coeffs(prof, family, sites, target=target))
    if (getattr(est, "needs_bit_census", False)
            and hasattr(est, "resid") and est.resid is None
            and not include_transcendental):
        est.resid = energy_coeffs(prof, family, sites, target=target,
                                  op_classes=frozenset({"transcendental"}))
    if getattr(est, "name", None) != kind:
        try:
            est.name = kind   # reports carry the registry name
        except AttributeError:   # frozen custom estimator keeps its own
            pass
    return est


def census_energy_pj(bits: int) -> float:
    """Measured dynamic FPU energy of a serving run: the fused §III-C
    trailing-zero census (total *active* mantissa bits over every stored
    kernel tile) converted at the fp32 dot-op energy per full-width
    mantissa bit. The serving analogue of ``dynamic_fpu_energy`` —
    input-dependent where :func:`abstract_step_energy` is the
    width-affine static bound."""
    return float(bits) * _epi("dot", "float32") / _full_bits("float32")


def abstract_step_energy(step_fn: Callable, *args,
                         rule=None,
                         include_transcendental: bool = True
                         ) -> EnergyReport:
    """Static energy of ONE compiled step, profiled **abstractly**.

    ``args`` may be ``jax.ShapeDtypeStruct`` trees — the step is traced,
    never executed, so this costs zero device dispatches. Exact for the
    ``MantissaTrunc`` FPI family (the static model's per-FLOP charge is
    affine in the clamped mantissa width, which is all that family
    changes); pair with host-side dispatch counts to bill a serving run,
    e.g. drafter energy = ``abstract_step_energy(decode_cell, ...,
    rule=draft_rule).total_pj * k * stats.draft_steps``."""
    from repro.core.energy import static_energy
    from repro.core.profiler import profile

    prof = profile(step_fn, *args,
                   include_transcendental=include_transcendental)
    return static_energy(prof, rule)


def host_device_parity(task, family: str, sites: Sequence[str],
                       estimator, evaluator, genomes, inputs, *,
                       include_transcendental: bool = False) -> float:
    """Worst relative difference between the device-folded dynamic FPU
    energies of the evaluator's most recent dispatch and the independent
    eager host reference (``capture_bit_census`` + ``dynamic_fpu_energy``
    + the estimator's static terms), across (genomes × inputs). Shared by
    tests/test_energy_dynamic.py and the CI smoke gate so both check one
    contract."""
    from repro.core.energy import dynamic_fpu_energy
    from repro.core.interpreter import capture_bit_census
    from repro.core.placement import rule_from_genome

    dev = estimator.fpu_matrix(evaluator, genomes)
    resid = estimator.governed_residual(genomes)
    worst = 0.0
    for p, g in enumerate(genomes):
        rule = rule_from_genome(family, sites, g, target=task.target,
                                mode=task.mode)
        h = capture_bit_census(
            task.fn, rule, family, sites, target=task.target,
            include_transcendental=include_transcendental)
        for i, inp in enumerate(inputs):
            _, records = h(*inp)
            host = (dynamic_fpu_energy(records)
                    + estimator.coeffs.fpu_const + resid[p])
            worst = max(worst,
                        abs(host - dev[p, i]) / max(abs(host), 1e-30))
    return worst
