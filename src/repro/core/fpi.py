"""Floating Point Implementations (FPIs), paper §III-B3 / §IV step 3.

An FPI describes *how* a floating point operation is approximated. The
paper's evaluation uses mantissa bit truncation (24 FPIs for fp32, 53 for
fp64); users may define custom FPIs by subclassing ``FpImplementation``
(the paper's ``FpImplementation`` virtual class) and overriding
``perform_operation`` to rewrite operands and/or results directly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.utils.numerics import float_spec, truncate_mantissa
from repro.utils.registry import Registry

# Op classes an FPI may target (paper: "The FPI can be applied to one or
# more floating point arithmetic instruction").
OP_CLASSES = ("add", "sub", "mul", "div", "dot", "conv", "transcendental")

fpi_registry: Registry["FpImplementation"] = Registry("fpi")


class FpImplementation:
    """Base FPI. Identity by default.

    ``perform_operation`` mirrors the paper's PerformOperation subroutine:
    it receives the op class, the would-be operands and the exactly
    computed result, and returns the approximated result. The default
    pipeline is quantize(result); subclasses may also pre-quantize
    operands (see ``quantize_operands``).
    """

    name: str = "identity"
    #: op classes this FPI applies to; others pass through untouched.
    ops: Tuple[str, ...] = OP_CLASSES

    def applies_to(self, op_class: str) -> bool:
        return op_class in self.ops

    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:  # result transform
        return x

    def quantize_operands(self, op_class: str,
                          operands: Sequence[jnp.ndarray]) -> Sequence[jnp.ndarray]:
        return operands

    def perform_operation(self, op_class: str, operands: Sequence[jnp.ndarray],
                          result: jnp.ndarray) -> jnp.ndarray:
        if not self.applies_to(op_class):
            return result
        return self.quantize(result)

    # -- energy model hooks -------------------------------------------------
    def mantissa_bits(self, dtype) -> int:
        """Effective mantissa bits for the energy model (full = identity)."""
        return float_spec(dtype).mantissa_bits

    def __repr__(self):
        return f"<FPI {self.name}>"


class Identity(FpImplementation):
    name = "identity"


@dataclasses.dataclass(frozen=True)
class MantissaTrunc(FpImplementation):
    """The paper's FPI family: keep `bits` effective mantissa bits.

    bits=24 (fp32) / 53 (fp64) is the identity; bits=8 on fp32 emulates a
    bf16-mantissa FPU. ``mode="trunc"`` reproduces the paper's raw bit
    truncation; ``"rne"`` (default) is round-to-nearest-even, which the
    TPU-adapted kernels implement natively.
    """
    bits: int = 24
    mode: str = "rne"
    ops: Tuple[str, ...] = OP_CLASSES

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"mant{self.bits}{'t' if self.mode == 'trunc' else ''}"

    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:
        spec = float_spec(x.dtype)
        bits = min(self.bits, spec.mantissa_bits)
        return truncate_mantissa(x, bits, self.mode)

    def mantissa_bits(self, dtype) -> int:
        return min(self.bits, float_spec(dtype).mantissa_bits)


@dataclasses.dataclass(frozen=True)
class PerOpTrunc(FpImplementation):
    """Different mantissa widths per op class (paper §IV step 3 example:
    8 bits for add/sub, 24 bits for mul)."""
    bits_by_op: Tuple[Tuple[str, int], ...] = ()
    mode: str = "rne"
    default_bits: int = 24

    @property
    def name(self) -> str:  # type: ignore[override]
        inner = ",".join(f"{k}={v}" for k, v in self.bits_by_op)
        return f"peropt({inner})"

    @property
    def ops(self) -> Tuple[str, ...]:  # type: ignore[override]
        return OP_CLASSES

    def _bits_for(self, op_class: str) -> int:
        return dict(self.bits_by_op).get(op_class, self.default_bits)

    def perform_operation(self, op_class, operands, result):
        spec = float_spec(result.dtype)
        bits = min(self._bits_for(op_class), spec.mantissa_bits)
        return truncate_mantissa(result, bits, self.mode)

    def mantissa_bits(self, dtype) -> int:
        full = float_spec(dtype).mantissa_bits
        vals = [min(v, full) for _, v in self.bits_by_op] or [self.default_bits]
        return max(vals)


@dataclasses.dataclass(frozen=True)
class OperandTrunc(FpImplementation):
    """Truncate *operands* before the op (the fused-matmul kernel's
    semantics): models an FPU whose input datapath is narrowed."""
    bits: int = 24
    mode: str = "rne"
    ops: Tuple[str, ...] = OP_CLASSES

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"opmant{self.bits}"

    def quantize_operands(self, op_class, operands):
        if not self.applies_to(op_class):
            return operands
        out = []
        for o in operands:
            if hasattr(o, "dtype") and jnp.issubdtype(o.dtype, jnp.floating):
                spec = float_spec(o.dtype)
                out.append(truncate_mantissa(o, min(self.bits, spec.mantissa_bits),
                                             self.mode))
            else:
                out.append(o)
        return out

    def perform_operation(self, op_class, operands, result):
        return result  # operands already handled

    def mantissa_bits(self, dtype) -> int:
        return min(self.bits, float_spec(dtype).mantissa_bits)


@dataclasses.dataclass(frozen=True)
class LambdaFPI(FpImplementation):
    """Arbitrary user FPI from a result-transform callable (e.g. a neural
    approximation of `sin`, paper's [23])."""
    fn: Callable[[jnp.ndarray], jnp.ndarray] = lambda x: x
    label: str = "lambda"
    ops: Tuple[str, ...] = OP_CLASSES
    model_bits: int = 24

    @property
    def name(self) -> str:  # type: ignore[override]
        return self.label

    def quantize(self, x):
        return self.fn(x)

    def mantissa_bits(self, dtype) -> int:
        return min(self.model_bits, float_spec(dtype).mantissa_bits)


IDENTITY = Identity()


def single_precision_fpis(mode: str = "rne") -> list[MantissaTrunc]:
    """The paper's 24 fp32 FPIs (1..24 mantissa bits)."""
    return [MantissaTrunc(bits=b, mode=mode) for b in range(1, 25)]


def double_precision_fpis(mode: str = "rne") -> list[MantissaTrunc]:
    """The paper's 53 fp64 FPIs (1..53 mantissa bits)."""
    return [MantissaTrunc(bits=b, mode=mode) for b in range(1, 54)]


fpi_registry.register("identity", IDENTITY)
for _b in (4, 8, 10, 16, 24):
    fpi_registry.register(f"mant{_b}", MantissaTrunc(bits=_b))
