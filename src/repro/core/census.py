"""Trace-time bit-census tape: the side channel that carries the fused
kernel epilogues' per-tile censuses up to whatever jitted program is
being traced, without threading an extra return value through every
layer of the model stack.

The serving engine opens a :func:`census_scope` around each phase
program's trace (``serve.engine._phase_programs``); the attention /
matmul call sites (``models/attention.py``, ``kernels/ops.py`` callers)
:func:`note_count` the census scalar their kernel epilogue produced; the
engine folds the tape's total into one extra int32 output of the
*existing* compiled step — zero additional dispatches versus the static
path.

The tape is a trace-time construct, so ``lax.scan`` bodies need care:
an entry appended inside a scan body is an inner tracer and must not be
folded outside the scan. Such bodies shield themselves with
:func:`collect` — run under a local nested scope, emit the folded total
as a scan output, and the caller re-notes the summed totals to the
enclosing tape (see ``models/prefill.py`` and the ``scan_layers``
bodies in ``models/transformer.py``).

The same shield applies to ``lax.while_loop`` bodies, with one twist:
a while loop has no per-iteration outputs, so the body runs
:func:`collect` each iteration and ACCUMULATES the total into an int32 element
of the loop *carry*; after the loop the caller re-notes the carried sum
to the enclosing tape. This is how the serving engine's fused decode
megastep (``models/decode_loop.py``) keeps the measured census exact at
one dispatch per window: each loop iteration's count equals the count
the corresponding single-step dispatch would have noted, and the carry
folds them without any extra device round trip.

Counts are exact int32 and match ``kernels.ref.bit_census_ref`` of the
tensors the kernels actually stored — the measured-census parity gate in
``benchmarks/check_smoke.py`` holds them to the host reference exactly.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Callable, Tuple

import jax.numpy as jnp

_tls = threading.local()


class CensusTape:
    """Accumulates int32 census scalars noted while its scope is open."""

    __slots__ = ("entries",)

    def __init__(self) -> None:
        self.entries: list = []

    def total(self) -> jnp.ndarray:
        """Fold the noted scalars into one int32 scalar (0 if none)."""
        tot = jnp.zeros((), jnp.int32)
        for e in self.entries:
            tot = tot + e
        return tot


@contextmanager
def census_scope():
    """Open a fresh tape; :func:`note_count` calls inside the block land
    on it. Scopes nest — the innermost open tape receives the notes —
    which is what lets a ``lax.scan`` body shield its entries from the
    enclosing trace (see :func:`collect`)."""
    prev = getattr(_tls, "tape", None)
    tape = CensusTape()
    _tls.tape = tape
    try:
        yield tape
    finally:
        _tls.tape = prev


def census_active() -> bool:
    """True when some census scope is open (checked at trace time, so
    call sites can skip the census arithmetic entirely when nobody is
    listening)."""
    return getattr(_tls, "tape", None) is not None


def note_count(count) -> None:
    """Add one census scalar (int32 array or tracer) to the innermost
    open tape; a no-op when no scope is open."""
    tape = getattr(_tls, "tape", None)
    if tape is not None:
        tape.entries.append(jnp.asarray(count, jnp.int32))


def collect(fn: Callable) -> Tuple[object, jnp.ndarray]:
    """Run ``fn()`` under a local tape; return ``(result, total)``.

    The scan-body shield: entries noted inside a ``lax.scan`` body are
    inner tracers, so the body collects locally, threads the total out
    as a per-iteration scan output, and the caller re-notes the folded
    sum to the enclosing tape."""
    with census_scope() as tape:
        out = fn()
        tot = tape.total()
    return out, tot
