"""NEAT core — the paper's contribution as composable JAX modules."""
from repro.core.fpi import (
    FpImplementation, Identity, IDENTITY, MantissaTrunc, OperandTrunc,
    PerOpTrunc, LambdaFPI, single_precision_fpis, double_precision_fpis,
    fpi_registry,
)
from repro.core.placement import (
    PlacementRule, WholeProgram, CurrentScope, CallStack, LayerCategory,
    LayerInstance, rule_from_genome, register_fp_selector, selector_registry,
)
from repro.core.scope import (
    pscope, current_stack, scope_path, PHASES, current_phase, phase_scope,
    tag_phase,
)
from repro.core.policy import (
    PhaseSpec, PrecisionPolicy, PolicyRule, policy_params,
    uniform_param_views,
)
from repro.core.quantize import (
    neat_quantize, quantize_here, use_rule, active_rule, ste_truncate,
)
from repro.core.interpreter import (
    neat_transform, neat_transform_dynamic, neat_transform_population,
    capture_bit_census, BitChannel, BitsRecord, BitCensusCapture,
)
from repro.core.profiler import profile, Profile
from repro.core.energy import (
    EnergyReport, static_energy, census_energy, dynamic_fpu_energy,
    EnergyCoeffs, energy_coeffs, population_energy,
    EPI_PJ, MEM_PJ_PER_BYTE,
)
from repro.core.estimators import (
    EnergyEstimator, StaticEnergyEstimator, DynamicEnergyEstimator,
    make_estimator, register_estimator, channel_scales, fold_bit_counts,
    host_device_parity, abstract_step_energy,
)
from repro.core.nsga2 import nsga2, NSGA2, NSGA2Result, Evaluated, pareto_front
from repro.core.pareto import (
    TradeoffPoint, pareto_points, lower_convex_hull, energy_at_threshold,
    savings_at_threshold, harmonic_mean, correlation,
)
from repro.core.explorer import (
    ExplorationTask, ExplorationReport, ServingTask, explore,
    explore_serving, default_error_fn, sites_for_family,
    PopulationEvaluator,
)
