"""Precision scopes — the call-stack NEAT observes.

The paper registers Pin callbacks on function entry/exit to track the call
stack. The JAX analogue: model/app code wraps regions in ``pscope(name)``,
which (a) pushes onto a thread-local stack consulted by scope-mode
quantization and the energy model, and (b) enters ``jax.named_scope`` so
that trace-time machinery (the jaxpr interpreter, the profiler) sees the
identical stack via ``eqn.source_info.name_stack``.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Iterator, Tuple

import jax

_tls = threading.local()


def current_stack() -> Tuple[str, ...]:
    return tuple(getattr(_tls, "stack", ()))


def scope_path(stack: Tuple[str, ...] | None = None) -> str:
    return "/".join(current_stack() if stack is None else stack)


@contextlib.contextmanager
def pscope(name: str) -> Iterator[None]:
    """Enter a named precision scope (nestable)."""
    stack = list(getattr(_tls, "stack", ()))
    stack.append(name)
    _tls.stack = tuple(stack)
    try:
        with jax.named_scope(name):
            yield
    finally:
        _tls.stack = tuple(stack[:-1])


# ---------------------------------------------------------------------------
# Phase tags — the serving-phase axis of PrecisionPolicy addressing.
# ---------------------------------------------------------------------------

PHASES = ("prefill", "decode", "draft", "verify")


def current_phase() -> str | None:
    """The active serving phase ("prefill" | "decode" | "draft" |
    "verify"), or None outside any phase scope. Like ``pscope`` this is
    a thread-local consulted at *trace* time, so a phase baked into a
    jitted step function governs every FLOP that step dispatches.
    Deliberately separate from the ``pscope`` stack: phases address the
    engine's step kind, scopes address the model's layer structure, and
    a rule family keyed on layer scopes must not see phase frames."""
    return getattr(_tls, "phase", None)


@contextlib.contextmanager
def phase_scope(name: str, default: bool = False) -> Iterator[None]:
    """Tag a region with a serving phase.

    ``default=True`` applies the tag only when no phase is already
    active — model step functions self-tag with their natural phase
    (``decode_step`` -> "decode") while the engine's wrappers set the
    authoritative phase explicitly (the drafter traces ``decode_step``
    under ``phase_scope("draft")`` and must win)."""
    prev = getattr(_tls, "phase", None)
    if default and prev is not None:
        yield
        return
    _tls.phase = name
    try:
        yield
    finally:
        _tls.phase = prev


def tag_phase(name: str):
    """Decorator form of ``phase_scope(name, default=True)``: model step
    functions self-tag with their natural phase so direct callers (the
    estimators, ad-hoc scripts) resolve phase-aware policies sensibly,
    while an engine wrapper's explicit ``phase_scope`` still wins (the
    drafter traces ``decode_step`` under "draft")."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with phase_scope(name, default=True):
                return fn(*args, **kwargs)
        return wrapped
    return deco


def parse_name_stack(name_stack) -> Tuple[str, ...]:
    """Normalize a jaxpr ``source_info.name_stack`` to a tuple of frames."""
    s = str(name_stack)
    if not s:
        return ()
    return tuple(p for p in s.split("/") if p)
