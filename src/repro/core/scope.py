"""Precision scopes — the call-stack NEAT observes.

The paper registers Pin callbacks on function entry/exit to track the call
stack. The JAX analogue: model/app code wraps regions in ``pscope(name)``,
which (a) pushes onto a thread-local stack consulted by scope-mode
quantization and the energy model, and (b) enters ``jax.named_scope`` so
that trace-time machinery (the jaxpr interpreter, the profiler) sees the
identical stack via ``eqn.source_info.name_stack``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Tuple

import jax

_tls = threading.local()


def current_stack() -> Tuple[str, ...]:
    return tuple(getattr(_tls, "stack", ()))


def scope_path(stack: Tuple[str, ...] | None = None) -> str:
    return "/".join(current_stack() if stack is None else stack)


@contextlib.contextmanager
def pscope(name: str) -> Iterator[None]:
    """Enter a named precision scope (nestable)."""
    stack = list(getattr(_tls, "stack", ()))
    stack.append(name)
    _tls.stack = tuple(stack)
    try:
        with jax.named_scope(name):
            yield
    finally:
        _tls.stack = tuple(stack[:-1])


def parse_name_stack(name_stack) -> Tuple[str, ...]:
    """Normalize a jaxpr ``source_info.name_stack`` to a tuple of frames."""
    s = str(name_stack)
    if not s:
        return ()
    return tuple(p for p in s.split("/") if p)
