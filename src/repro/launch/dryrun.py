"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

The XLA_FLAGS line below MUST run before any jax import — jax locks the
device count on first init, and the production meshes need 512
placeholder host devices.

Per cell this produces: memory_analysis (fits-per-chip proof),
cost_analysis (FLOPs/bytes for the roofline), and the collective schedule
parsed from the partitioned HLO. Results are written as JSON under
experiments/dryrun/ and summarized into EXPERIMENTS.md by
benchmarks/roofline_table.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-moe-1b-a400m \
      --shape train_4k [--multi-pod] [--all] [--rule mant8]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
import traceback
from typing import Dict, Optional

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_arch, list_archs
from repro.core.placement import WholeProgram
from repro.core.fpi import MantissaTrunc
from repro.core.quantize import use_rule
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (Roofline, model_flops_for,
                                   parse_collective_bytes)
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.clipping import clip_by_global_norm
from repro.sharding.specs import (batch_shardings, cache_shardings,
                                  make_rules, opt_state_shardings,
                                  params_shardings, use_activation_sharding)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    shape = SHAPES[shape_name]
    b, t = shape.global_batch, shape.seq_len
    tok = lambda bb, tt: jax.ShapeDtypeStruct((bb, tt), jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok(b, t), "labels": tok(b, t)}
        if cfg.family == "encdec":
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, t, cfg.d_model), jnp.dtype(cfg.dtype))
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": tok(b, t)}
        if cfg.family == "encdec":
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, t, cfg.d_model), jnp.dtype(cfg.dtype))
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": tok(b, 1)}


def _cell_cfg(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    kind = SHAPES[shape_name].kind
    changes = {}
    if kind == "train":
        changes.update(remat=True)
    if cfg.family == "moe":
        changes.update(moe_impl="ep")
    # chunk sizes tuned for the 32k/500k shapes (VMEM-friendly temps)
    if shape_name in ("prefill_32k",):
        changes.update(attn_block_q=1024, ssd_chunk=128)
    # scan-over-layers keeps compile time O(1) in depth. Decode for the
    # non-transformer families stays unrolled (their stepwise caches are
    # heterogeneous); their decode bodies are small.
    if cfg.family in ("dense", "moe", "vlm"):
        changes.update(scan_layers=True)
    elif cfg.family in ("ssm", "hybrid") and kind != "decode":
        changes.update(scan_layers=True)
    return dataclasses.replace(cfg, **changes) if changes else cfg


def build_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               rule_bits: Optional[int] = None, fsdp: bool = True,
               sequence_parallel: bool = True, donate: bool = True,
               tp_intermediates: bool = True,
               overrides: Optional[Dict] = None) -> Dict:
    """Lower + compile one (arch x shape x mesh) cell; return the record.

    ``overrides`` are dataclasses.replace fields applied on top of the
    cell config — the §Perf hillclimb's lever (remat_policy, ssd_chunk,
    attn_block_q, moe_impl, dtype, ...).
    """
    shape = SHAPES[shape_name]
    base_cfg = get_arch(arch)
    if not shape.applies(base_cfg):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi_pod" if multi_pod else "single_pod",
                "status": "skipped", "reason": shape.skip_reason(base_cfg)}
    cfg = _cell_cfg(base_cfg, shape_name)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, fsdp=fsdp)
    model = build_model(cfg)
    rule = (WholeProgram(fpi=MantissaTrunc(rule_bits), target="half")
            if rule_bits else None)

    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_sh = params_shardings(rules, params_shape)
    batch = input_specs(cfg, shape_name)
    b_sh = batch_shardings(rules, batch)

    t0 = time.time()
    with mesh, use_rule(rule), use_activation_sharding(
            rules, sequence_parallel=sequence_parallel,
            tp_intermediates=tp_intermediates):
        if shape.kind == "train":
            opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
            o_sh = opt_state_shardings(rules, opt_shape, params_shape)

            grad_shard = bool(int(os.environ.get("REPRO_GRAD_SHARD", "0")))

            def train_step(params, opt_state, batch):
                def lossf(p):
                    return model.loss(p, batch)[0]
                loss, grads = jax.value_and_grad(lossf)(params)
                if grad_shard:
                    # pin grads to the param shardings so GSPMD emits
                    # reduce-scatter (ZeRO) instead of all-reduce
                    grads = jax.lax.with_sharding_constraint(grads, p_sh)
                grads, gnorm = clip_by_global_norm(grads, 1.0)
                params, opt_state = adamw_update(
                    grads, opt_state, params, 1e-4)
                return params, opt_state, {"loss": loss, "gnorm": gnorm}

            jitted = jax.jit(
                train_step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_shape, opt_shape, batch)
        elif shape.kind == "prefill":
            def prefill(params, batch):
                if cfg.family == "encdec":
                    return model.forward(params, batch)
                return model.forward(params, batch["tokens"])
            jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_shape, batch)
        else:   # decode
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_sh = cache_shardings(rules, cache_shape, shape.global_batch)

            def serve_step(params, cache, batch):
                return model.decode_step(params, cache, batch["tokens"])

            jitted = jax.jit(
                serve_step, in_shardings=(p_sh, c_sh, b_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_shape, cache_shape, batch)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        # exact FLOP/byte census of the traced program (global shapes):
        # the profiler multiplies scan bodies by trip count, which XLA
        # CPU's cost analysis does not.
        from repro.core.profiler import profile as _profile
        if shape.kind == "train":
            prof = _profile(train_step, params_shape, opt_shape, batch)
        elif shape.kind == "prefill":
            prof = _profile(prefill, params_shape, batch)
        else:
            prof = _profile(serve_step, params_shape, cache_shape, batch)
        jaxpr_flops = float(prof.total_flops)
        jaxpr_bytes = float(prof.total_bytes)

    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # some backends return [dict]
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # top-level whiles are the layer scans; their trip count
    if cfg.scan_layers and cfg.family in ("dense", "moe", "vlm"):
        trips_hint = cfg.n_layers
    elif cfg.scan_layers and cfg.family == "hybrid":
        trips_hint = max(cfg.n_layers // max(cfg.attn_period, 1), 1)
    elif cfg.scan_layers and cfg.family == "ssm":
        trips_hint = 7            # longest homogeneous run (xLSTM 7:1)
    else:
        trips_hint = 1
    coll = parse_collective_bytes(hlo, loop_trips_hint=trips_hint)

    chips = int(np.prod(list(mesh.shape.values())))
    mem_rec = {}
    if mem is not None:
        for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_rec[f] = int(v)
    roof = Roofline(
        # jaxpr census is global-shape; per-chip = /chips (GSPMD may add
        # small redundant compute on top — the XLA number is recorded
        # alongside as xla_flops_per_chip, loop-undercounted).
        flops_per_chip=jaxpr_flops / chips,
        hbm_bytes_per_chip=jaxpr_bytes / chips,
        wire_bytes_per_chip=float(sum(coll.values())),
        collectives=coll,
        model_flops=model_flops_for(cfg, shape.kind, shape.seq_len,
                                    shape.global_batch),
        chips=chips,
        arg_bytes=float(mem_rec.get("argument_size_in_bytes", 0)),
        out_bytes=float(mem_rec.get("output_size_in_bytes", 0)),
        temp_bytes=float(mem_rec.get("temp_size_in_bytes", 0)),
    )
    mem_rec["xla_flops_per_chip"] = float(cost.get("flops", 0.0))
    mem_rec["xla_bytes_per_chip"] = float(cost.get("bytes accessed", 0.0))
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": chips,
        "status": "ok",
        "rule_bits": rule_bits,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if k in ("flops", "transcendentals",
                                   "bytes accessed", "optimal_seconds")},
        "roofline": roof.as_dict(),
    }
    return record


def save_record(record: Dict, out_dir: str = OUT_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    suffix = "_q" + str(record.get("rule_bits")) if record.get("rule_bits") \
        else ""
    name = (f"{record['arch']}_{record['shape']}_"
            f"{record['mesh']}{suffix}.json").replace("/", "_")
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--rule", default=None,
                    help="mantissa bits for a WP NEAT rule (e.g. 8)")
    ap.add_argument("--out", default=OUT_DIR)
    # §Perf hillclimb levers
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel activations")
    ap.add_argument("--no-tp-hints", action="store_true",
                    help="disable Megatron-TP intermediate constraints")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--remat-policy", default=None,
                    choices=["full", "dots"])
    ap.add_argument("--moe-impl", default=None,
                    choices=["ragged", "dense", "ep"])
    ap.add_argument("--ssd-chunk", type=int, default=None)
    ap.add_argument("--attn-block-q", type=int, default=None)
    ap.add_argument("--dtype", default=None)
    ap.add_argument("--suffix", default="",
                    help="output filename suffix for variant records")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rule_bits = int(args.rule) if args.rule else None
    overrides = {}
    for field, val in (("remat_policy", args.remat_policy),
                       ("moe_impl", args.moe_impl),
                       ("ssd_chunk", args.ssd_chunk),
                       ("attn_block_q", args.attn_block_q),
                       ("dtype", args.dtype),
                       ("param_dtype", args.param_dtype)):
        if val is not None:
            overrides[field] = val

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = build_cell(arch, shape, multi_pod=mp,
                                     rule_bits=rule_bits,
                                     fsdp=not args.no_fsdp,
                                     sequence_parallel=not args.no_sp,
                                     tp_intermediates=not args.no_tp_hints,
                                     overrides=overrides or None)
                    if args.suffix:
                        rec["variant"] = args.suffix
                        rec["arch"] = rec["arch"] + args.suffix
                except Exception as e:
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi_pod_2x16x16" if mp
                           else "single_pod_16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                path = save_record(rec, args.out)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[dryrun] OK   {tag}: compute={r['compute_s']:.4f}s "
                          f"memory={r['memory_s']:.4f}s "
                          f"collective={r['collective_s']:.4f}s "
                          f"bottleneck={r['bottleneck']} "
                          f"(compile {rec['compile_s']:.0f}s) -> {path}")
                elif rec["status"] == "skipped":
                    print(f"[dryrun] SKIP {tag}: {rec['reason']}")
                else:
                    print(f"[dryrun] FAIL {tag}: {rec['error']}")
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
