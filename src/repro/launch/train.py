"""Training launcher.

Two modes:
* default — runs on the real local devices (CPU demo / single host):
  reduced or full config, synthetic data, checkpointing, NEAT rule option.
* ``--dry-run`` — delegates to launch/dryrun.py semantics for the
  production mesh (lower+compile only).

Example (the end-to-end driver used by examples/train_100m.py):
  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --reduced --steps 200 --seq-len 128 --batch 8 --rule mant10
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch, list_archs
from repro.core.policy import PrecisionPolicy
from repro.data.synthetic import SyntheticLMDataset
from repro.models import build_model
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m",
                    choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--rule", default=None,
                    help="NEAT WP mantissa bits for QAT (e.g. 10)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model,
                          d_ff=4 * args.d_model, vocab=2048)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    from repro.utils.tree import tree_count_params
    print(f"[train] arch={cfg.name} family={cfg.family} "
          f"params={tree_count_params(params)/1e6:.1f}M")

    rule = None
    if args.rule:
        # deprecated shorthand: mantissa bits fold into the uniform
        # PrecisionPolicy, whose as_rule() is the trainer's ambient rule
        rule = PrecisionPolicy.uniform(int(args.rule),
                                       name=f"mant{args.rule}").as_rule()
        print(f"[train] NEAT rule: WP mant{args.rule} (STE QAT; "
              "via PrecisionPolicy.uniform)")

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq_len, args.batch)

    def data_fn(step):
        b = ds.batch(step)
        if cfg.family == "encdec":
            import jax.numpy as jnp
            b["src_embeds"] = jax.random.normal(
                jax.random.fold_in(jax.random.key(7), step),
                (args.batch, args.seq_len, cfg.d_model), jnp.float32)
        return b

    tcfg = TrainerConfig(peak_lr=args.lr, warmup_steps=args.steps // 10,
                         total_steps=args.steps,
                         microbatches=args.microbatches,
                         checkpoint_dir=args.checkpoint_dir)
    trainer = Trainer(model.loss, tcfg, rule=rule)
    _, _, history = trainer.fit(params, data_fn, steps=args.steps,
                                log_every=max(args.steps // 10, 1))
    if history:
        print(f"[train] final loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
