"""Production meshes (TPU v5e numbers in launch/roofline.py).

A function, not a module-level constant, so importing never touches jax
device state. Single pod: 16x16 = 256 chips ("data","model"); multi-pod:
2x16x16 = 512 chips ("pod","data","model") — the pod axis rides DCI and
serves either as outer data parallelism (default) or pipeline stages
(sharding/pipeline.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (CPU tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_population_mesh(n_devices: int | None = None):
    """1-D mesh over local devices for population-axis data parallelism.

    The NEAT explorer shards NSGA-II genome batches across it: each
    device evaluates a slice of the population through the same compiled
    program. On CPU, ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    exposes N virtual devices, so the sharded path is testable without
    accelerators.
    """
    n = len(jax.devices()) if n_devices is None else n_devices
    return jax.make_mesh((n,), ("pop",))


def population_sharding(mesh):
    """Axis-0 ("pop") sharding for everything the explorer batches per
    genome: the NSGA-II bits matrix going in, and — since outputs follow
    their batched operand — the per-genome error leaves and the dynamic
    estimator's ``(P, n_channels)`` bit-census accumulators coming out.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec("pop"))
