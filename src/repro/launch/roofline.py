"""Roofline-term derivation from compiled dry-run artifacts.

TPU v5e hardware constants (per chip): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI. The three terms (seconds, per step):

  compute    = HLO_FLOPs / (chips x peak)      [cost_analysis is already
                                                per-partition, so /chips is
                                                implicit]
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = wire_bytes / (chips x link_bw)

``collective_bytes`` is not in cost_analysis: we parse the partitioned
HLO and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, scaled by a ring-cost
factor (all-reduce moves ~2x its operand bytes on the wire; the others
~1x). HLO shapes in the partitioned module are per-device, so the sums
are per-chip wire bytes and the division by chips is again implicit.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
#: per-chip power envelope, watts (documented estimate — Google quotes
#: ~2x perf/W over v4; the absolute TDP is not published). Feeds the
#: measured-power energy estimator (time x TDP).
TDP_WATTS = 170.0
#: element-wise throughput: the 8x128 VPU sustains a small fraction of
#: the MXU's matmul peak (documented estimate)
VPU_FLOPS = PEAK_FLOPS / 16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# `%name = <shape-or-tuple> <collective>(...)`; "-done" lines never match
# because the literal op text is e.g. "all-reduce-done(".
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(token: str) -> int:
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(token))


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))          # [n_groups, group_size]<=[total]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _line_wire_bytes(line: str):
    m = _OP_RE.search(line)
    if not m:
        return None
    shape_tok, base = m.group(1), m.group(2)
    r = _result_bytes(shape_tok)
    g = _group_size(line)
    ring = (g - 1) / g if g > 1 else 0.0
    if base == "all-reduce":
        return base, 2.0 * r * ring
    if base == "reduce-scatter":
        return base, r * g * ring
    if base == "collective-permute":
        return base, float(r)
    return base, r * ring      # all-gather / all-to-all


_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """Loose HLO computation splitter. Returns (blocks, entry_name):
    a header is a line ending in '{' with an arg list and no '=' before
    the first paren (instruction lines always assign)."""
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.endswith("{") and "(" in ls and "=" not in ls.split("(")[0]:
            name = ls.split("(")[0].strip()
            is_entry = name.startswith("ENTRY")
            name = name.replace("ENTRY", "").strip().lstrip("%")
            cur = name
            comps[cur] = []
            if is_entry:
                entry = name
            continue
        if cur is not None:
            if ls == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines: list) -> int:
    """XLA scan loops compare an induction var against a constant bound;
    take the largest integer constant in the condition computation."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def parse_collective_bytes(hlo_text: str,
                           loop_trips_hint: int = 1) -> Dict[str, float]:
    """Per-chip wire bytes per collective kind from the partitioned HLO,
    with while-loop (lax.scan) bodies multiplied by their trip counts.

    Shapes in the partitioned module are per-device. Ring-algorithm wire
    cost per participant, result bytes R, group size g:
      all-reduce       2R(g-1)/g      (reduce-scatter + all-gather phases)
      all-gather        R(g-1)/g      (R = gathered result)
      reduce-scatter    Rg(g-1)/g     (input = R x g)
      all-to-all        R(g-1)/g
      collective-permute R
    """
    comps, entry = _split_computations(hlo_text)
    if entry is None or entry not in comps:
        # fall back to flat accounting
        out = {k: 0.0 for k in COLLECTIVES}
        for line in hlo_text.splitlines():
            r = _line_wire_bytes(line)
            if r:
                out[r[0]] += r[1]
        return out

    memo: Dict[str, Dict[str, float]] = {}

    def walk(name: str, depth: int = 0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        out = {k: 0.0 for k in COLLECTIVES}
        if name not in comps or depth > 16:
            return out
        memo[name] = out            # break recursion cycles
        for line in comps[name]:
            r = _line_wire_bytes(line)
            if r:
                out[r[0]] += r[1]
            if " while(" in line or "= while(" in line:
                bm = _WHILE_BODY_RE.search(line)
                cm_ = _WHILE_COND_RE.search(line)
                if bm:
                    trips = _trip_count(
                        comps.get(cm_.group(1), []) if cm_ else [])
                    if trips <= 1 and depth == 0:
                        # XLA hoists the loop bound out of the condition;
                        # top-level whiles are the layer scans — use the
                        # caller's known trip count.
                        trips = max(trips, loop_trips_hint)
                    sub = walk(bm.group(1), depth + 1)
                    for k, v in sub.items():
                        out[k] += v * trips
            else:
                # non-while subcomputations (fusions, conditionals)
                for cm in re.finditer(
                        r"(?:calls|branch_computations)="
                        r"[{]?%?([\w.\-]+)", line):
                    sub = walk(cm.group(1), depth + 1)
                    for k, v in sub.items():
                        out[k] += v
        memo[name] = out
        return out

    total = dict(walk(entry))
    # anything the call-edge walk missed (async wrappers, detached
    # computations) is counted once so no traffic is dropped
    for name, lines in comps.items():
        if name in memo:
            continue
        for line in lines:
            r = _line_wire_bytes(line)
            if r:
                total[r[0]] = total.get(r[0], 0.0) + r[1]
    return total


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    collectives: Dict[str, float]
    model_flops: float = 0.0          # 6ND (train) / 2ND (inference), global
    chips: int = 256
    # real per-chip numbers from compiled.memory_analysis()
    arg_bytes: float = 0.0
    out_bytes: float = 0.0
    temp_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        """From XLA 'bytes accessed'. NOTE: the CPU backend fuses far less
        than TPU, so this overcounts HBM traffic — treat as an upper
        bound; ``analytic_memory_s`` is the residency-based estimate."""
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def analytic_memory_s(self) -> float:
        """Residency-based per-chip traffic: arguments (params+inputs read
        once) + outputs + 2x temporaries (write + read back)."""
        return (self.arg_bytes + self.out_bytes
                + 2.0 * self.temp_bytes) / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Ideal overlapped step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/redundancy waste)."""
        tot = self.flops_per_chip * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the ideal overlapped step time."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops / self.chips) / (self.step_s * PEAK_FLOPS)

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "collectives": self.collectives,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "analytic_memory_s": self.analytic_memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_s": self.step_s,
            "model_flops": self.model_flops,
            "useful_flop_ratio": self.useful_flop_ratio,
            "mfu": self.mfu,
            "chips": self.chips,
        }


def model_flops_for(cfg, shape_kind: str, seq_len: int, global_batch: int,
                    new_tokens: int = 1) -> float:
    """MODEL_FLOPS: 6ND for training, 2ND for inference forward, where N
    = active params and D = tokens processed in the lowered step."""
    n = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n * seq_len * global_batch
    if shape_kind == "prefill":
        return 2.0 * n * seq_len * global_batch
    return 2.0 * n * new_tokens * global_batch       # decode: one token
