"""Serving launcher: batched decode with optional NEAT reduced-precision
placement (the paper's tradeoff, applied to LM inference).

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b --reduced \
      --prompts 6 --max-new 16 --rule mant8 --continuous

Precision flows through ONE surface — a
:class:`~repro.core.policy.PrecisionPolicy`:

* ``--policy policy.json`` loads an explorer-emitted policy artifact
  (``explore(objectives="serving")`` writes them; phase/layer bits);
* ``--rule mantN`` is the deprecated uniform shorthand, now
  ``PrecisionPolicy.uniform(N)``;
* ``--tiers gold=exact.json,bronze=cheap.json`` (or
  ``name=mantN``) serves SLA tiers: the slot budget is partitioned,
  requests are assigned round-robin across tiers, and admission may
  downgrade under backlog pressure (``--tier-backlog``, never below
  ``--tier-floor``).

``--continuous`` (default) refills slots mid-flight from the queue;
``--wave`` keeps the historical wave scheduler (slots refill only
between waves).

Bursty-traffic knobs: ``--arrivals poisson:RATE`` / ``--arrivals
diurnal`` replays a seeded open-loop workload (requests arrive over
wall time instead of all at t=0); ``--deadline-s`` sheds requests
whose TTFT SLA expires while queued; ``--priority P0,P1,...`` admits
(and, unless ``--no-preempt``, preempts) higher classes first. Shed /
preemption / swap-traffic totals print in the report.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch, list_archs
from repro.core.policy import PrecisionPolicy
from repro.models import build_model
from repro.serve.engine import (DecodeEngine, KVConfig, ServeConfig,
                                SpecConfig)
from repro.serve.traffic import TrafficConfig, generate_traffic


def _parse_policy(spec: str) -> PrecisionPolicy:
    """``mantN`` -> uniform N-bit policy; anything else is a path to a
    ``policy.json`` artifact."""
    if spec.startswith("mant") and spec[4:].isdigit():
        return PrecisionPolicy.uniform(int(spec[4:]), name=spec)
    return PrecisionPolicy.load(spec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rule", default=None,
                    help="DEPRECATED: mantN uniform rule; use --policy")
    ap.add_argument("--policy", default=None,
                    help="precision policy: a policy.json artifact from "
                         "explore(objectives='serving'), or mantN for a "
                         "uniform policy")
    ap.add_argument("--tiers", default=None,
                    help="SLA tiers, best first: comma-separated "
                         "name=policy pairs where policy is mantN or a "
                         "policy.json path, e.g. "
                         "gold=mant24,bronze=cheap.json")
    ap.add_argument("--tier-floor", default=None,
                    help="worst tier admission may downgrade to "
                         "(default: the last tier)")
    ap.add_argument("--tier-backlog", type=int, default=0,
                    help="downgrade a request when its tier's backlog "
                         "reaches this multiple of the tier's slots "
                         "(0 = never downgrade)")
    ap.add_argument("--estimate-energy", action="store_true",
                    help="report estimated pJ/token from the per-phase "
                         "row accounting (abstract cell census; zero "
                         "extra dispatches)")
    ap.add_argument("--continuous", dest="engine", action="store_const",
                    const="continuous", default="continuous",
                    help="continuous batching: refill slots mid-flight")
    ap.add_argument("--wave", dest="engine", action="store_const",
                    const="wave", help="historical wave scheduler")
    ap.add_argument("--admission", default="fifo", choices=("fifo", "sjf"),
                    help="queue admission order: arrival (fifo) or "
                         "shortest-prompt-first (sjf)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size for the continuous engine "
                         "(tokens ingested per slot per compiled step; "
                         "1 = legacy streaming prefill)")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="pure-decode steps fused into one on-device "
                         "megastep (the host syncs once per window); "
                         "1 = the historical sync-every-token loop")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size in tokens (0 = contiguous "
                         "per-slot strips; > 0 = paged pool + block "
                         "tables + packed ragged prefill)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="total KV pool pages (0 derives the contiguous "
                         "layout's capacity, slots * max_len/page_size)")
    ap.add_argument("--pack-tokens", type=int, default=0,
                    help="packed prefill stream width per step (0 "
                         "derives slots * chunk)")
    ap.add_argument("--pages-per-block", type=int, default=1,
                    help="block-table entries the paged flash kernel "
                         "streams per KV grid step (block_k = "
                         "pages-per-block * page-size; fills the MXU "
                         "tile at small page sizes; requires "
                         "--page-size > 0)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens per slot "
                         "per step (0 = off); the drafter is the model "
                         "itself at --drafter-bits mantissa bits")
    ap.add_argument("--drafter-bits", type=int, default=10,
                    help="NEAT drafter mantissa bits (incl. implicit; "
                         "fp32: 1..24, 24 = identity drafter)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="scale each slot's draft budget by its "
                         "trailing acceptance rate")
    ap.add_argument("--arrivals", default=None,
                    help="open-loop arrival process: poisson:RATE "
                         "(requests/s) or diurnal (thinned sinusoid); "
                         "default = closed-loop, everything at t=0. "
                         "Replaces --prompts' synthetic prompts with a "
                         "seeded traffic workload of the same size")
    ap.add_argument("--traffic-seed", type=int, default=0,
                    help="seed naming the --arrivals workload exactly")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="TTFT deadline per request (seconds from its "
                         "arrival); expired queued requests are shed "
                         "with status shed_deadline instead of served")
    ap.add_argument("--priority", default=None,
                    help="comma-separated per-request priority classes "
                         "(higher admits/preempts first), cycled over "
                         "the request list; e.g. 1,0,0")
    ap.add_argument("--no-preempt", dest="preempt", action="store_false",
                    default=True,
                    help="disable preemption: pool pressure stalls (or "
                         "as a last resort sheds) instead of swapping "
                         "the lowest-priority slot to host")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    policy = None
    if args.policy and args.rule:
        ap.error("--rule is the deprecated alias of --policy; pass one")
    if args.policy:
        policy = _parse_policy(args.policy)
        print(f"[serve] precision policy: {policy.name or args.policy}")
    elif args.rule:
        # deprecated path: mantN folds into the uniform policy
        bits = int(args.rule.replace("mant", ""))
        policy = PrecisionPolicy.uniform(bits, name=args.rule)
        print(f"[serve] NEAT rule: WP mant{bits} (deprecated --rule; "
              "equals --policy mant{bits})".format(bits=bits))

    tiers = None
    if args.tiers:
        tiers = {}
        for pair in args.tiers.split(","):
            name, _, spec = pair.partition("=")
            if not spec:
                ap.error(f"--tiers entry {pair!r} is not name=policy")
            tiers[name.strip()] = _parse_policy(spec.strip())
        print(f"[serve] tiers: {list(tiers)}")

    spec = None
    if args.spec_k > 0:
        spec = SpecConfig(k=args.spec_k, drafter_bits=args.drafter_bits,
                          adaptive=args.spec_adaptive)
        print(f"[serve] speculative: k={args.spec_k} "
              f"drafter=mant{args.drafter_bits}"
              f"{' adaptive' if args.spec_adaptive else ''}")

    engine = DecodeEngine(model, params,
                          ServeConfig(max_len=128, batch_slots=args.slots,
                                      engine=args.engine,
                                      admission=args.admission,
                                      prefill_chunk=args.chunk,
                                      sync_every=args.sync_every,
                                      kv=KVConfig(
                                          page_size=args.page_size,
                                          pages=args.kv_pages,
                                          pack_tokens=args.pack_tokens,
                                          pages_per_block=args.pages_per_block),
                                      spec=spec, tiers=tiers,
                                      tier_floor=args.tier_floor,
                                      tier_backlog=args.tier_backlog,
                                      preempt=args.preempt,
                                      estimate_energy=args.estimate_energy),
                          policy=policy)
    prompts = [[(7 * i + 3) % cfg.vocab_size for _ in range(4)]
               for i in range(args.prompts)]
    max_new = args.max_new
    arrivals = priorities = None
    if args.arrivals:
        proc, _, rate = args.arrivals.partition(":")
        if proc not in ("poisson", "diurnal"):
            ap.error(f"--arrivals {args.arrivals!r}: process must be "
                     "poisson[:RATE] or diurnal")
        traffic = generate_traffic(TrafficConfig(
            n_requests=args.prompts, seed=args.traffic_seed, process=proc,
            rate_rps=float(rate) if rate else 8.0, vocab=cfg.vocab_size,
            decode_max=args.max_new,
            priority_weights=(3.0, 1.0) if args.priority is None else (1.0,)))
        prompts = [t.prompt for t in traffic]
        max_new = [t.max_new_tokens for t in traffic]
        arrivals = [t.arrival_s for t in traffic]
        priorities = [t.priority for t in traffic]
        print(f"[serve] traffic: {proc} seed={args.traffic_seed} "
              f"span={arrivals[-1]:.2f}s")
    if args.priority is not None:
        classes = [int(p) for p in args.priority.split(",")]
        priorities = [classes[i % len(classes)]
                      for i in range(args.prompts)]
    deadlines = args.deadline_s
    tier_of = None
    if tiers:
        names = list(tiers)
        tier_of = [names[i % len(names)] for i in range(args.prompts)]
    outs = engine.generate(prompts, max_new_tokens=max_new,
                           tiers=tier_of, priority=priorities,
                           deadline_s=deadlines, arrival_s=arrivals)
    for i, o in enumerate(outs):
        status = engine.stats.status.get(i, "ok")
        print(f"[serve] prompt {i}: {len(o)} tokens -> {o[:8]}... "
              f"[{status}]")
    st = engine.stats
    print(f"[serve] engine={args.engine} steps={st.steps} "
          f"occupancy={st.occupancy:.2f} tokens={st.tokens_out} "
          f"prefill_tokens={st.prefill_tokens} "
          f"mean_ttft={st.mean_ttft_s * 1e3:.1f}ms")
    print(f"[serve] hardening: shed_deadline={st.shed_deadline} "
          f"shed_capacity={st.shed_capacity} "
          f"preemptions={st.preemptions} "
          f"swap_out={st.swap_out_bytes / 2 ** 20:.2f}MB "
          f"swap_in={st.swap_in_bytes / 2 ** 20:.2f}MB "
          f"goodput_tokens={st.goodput_tokens}")
    print(f"[serve] host/device: host_syncs={st.host_syncs} "
          f"megasteps={st.megasteps} "
          f"dispatch_wait={st.dispatch_wait_s * 1e3:.1f}ms "
          f"host_sched={st.host_sched_s * 1e3:.1f}ms "
          f"p50_tok_lat={st.p50_tok_lat_s * 1e3:.2f}ms "
          f"p99_tok_lat={st.p99_tok_lat_s * 1e3:.2f}ms")
    if args.estimate_energy:
        print(f"[serve] energy: {st.est_pj_per_token:.0f} pJ/token "
              f"(phase_rows={dict(sorted(st.phase_rows.items()))})")
        print(f"[serve] measured: {st.measured_pj_per_token:.0f} pJ/token "
              f"(phase_census={dict(sorted(st.phase_census.items()))})")
    if tiers:
        for name, ts in st.per_tier.items():
            print(f"[serve] tier {name}: tokens/s={ts.tokens_per_s:.1f} "
                  f"acceptance={ts.acceptance_rate:.3f} "
                  f"p50_ttft={ts.p50_ttft_s * 1e3:.1f}ms "
                  f"p99_ttft={ts.p99_ttft_s * 1e3:.1f}ms "
                  f"est_pJ/tok={ts.est_pj_per_token:.0f} "
                  f"measured_pJ/tok={ts.measured_pj_per_token:.0f}")
        print(f"[serve] downgraded={st.downgraded}")
    if args.page_size:
        print(f"[serve] paged: pool={st.pool_pages} pages "
              f"peak_resident={st.peak_resident_pages} "
              f"peak_active={st.peak_active_requests}")
    if spec is not None:
        hist = dict(sorted(st.accepted_hist.items()))
        print(f"[serve] spec: acceptance={st.acceptance_rate:.3f} "
              f"windows={st.spec_windows} drafted={st.draft_tokens} "
              f"accepted={st.accepted_tokens} "
              f"draft_steps={st.draft_steps} "
              f"verify_steps={st.verify_steps} hist={hist} "
              f"p50_ttft={st.p50_ttft_s * 1e3:.1f}ms "
              f"p99_ttft={st.p99_ttft_s * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
