"""Serving launcher: batched decode with optional NEAT reduced-precision
placement (the paper's tradeoff, applied to LM inference).

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b --reduced \
      --prompts 6 --max-new 16 --rule mant8 --continuous

``--continuous`` (default) refills slots mid-flight from the queue;
``--wave`` keeps the historical wave scheduler (slots refill only
between waves).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch, list_archs
from repro.core.fpi import MantissaTrunc
from repro.core.placement import WholeProgram
from repro.models import build_model
from repro.serve.engine import DecodeEngine, ServeConfig, SpecConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rule", default=None)
    ap.add_argument("--continuous", dest="engine", action="store_const",
                    const="continuous", default="continuous",
                    help="continuous batching: refill slots mid-flight")
    ap.add_argument("--wave", dest="engine", action="store_const",
                    const="wave", help="historical wave scheduler")
    ap.add_argument("--admission", default="fifo", choices=("fifo", "sjf"),
                    help="queue admission order: arrival (fifo) or "
                         "shortest-prompt-first (sjf)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size for the continuous engine "
                         "(tokens ingested per slot per compiled step; "
                         "1 = legacy streaming prefill)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size in tokens (0 = contiguous "
                         "per-slot strips; > 0 = paged pool + block "
                         "tables + packed ragged prefill)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="total KV pool pages (0 derives the contiguous "
                         "layout's capacity, slots * max_len/page_size)")
    ap.add_argument("--pack-tokens", type=int, default=0,
                    help="packed prefill stream width per step (0 "
                         "derives slots * chunk)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens per slot "
                         "per step (0 = off); the drafter is the model "
                         "itself at --drafter-bits mantissa bits")
    ap.add_argument("--drafter-bits", type=int, default=10,
                    help="NEAT drafter mantissa bits (incl. implicit; "
                         "fp32: 1..24, 24 = identity drafter)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="scale each slot's draft budget by its "
                         "trailing acceptance rate")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rule = None
    if args.rule:
        bits = int(args.rule.replace("mant", ""))
        rule = WholeProgram(fpi=MantissaTrunc(bits), target="single")
        print(f"[serve] NEAT rule: WP mant{bits}")

    spec = None
    if args.spec_k > 0:
        spec = SpecConfig(k=args.spec_k, drafter_bits=args.drafter_bits,
                          adaptive=args.spec_adaptive)
        print(f"[serve] speculative: k={args.spec_k} "
              f"drafter=mant{args.drafter_bits}"
              f"{' adaptive' if args.spec_adaptive else ''}")

    engine = DecodeEngine(model, params,
                          ServeConfig(max_len=128, batch_slots=args.slots,
                                      engine=args.engine,
                                      admission=args.admission,
                                      prefill_chunk=args.chunk,
                                      page_size=args.page_size,
                                      kv_pages=args.kv_pages,
                                      pack_tokens=args.pack_tokens,
                                      spec=spec),
                          rule=rule)
    prompts = [[(7 * i + 3) % cfg.vocab_size for _ in range(4)]
               for i in range(args.prompts)]
    outs = engine.generate(prompts, max_new_tokens=args.max_new)
    for i, o in enumerate(outs):
        print(f"[serve] prompt {i}: {len(o)} tokens -> {o[:8]}...")
    st = engine.stats
    print(f"[serve] engine={args.engine} steps={st.steps} "
          f"occupancy={st.occupancy:.2f} tokens={st.tokens_out} "
          f"prefill_tokens={st.prefill_tokens} "
          f"mean_ttft={st.mean_ttft_s * 1e3:.1f}ms")
    if args.page_size:
        print(f"[serve] paged: pool={st.pool_pages} pages "
              f"peak_resident={st.peak_resident_pages} "
              f"peak_active={st.peak_active_requests}")
    if spec is not None:
        hist = dict(sorted(st.accepted_hist.items()))
        print(f"[serve] spec: acceptance={st.acceptance_rate:.3f} "
              f"windows={st.spec_windows} drafted={st.draft_tokens} "
              f"accepted={st.accepted_tokens} "
              f"draft_steps={st.draft_steps} "
              f"verify_steps={st.verify_steps} hist={hist} "
              f"p50_ttft={st.p50_ttft_s * 1e3:.1f}ms "
              f"p99_ttft={st.p99_ttft_s * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
