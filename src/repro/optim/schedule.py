"""LR schedules (callable: step -> lr, traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return sched
