from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_global_norm


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped grads, pre-clip global norm)."""
    gnorm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), gnorm
