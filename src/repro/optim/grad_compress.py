"""Int8 gradient compression with error feedback (1-bit-Adam style
residual correction) — a distributed-optimization option for cross-pod
gradient reduction where the "pod" axis rides slower DCI links.

compress -> all-reduce int8 (4x fewer bytes than fp32, 2x vs bf16) ->
decompress; the quantization residual is fed back into the next step so
the scheme is unbiased over time.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def error_feedback_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8(g: jnp.ndarray, err: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale fp32 scalar, new error residual)."""
    corrected = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, corrected - deq


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, err_tree):
    """Compress every leaf; returns (packed tree, new error tree)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress_int8(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    packed = jax.tree.unflatten(tdef, [
        {"q": q, "scale": s} for q, s in zip(qs, scales)])
    return packed, jax.tree.unflatten(tdef, errs)


def decompress_tree(packed):
    return jax.tree.map(
        lambda leaf: decompress_int8(leaf["q"], leaf["scale"]),
        packed, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
