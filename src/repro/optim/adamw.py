"""AdamW from scratch (no optax in this environment).

State layout is a pytree mirroring params; under the production mesh the
trainer shards optimizer moments over the "data" axis (ZeRO-1) via the
sharding rules in ``repro.sharding.specs`` — the update math here is
sharding-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # moments kept in fp32 regardless of param dtype
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, lr, cfg: AdamWConfig = AdamWConfig()
                 ) -> Tuple[dict, dict]:
    """Returns (new_params, new_state)."""
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p):
        a, b, c = upd(g, mu, nu, p)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    return (jax.tree.unflatten(tdef, new_p),
            {"mu": jax.tree.unflatten(tdef, new_mu),
             "nu": jax.tree.unflatten(tdef, new_nu),
             "count": count})
