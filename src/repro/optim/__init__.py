from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig
from repro.optim.schedule import warmup_cosine, constant_lr
from repro.optim.clipping import clip_by_global_norm
from repro.optim.grad_compress import (
    compress_int8, decompress_int8, error_feedback_init,
)
