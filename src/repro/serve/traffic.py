"""Seeded open-loop traffic generation for the serving engine.

Closed-loop benchmarks (submit everything at t=0, wait) only ever see
means; production failure modes — p99 TTFT blowups, shed storms, pool
thrash — live in the *arrival process*. This module synthesizes
reproducible open-loop workloads: Poisson and diurnal (thinned
inhomogeneous Poisson) arrivals, a long-tail lognormal prompt-length
mixture, per-class completion budgets, priority classes and optional
TTFT deadlines. Everything is driven by one seeded ``numpy`` generator,
so a (seed, process, rate) triple names a workload exactly.

Lives under ``repro.serve`` so the launcher (``repro.launch.serve``)
can import it with only ``src`` on the path; ``benchmarks/traffic.py``
re-exports it for the bench harness.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class TrafficRequest:
    """One synthetic request: a token prompt plus serving metadata."""
    prompt: List[int]
    max_new_tokens: int
    arrival_s: float                  # offset from the workload's t=0
    priority: int = 0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class TrafficConfig:
    """Knobs for :func:`generate_traffic`. All randomness flows from
    ``seed`` — two configs with equal fields produce equal workloads."""
    n_requests: int = 64
    seed: int = 0
    #: "poisson" (exponential inter-arrivals at ``rate_rps``) or
    #: "diurnal" (inhomogeneous Poisson thinned against a sinusoid with
    #: ``diurnal_period_s`` period — peak rate = ``rate_rps``)
    process: str = "poisson"
    rate_rps: float = 8.0
    diurnal_period_s: float = 8.0
    #: prompt lengths ~ lognormal(mean, sigma), clipped to [1, max]:
    #: most prompts are short, a heavy tail is 5-20x longer
    prompt_mean: float = 8.0
    prompt_sigma: float = 0.6
    prompt_max: int = 48
    #: completion budgets ~ lognormal, same clip discipline
    decode_mean: float = 12.0
    decode_sigma: float = 0.5
    decode_max: int = 48
    vocab: int = 64
    #: priority classes drawn with the given weights (index = priority,
    #: higher = more important); single-class traffic by default
    priority_weights: Sequence[float] = (1.0,)
    #: fraction of requests carrying a TTFT deadline, and its value
    deadline_frac: float = 0.0
    deadline_s: float = 0.5


def _lengths(rng: np.random.Generator, n: int, mean: float, sigma: float,
             cap: int) -> np.ndarray:
    """Long-tail lengths: lognormal with the requested *linear* mean."""
    mu = math.log(mean) - 0.5 * sigma * sigma
    vals = rng.lognormal(mu, sigma, size=n)
    return np.clip(np.round(vals), 1, cap).astype(int)


def _arrivals(rng: np.random.Generator, cfg: TrafficConfig) -> np.ndarray:
    if cfg.process == "poisson":
        gaps = rng.exponential(1.0 / cfg.rate_rps, size=cfg.n_requests)
        return np.cumsum(gaps)
    if cfg.process == "diurnal":
        # thinning: draw candidates at the peak rate, keep each with
        # probability intensity(t)/peak — a raised sinusoid, so the
        # workload alternates calm troughs and admission-storm crests
        out: List[float] = []
        t = 0.0
        while len(out) < cfg.n_requests:
            t += rng.exponential(1.0 / cfg.rate_rps)
            lam = 0.5 * (1.0 + math.sin(
                2.0 * math.pi * t / cfg.diurnal_period_s))
            if rng.random() < lam:
                out.append(t)
        return np.asarray(out)
    raise ValueError(f"unknown arrival process {cfg.process!r}; "
                     "one of ('poisson', 'diurnal')")


def generate_traffic(cfg: TrafficConfig) -> List[TrafficRequest]:
    """Synthesize the workload: ``n_requests`` requests sorted by
    arrival time, fully determined by ``cfg`` (including ``seed``)."""
    rng = np.random.default_rng(cfg.seed)
    arrive = _arrivals(rng, cfg)
    plens = _lengths(rng, cfg.n_requests, cfg.prompt_mean,
                     cfg.prompt_sigma, cfg.prompt_max)
    budgets = _lengths(rng, cfg.n_requests, cfg.decode_mean,
                       cfg.decode_sigma, cfg.decode_max)
    w = np.asarray(cfg.priority_weights, float)
    prios = rng.choice(len(w), size=cfg.n_requests, p=w / w.sum())
    dl = rng.random(cfg.n_requests) < cfg.deadline_frac
    reqs = [TrafficRequest(
        prompt=[int(x) for x in rng.integers(1, cfg.vocab,
                                             size=plens[i])],
        max_new_tokens=int(budgets[i]),
        arrival_s=float(arrive[i]),
        priority=int(prios[i]),
        deadline_s=cfg.deadline_s if dl[i] else None,
    ) for i in range(cfg.n_requests)]
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs
