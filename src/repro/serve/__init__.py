from repro.serve.engine import DecodeEngine, ServeConfig, ServeStats

__all__ = ["DecodeEngine", "ServeConfig", "ServeStats"]
