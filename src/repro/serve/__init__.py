from repro.serve.engine import DecodeEngine, ServeConfig
