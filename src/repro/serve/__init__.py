from repro.core.policy import PrecisionPolicy
from repro.serve.engine import (DecodeEngine, KVConfig, ServeConfig,
                                ServeStats, SpecConfig, drafter_params)

__all__ = ["DecodeEngine", "KVConfig", "PrecisionPolicy", "ServeConfig",
           "ServeStats", "SpecConfig", "drafter_params"]
