from repro.serve.engine import (DecodeEngine, ServeConfig, ServeStats,
                                SpecConfig, drafter_params)

__all__ = ["DecodeEngine", "ServeConfig", "ServeStats", "SpecConfig",
           "drafter_params"]
