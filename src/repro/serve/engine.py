"""Decode engine: prefill + greedy/temperature decode against the model's
KV cache, with NEAT placement support for reduced-precision serving.

Two schedulers share one compiled (batch, 1)-token decode step; the
continuous scheduler additionally runs a compiled **chunked-prefill**
step — and, with ``page_size > 0``, switches to the **paged** memory
layout and a **packed ragged prefill** step:

* the KV cache becomes a shared ``(num_pages, page_size, ...)`` pool
  per layer plus one ``(B, max_pages)`` block table, managed by a
  host-side :class:`PageAllocator` — pages are allocated on admission
  (the request's worst-case ``ceil((tail + budget) / page_size)``
  tokens), freed on retire, and **admission is gated on free pages, not
  free slots**: total resident KV is bounded by the live requests'
  actual needs, so at a fixed pool many more short requests run
  concurrently than the contiguous layout's ``B × max_len`` strips
  allow;
* prefill steps carry one packed ``(ΣC,)`` token stream instead of a
  ``(B, C)`` rectangle: each packed row names its owning slot and
  absolute cache position, decoding slots ride along as single rows,
  and the step's compute scales with *live tokens* (``pack_tokens``
  budget) rather than ``B × C`` padding.

* **continuous** (default): the KV cache carries a per-slot position
  vector, so the engine is a scheduler loop — admit queued requests into
  free slots *mid-flight*, ingest each slot's remaining prompt in
  ``prefill_chunk``-token blocks through one compiled
  ``Model.prefill_chunk`` call (attention families batch the chunk
  through the flash kernel's ``q_start`` path; recurrent families scan
  it on-device), retire on EOS/budget, and immediately refill. Steps are
  **mixed**: slots mid-prefill consume chunks while decoding slots emit
  one token in the same dispatch, ragged tails masked via per-slot
  ``n_new``/``kv_len``. Once no slot is prefilling the engine drops back
  to the cheap (batch, 1) decode step. A retired slot is reset (its KV
  entries and position zeroed) before reuse, and per-slot causal masking
  keys every slot on its own length, so a recycled slot can never attend
  to the previous request's KV entries. No wave barrier, no fresh-cache
  restarts. ``prefill_chunk=1`` degenerates to streaming prefill (the
  baseline the chunked path is benchmarked against).

* **wave**: the historical scheduler — requests are packed into fixed
  slots wave by wave, every prompt token streamed through the decode
  step, and a finished wave pulls the next requests from the queue.
  Kept as the parity reference: under greedy decoding both schedulers
  produce identical per-request completions.

Both schedulers admit from one queue whose order is the configured
admission policy — ``"fifo"`` (arrival) or ``"sjf"`` (fewest remaining
prefill *steps* first: ``ceil(len(tail) / prefill_chunk)`` for the
continuous engine, the raw tail length for the streaming wave
scheduler) — and every request carries its own ``max_new`` budget
(``generate(prompts, max_new_tokens=[...])``; an int broadcasts).
``ServeStats`` tracks per-request time-to-first-token alongside the
step/occupancy accounting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementRule
from repro.core.quantize import use_rule
from repro.models.model_api import Model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    batch_slots: int = 8
    temperature: float = 0.0          # 0 = greedy
    eos_token: Optional[int] = None
    seed: int = 0
    engine: str = "continuous"        # "continuous" | "wave"
    #: queue admission order: "fifo" (arrival) or "sjf" (shortest job
    #: first — short requests stop convoying behind long prefills; a
    #: stable sort keeps arrival order among equal keys). The sjf key is
    #: the post-chunking remaining-prefill length: the number of compiled
    #: prefill steps the admitted tail will actually consume — with a
    #: **page-availability tie-break** on the paged engine: among equal
    #: step keys, the request needing fewer KV pages sorts first (then
    #: arrival order), so a short-prompt request with a huge completion
    #: budget cannot hold the queue head while cheaper requests could
    #: already run. Completions are returned in request order either
    #: way, and greedy outputs are admission-order independent.
    admission: str = "fifo"
    #: tokens each prefilling slot ingests per compiled step (continuous
    #: engine only; 1 = legacy streaming prefill, token by token)
    prefill_chunk: int = 32
    #: KV page size in tokens; 0 = contiguous per-slot (B, max_len)
    #: strips (the PR-4 rectangle path). > 0 switches the continuous
    #: engine to the paged pool + block tables + packed ragged prefill.
    #: Pick ``page_size | max_len`` so the paged logical length equals
    #: the contiguous S axis (keeps the attention reductions identical).
    page_size: int = 0
    #: total pool pages; 0 derives ``batch_slots * ceil(max_len /
    #: page_size)`` — the same token capacity as the contiguous layout.
    #: Smaller pools trade concurrency headroom for memory; admission
    #: blocks (backpressure) rather than overcommitting.
    kv_pages: int = 0
    #: packed-stream width per compiled prefill step (ΣC); 0 derives
    #: ``batch_slots * prefill_chunk`` (the rectangle's token capacity,
    #: so step counts never regress). Must be >= batch_slots so every
    #: active slot gets at least one row per step.
    pack_tokens: int = 0


@dataclasses.dataclass
class ServeStats:
    """Occupancy + latency accounting for the last ``generate`` call."""
    steps: int = 0                    # compiled step dispatches
    active_slot_steps: int = 0        # slot-steps spent on a live request
    slot_steps: int = 0               # steps * batch_slots
    tokens_out: int = 0               # completion tokens emitted
    n_requests: int = 0
    prefill_steps: int = 0            # steps where >= 1 slot ate a chunk
    prefill_tokens: int = 0           # prompt tokens ingested
    #: paged engine: pool size, high-water mark of allocated pages and
    #: of concurrently admitted requests (0 on the contiguous path)
    pool_pages: int = 0
    peak_resident_pages: int = 0
    peak_active_requests: int = 0
    #: per-request time-to-first-token, seconds since generate() started
    ttft_s: Dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def occupancy(self) -> float:
        return self.active_slot_steps / max(self.slot_steps, 1)

    @property
    def mean_ttft_s(self) -> float:
        return (sum(self.ttft_s.values()) / len(self.ttft_s)
                if self.ttft_s else 0.0)


class PageAllocator:
    """Host-side free-list allocator over the shared KV pool.

    Pages are plain ints indexing every layer's pool identically. The
    free list is FIFO (freed pages recycle oldest-first), so allocation
    is deterministic for a fixed workload — the paged engine's step
    sequence, and therefore its stats, are reproducible."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages, or None (and no change) if fewer are free."""
        if n > len(self._free):
            return None
        taken, self._free = self._free[:n], self._free[n:]
        return taken

    def free(self, pages: List[int]) -> None:
        self._free.extend(pages)


class DecodeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 rule: Optional[PlacementRule] = None):
        if cfg.engine not in ("continuous", "wave"):
            raise ValueError(f"unknown engine {cfg.engine!r}")
        if cfg.admission not in ("fifo", "sjf"):
            raise ValueError(f"unknown admission policy {cfg.admission!r}")
        if cfg.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if cfg.page_size < 0 or cfg.kv_pages < 0 or cfg.pack_tokens < 0:
            raise ValueError("page_size/kv_pages/pack_tokens must be >= 0")
        if cfg.page_size and cfg.engine != "continuous":
            raise ValueError("paged KV requires the continuous engine")
        from repro.models.attention import max_pages_for
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rule = rule
        self.stats = ServeStats()
        self.paged = cfg.page_size > 0
        if self.paged:
            self.max_pages = max_pages_for(cfg.max_len, cfg.page_size)
            self.num_pages = (cfg.kv_pages or
                              cfg.batch_slots * self.max_pages)
            self.pack_tokens = (cfg.pack_tokens or
                                cfg.batch_slots * cfg.prefill_chunk)
            if self.pack_tokens < cfg.batch_slots:
                raise ValueError("pack_tokens must be >= batch_slots "
                                 "(every active slot needs one row)")
        with use_rule(rule):
            self._step = jax.jit(
                lambda p, c, t: model.decode_step(p, c, t))
            # the chunked-prefill step: (B, C) tokens + per-slot n_new in
            # one dispatch (mixed prefill/decode); compiled lazily, so
            # wave engines never pay for it
            self._chunk_step = jax.jit(
                lambda p, c, t, n: model.prefill_chunk(p, c, t, n))
            # the packed-prefill step: one (ΣC,) ragged stream + per-row
            # slot/position vectors; per-slot rows are capped at
            # prefill_chunk (static, for the recurrent unpack rectangle)
            self._packed_step = jax.jit(
                lambda p, c, t, s, q, l: model.prefill_packed(
                    p, c, t, s, q, l, cfg.prefill_chunk))
            # donate the cache: the reset runs on the admit hot path and
            # the caller always rebinds, so XLA may update it in place
            # instead of copying every layer's (B, S, KV, Dh) buffers
            self._reset = jax.jit(lambda c, m: model.reset_slots(c, m),
                                  donate_argnums=0)

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature).astype(jnp.int32)

    def _prompt_tail(self, prompt, max_new_tokens: int) -> List[int]:
        # keep only the prompt tail that leaves cache room for the full
        # completion — otherwise a near-max_len prompt would exhaust the
        # cache mid-prefill and silently return a short/empty completion
        keep = max(1, self.cfg.max_len - 1 - max_new_tokens)
        return list(prompt)[-keep:] if prompt else [0]

    def _budgets(self, prompts,
                 max_new_tokens: Union[int, Sequence[int]]) -> List[int]:
        """Per-request completion budgets: one int broadcasts; a sequence
        gives each request its own ``max_new`` ceiling."""
        if isinstance(max_new_tokens, (int, np.integer)):
            budgets = [int(max_new_tokens)] * len(prompts)
        else:
            budgets = [int(b) for b in max_new_tokens]
        if len(budgets) != len(prompts):
            raise ValueError(f"{len(budgets)} max_new budgets for "
                             f"{len(prompts)} prompts")
        if any(b < 1 for b in budgets):
            raise ValueError("per-request max_new budgets must be >= 1")
        return budgets

    def _prefill_stride(self) -> int:
        """Prompt tokens one compiled step ingests per slot: the chunk
        size for the continuous engine, 1 for the streaming wave path."""
        return (self.cfg.prefill_chunk if self.cfg.engine == "continuous"
                else 1)

    def _pages_needed(self, tail_len: int, budget: int) -> int:
        """Worst-case KV pages one request can touch: its prompt tail
        plus its full completion budget (the engine retires a slot
        before writing past this, so admission-time reservation never
        has to grow — exhaustion can only block *admission*, never a
        running request), clamped to the block-table width — a slot
        retires at ``max_len - 1`` anyway, so reserving past
        ``max_pages`` could never be used (and wouldn't fit the
        table)."""
        if not (self.paged and self.model.paged_kv):
            return 0
        return min(-(-(tail_len + budget) // self.cfg.page_size),
                   self.max_pages)

    def _admission_order(self, queue: List[tuple]) -> List[tuple]:
        """Apply the configured admission policy to a (rid, prompt, budget)
        queue. ``sjf`` sorts by the post-chunking remaining-prefill
        length — the compiled prefill steps the admitted tail will
        consume, ``ceil(len / prefill_stride)`` — stably, so chunked
        prefill doesn't misorder on sub-chunk length differences that
        cost identical step counts. On the paged engine the sort key is
        ``(prefill_steps, pages_needed)``: a request's KV-page demand
        covers its *completion budget* too, so a short-prompt request
        with a huge ``max_new`` (cheap to prefill, expensive to hold)
        no longer outranks an equally-cheap request that could actually
        be admitted — the documented page-availability tie-break."""
        if self.cfg.admission == "sjf":
            stride = self._prefill_stride()
            return sorted(queue, key=lambda e: (
                -(-len(e[1]) // stride),
                self._pages_needed(len(e[1]), e[2])))
        return list(queue)

    def generate(self, prompts: List[List[int]],
                 max_new_tokens: Union[int, Sequence[int]] = 32
                 ) -> List[List[int]]:
        """Serve a list of token prompts; returns completions per prompt.
        ``max_new_tokens`` is a global ceiling (int) or one budget per
        request. ``self.stats`` holds step/occupancy/TTFT accounting."""
        self.stats = ServeStats(n_requests=len(prompts))
        self._t0 = time.perf_counter()
        outputs: dict[int, List[int]] = {i: [] for i in range(len(prompts))}
        budgets = self._budgets(prompts, max_new_tokens)
        key = jax.random.key(self.cfg.seed)
        with use_rule(self.rule):
            # both schedulers admit the cache-truncated prompt tails, so
            # the sjf sort key is computed on the length actually prefilled
            queue = self._admission_order(
                [(rid, self._prompt_tail(p, budgets[rid]), budgets[rid])
                 for rid, p in enumerate(prompts)])
            if self.cfg.engine == "continuous" and self.paged:
                self._run_packed(queue, outputs, key)
            elif self.cfg.engine == "continuous":
                self._run_continuous(queue, outputs, key)
            else:
                while queue:
                    wave = [queue.pop(0) for _ in
                            range(min(self.cfg.batch_slots, len(queue)))]
                    key = self._run_wave(wave, outputs, key)
        self.stats.slot_steps = self.stats.steps * self.cfg.batch_slots
        self.stats.tokens_out = sum(len(o) for o in outputs.values())
        return [outputs[i] for i in range(len(prompts))]

    def _first_token(self, rid: int) -> None:
        """Record time-to-first-token the moment a request's first
        completion token lands."""
        if rid not in self.stats.ttft_s:
            self.stats.ttft_s[rid] = time.perf_counter() - self._t0

    # -- continuous scheduler ------------------------------------------------
    def _run_continuous(self, queue, outputs, key):
        """One scheduler loop over the compiled steps: admit the ordered
        (rid, prompt-tail, budget) queue into free slots, ingest each
        slot's remaining prompt in ``prefill_chunk``-token blocks (mixed
        with single-token decodes for slots already past prefill), retire
        on EOS/budget and refill mid-flight while other slots keep
        working."""
        cfg = self.cfg
        n_slots = cfg.batch_slots
        chunk = cfg.prefill_chunk
        cache = self.model.init_cache(n_slots, cfg.max_len)
        rid = [-1] * n_slots              # -1 = free slot
        rem: List[List[int]] = [[] for _ in range(n_slots)]  # prompt left
        cur = [0] * n_slots               # next decode token per slot
        left = [0] * n_slots              # completion tokens still owed
        spos = [0] * n_slots              # slot's own cache position

        while queue or any(r >= 0 for r in rid):
            # admit: reset + refill every free slot from the queue (one
            # compiled reset call per step regardless of how many admit)
            admit = np.zeros((n_slots,), bool)
            for s in range(n_slots):
                if rid[s] < 0 and queue:
                    rid[s], prompt, budget = queue.pop(0)
                    rem[s] = list(prompt)
                    left[s] = budget
                    spos[s] = 0
                    admit[s] = True
            if admit.any():
                cache = self._reset(cache, jnp.asarray(admit))

            key, sub = jax.random.split(key)
            took = [0] * n_slots
            if any(rid[s] >= 0 and rem[s] for s in range(n_slots)):
                # mixed chunked step: prefilling slots eat a chunk,
                # decoding slots ride along with n_new == 1
                toks = np.zeros((n_slots, chunk), np.int32)
                n_new = np.ones((n_slots,), np.int32)
                for s in range(n_slots):
                    if rid[s] < 0:
                        continue
                    if rem[s]:
                        take = rem[s][:chunk]
                        took[s] = len(take)
                        n_new[s] = len(take)
                        toks[s, :len(take)] = take
                        self.stats.prefill_tokens += len(take)
                    else:
                        toks[s, 0] = cur[s]
                logits, cache = self._chunk_step(
                    self.params, cache, jnp.asarray(toks),
                    jnp.asarray(n_new))
                self.stats.prefill_steps += 1
            else:
                # pure decode step: the cheap (B, 1) path
                toks = np.zeros((n_slots, 1), np.int32)
                n_new = np.ones((n_slots,), np.int32)
                for s in range(n_slots):
                    if rid[s] >= 0:
                        toks[s, 0] = cur[s]
                logits, cache = self._step(self.params, cache,
                                           jnp.asarray(toks))
            nxt = np.asarray(self._sample(logits, sub))
            self.stats.steps += 1

            for s in range(n_slots):
                if rid[s] < 0:
                    continue
                self.stats.active_slot_steps += 1
                spos[s] += int(n_new[s])
                if took[s]:
                    rem[s] = rem[s][took[s]:]
                    if rem[s]:
                        continue              # still prefilling next step
                # prompt fully in cache: the sample is a completion token
                # (for a slot that just drained its prompt, the chunk's
                # last valid column produced it — first token for free)
                tok = int(nxt[s])
                self._first_token(rid[s])
                outputs[rid[s]].append(tok)
                left[s] -= 1
                if (left[s] <= 0
                        or (cfg.eos_token is not None
                            and tok == cfg.eos_token)
                        or spos[s] >= cfg.max_len - 1):
                    rid[s] = -1               # retire; refill next step
                else:
                    cur[s] = tok

    # -- paged scheduler (packed ragged prefill) -----------------------------
    def _run_packed(self, queue, outputs, key):
        """Continuous scheduling over the paged KV pool.

        Admission walks the ordered queue and admits every request that
        can get both a free slot and its worst-case page reservation
        (``ceil((tail + budget) / page_size)``); a request that cannot
        get pages blocks later requests **unless they need strictly
        fewer pages** (bounded bypass: a cheaper request can never delay
        the blocked head, whose reservation the bypassing one couldn't
        have satisfied anyway — and the head retains priority the
        moment its pages exist). Retiring a slot frees its pages and
        sentinels its block-table row immediately, so a recycled page
        can never be written through a stale table.

        While any slot holds un-ingested prompt, the step is one packed
        ``(pack_tokens,)`` stream: every active slot contributes at
        least one row (decoding slots exactly one — their next token),
        prefilling slots up to ``prefill_chunk`` rows as the budget
        allows, and the remainder is padding (slot index B, masked
        everywhere). Pure-decode steps drop to the (B, 1) path.
        """
        cfg = self.cfg
        n_slots = cfg.batch_slots
        chunk = cfg.prefill_chunk
        ps = cfg.page_size
        virtual = not self.model.paged_kv     # recurrent: nothing to page
        alloc = PageAllocator(self.num_pages)
        self.stats.pool_pages = 0 if virtual else self.num_pages
        for _, prompt, budget in queue:
            need = self._pages_needed(len(prompt), budget)
            if need > self.num_pages:
                raise ValueError(
                    f"request needs {need} KV pages but the pool holds "
                    f"{self.num_pages}; raise kv_pages or lower "
                    "max_len/max_new")
        if virtual:
            cache = self.model.init_cache(n_slots, cfg.max_len)
        else:
            cache = self.model.init_paged_cache(
                n_slots, cfg.max_len, ps, self.num_pages)
        tables = np.full((n_slots, self.max_pages), self.num_pages,
                         np.int32)
        tables_dirty = not virtual
        slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        rid = [-1] * n_slots
        rem: List[List[int]] = [[] for _ in range(n_slots)]
        cur = [0] * n_slots
        left = [0] * n_slots
        spos = [0] * n_slots

        def set_tables(c):
            # the block table may nest under "attn" (hybrid family)
            c = dict(c)
            if "block_tables" in c:
                c["block_tables"] = jnp.asarray(tables)
            elif "attn" in c and "block_tables" in c["attn"]:
                c["attn"] = dict(c["attn"])
                c["attn"]["block_tables"] = jnp.asarray(tables)
            return c

        while queue or any(r >= 0 for r in rid):
            # admit: free slots + page reservations, bounded bypass
            admit = np.zeros((n_slots,), bool)
            blocked_need = None
            pending = []
            for entry in queue:
                e_rid, prompt, budget = entry
                need = self._pages_needed(len(prompt), budget)
                free_slot = next((s for s in range(n_slots)
                                  if rid[s] < 0 and not admit[s]), None)
                bypass_ok = blocked_need is None or need < blocked_need
                pages = (alloc.alloc(need)
                         if free_slot is not None and bypass_ok else None)
                if free_slot is None or (need and pages is None) \
                        or not bypass_ok:
                    if blocked_need is None or need < blocked_need:
                        blocked_need = need
                    pending.append(entry)
                    continue
                s = free_slot
                rid[s], rem[s], left[s] = e_rid, list(prompt), budget
                spos[s] = 0
                slot_pages[s] = pages or []
                tables[s, :] = self.num_pages
                tables[s, :len(slot_pages[s])] = slot_pages[s]
                tables_dirty = tables_dirty or not virtual
                admit[s] = True
            queue[:] = pending
            if admit.any():
                cache = self._reset(cache, jnp.asarray(admit))
            if tables_dirty and not virtual:
                cache = set_tables(cache)
                tables_dirty = False
            self.stats.peak_resident_pages = max(
                self.stats.peak_resident_pages,
                0 if virtual else alloc.used_pages)
            self.stats.peak_active_requests = max(
                self.stats.peak_active_requests,
                sum(r >= 0 for r in rid))

            key, sub = jax.random.split(key)
            took = [0] * n_slots
            rows = [0] * n_slots              # packed rows per slot
            if any(rid[s] >= 0 and rem[s] for s in range(n_slots)):
                # packed step: lay out each active slot's rows in slot
                # order, reserving one row for every active slot after
                active = [s for s in range(n_slots) if rid[s] >= 0]
                toks = np.zeros((self.pack_tokens,), np.int32)
                slot_v = np.full((self.pack_tokens,), n_slots, np.int32)
                qpos = np.zeros((self.pack_tokens,), np.int32)
                last = np.zeros((n_slots,), np.int32)
                cursor = 0
                for j, s in enumerate(active):
                    reserve = len(active) - j - 1
                    if rem[s]:
                        take = min(len(rem[s]), chunk,
                                   self.pack_tokens - cursor - reserve)
                        take = max(take, 1)
                        took[s] = take
                        rows[s] = take
                        toks[cursor:cursor + take] = rem[s][:take]
                        self.stats.prefill_tokens += take
                    else:
                        rows[s] = 1
                        toks[cursor] = cur[s]
                    n = rows[s]
                    slot_v[cursor:cursor + n] = s
                    qpos[cursor:cursor + n] = np.arange(
                        spos[s], spos[s] + n)
                    cursor += n
                    last[s] = cursor - 1
                logits, cache = self._packed_step(
                    self.params, cache, jnp.asarray(toks),
                    jnp.asarray(slot_v), jnp.asarray(qpos),
                    jnp.asarray(last))
                self.stats.prefill_steps += 1
            else:
                # pure decode step: the cheap (B, 1) path
                toks = np.zeros((n_slots, 1), np.int32)
                for s in range(n_slots):
                    if rid[s] >= 0:
                        toks[s, 0] = cur[s]
                        rows[s] = 1
                logits, cache = self._step(self.params, cache,
                                           jnp.asarray(toks))
            nxt = np.asarray(self._sample(logits, sub))
            self.stats.steps += 1

            for s in range(n_slots):
                if rid[s] < 0:
                    continue
                self.stats.active_slot_steps += 1
                spos[s] += rows[s]
                if took[s]:
                    rem[s] = rem[s][took[s]:]
                    if rem[s]:
                        continue              # still prefilling next step
                tok = int(nxt[s])
                self._first_token(rid[s])
                outputs[rid[s]].append(tok)
                left[s] -= 1
                if (left[s] <= 0
                        or (cfg.eos_token is not None
                            and tok == cfg.eos_token)
                        or spos[s] >= cfg.max_len - 1):
                    rid[s] = -1               # retire: free pages now
                    alloc.free(slot_pages[s])
                    slot_pages[s] = []
                    tables[s, :] = self.num_pages
                    tables_dirty = tables_dirty or not virtual
                else:
                    cur[s] = tok

    # -- wave scheduler (parity reference) -----------------------------------
    def _run_wave(self, wave, outputs, key):
        """Serve one wave of (rid, prompt, budget) requests (<= batch_slots)
        from a fresh cache.

        Streams each slot's prompt through the compiled step token by
        token (prefill), then keeps stepping to decode; a slot flips from
        prefill to decode independently once its prompt is exhausted.
        """
        cfg = self.cfg
        n_slots = cfg.batch_slots
        prompts = [p for _, p, _ in wave]    # tails already truncated
        rids = [r for r, _, _ in wave]
        left = [b for _, _, b in wave]
        done = [False] * len(wave)
        cache = self.model.init_cache(n_slots, cfg.max_len)
        cur = np.zeros((n_slots, 1), np.int32)
        for s, p in enumerate(prompts):
            cur[s, 0] = p[0]

        pos = 0                        # step index (slots move in lockstep)
        while not all(done):
            key, sub = jax.random.split(key)
            logits, cache = self._step(self.params, cache, jnp.asarray(cur))
            nxt = np.asarray(self._sample(logits, sub))
            self.stats.steps += 1
            self.stats.active_slot_steps += sum(not d for d in done)
            for s in range(len(wave)):
                if done[s]:
                    continue
                if pos < len(prompts[s]):
                    self.stats.prefill_tokens += 1
                if pos + 1 < len(prompts[s]):
                    cur[s, 0] = prompts[s][pos + 1]   # still prefilling
                    continue
                tok = int(nxt[s])                     # prompt fully in cache
                self._first_token(rids[s])
                outputs[rids[s]].append(tok)
                left[s] -= 1
                if left[s] <= 0 or (cfg.eos_token is not None
                                    and tok == cfg.eos_token):
                    done[s] = True
                else:
                    cur[s, 0] = tok
            pos += 1
            if pos >= cfg.max_len - 1:
                break
        return key
