"""Batched decode engine: prefill + greedy/temperature decode against the
model's KV cache, with fixed-slot continuous batching (finished sequences
are replaced from a request queue without recompiling) and NEAT placement
support for reduced-precision serving."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementRule
from repro.core.quantize import use_rule
from repro.models.model_api import Model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    batch_slots: int = 8
    temperature: float = 0.0          # 0 = greedy
    eos_token: Optional[int] = None
    seed: int = 0


class DecodeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 rule: Optional[PlacementRule] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rule = rule
        with use_rule(rule):
            self._step = jax.jit(
                lambda p, c, t: model.decode_step(p, c, t))

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature).astype(jnp.int32)

    def generate(self, prompts: List[List[int]],
                 max_new_tokens: int = 32) -> List[List[int]]:
        """Serve a list of token prompts; returns completions per prompt.
        Requests are packed into fixed slots; finished slots pull the next
        queued request (continuous batching)."""
        cfg = self.cfg
        n_slots = cfg.batch_slots
        queue = list(enumerate(prompts))
        outputs: dict[int, List[int]] = {i: [] for i in range(len(prompts))}
        key = jax.random.key(cfg.seed)

        cache = self.model.init_cache(n_slots, cfg.max_len)
        slot_req = [-1] * n_slots            # request id per slot
        slot_left = [0] * n_slots            # tokens remaining
        cur = np.zeros((n_slots, 1), np.int32)

        def assign(slot):
            if not queue:
                slot_req[slot] = -1
                slot_left[slot] = 0
                return
            rid, prompt = queue.pop(0)
            slot_req[slot] = rid
            slot_left[slot] = max_new_tokens
            # prefill by stepping the prompt through the cache slot-wise:
            # simple (token-by-token) prefill keeps one compiled step fn.
            for t in prompt:
                cur[slot, 0] = t
            cur[slot, 0] = prompt[-1] if prompt else 0

        with use_rule(self.rule):
            for s in range(n_slots):
                assign(s)
            active = any(r >= 0 for r in slot_req)
            while active:
                key, sub = jax.random.split(key)
                logits, cache = self._step(self.params, cache,
                                           jnp.asarray(cur))
                nxt = np.asarray(self._sample(logits, sub))
                for s in range(n_slots):
                    rid = slot_req[s]
                    if rid < 0:
                        continue
                    tok = int(nxt[s])
                    outputs[rid].append(tok)
                    slot_left[s] -= 1
                    done = (slot_left[s] <= 0
                            or (cfg.eos_token is not None
                                and tok == cfg.eos_token))
                    if done:
                        assign(s)
                    else:
                        cur[s, 0] = tok
                active = any(r >= 0 for r in slot_req)
                pos = int(np.asarray(cache["pos"])) if "pos" in cache else 0
                if pos >= cfg.max_len - 1:
                    break
        return [outputs[i] for i in range(len(prompts))]
