"""Decode engine: prefill + greedy/temperature decode against the model's
KV cache, with NEAT placement support for reduced-precision serving.

Precision is a first-class policy surface: every engine carries ONE
:class:`~repro.core.policy.PrecisionPolicy` mapping ``(phase, layer) ->
(bits, mode)`` — phases are the engine's step kinds ({prefill, decode,
draft, verify}), layers the placement-rule site families. Each compiled
step program is traced under ``use_rule(policy.as_rule())`` plus a
``phase_scope`` naming its step kind, so the fused qk/pv hooks
(``_ambient_dot_bits``) and every ``quantize_here`` site resolve the
phase's own rule at trace time; phases marked ``weights=True``
additionally serve through policy-keyed truncated weight views
(:func:`~repro.core.policy.policy_params`). The legacy ``rule=`` kwarg
and ``SpecConfig.drafter_bits`` fold into a policy via
:meth:`PrecisionPolicy.from_rule` — byte-identical serving output.

``ServeConfig.tiers`` makes policies request-scoped: an ordered
``{tier_name: PrecisionPolicy}`` map (best first) partitions the slot
budget into per-tier sub-engines that share one compilation cache keyed
on ``policy.signature()`` (one set of compiled step programs per
distinct policy tier). ``generate(..., tiers=[...])`` assigns each
request an SLA class; admission may downgrade a request to a cheaper
tier under backlog pressure (``tier_backlog``), never below
``tier_floor``. ``ServeStats.per_tier`` reports per-tier tokens/sec,
acceptance, p50/p99 TTFT and (``estimate_energy=True``) estimated pJ
from the per-phase row counts times an abstractly-profiled decode-cell
cost — zero extra device dispatches.

Two schedulers share one compiled (batch, 1)-token decode step; the
continuous scheduler additionally runs a compiled **chunked-prefill**
step — and, with ``page_size > 0``, switches to the **paged** memory
layout and a **packed ragged prefill** step:

* the KV cache becomes a shared ``(num_pages, page_size, ...)`` pool
  per layer plus one ``(B, max_pages)`` block table, managed by a
  host-side :class:`PageAllocator` — pages are allocated on admission
  (the request's worst-case ``ceil((tail + budget) / page_size)``
  tokens), freed on retire, and **admission is gated on free pages, not
  free slots**: total resident KV is bounded by the live requests'
  actual needs, so at a fixed pool many more short requests run
  concurrently than the contiguous layout's ``B × max_len`` strips
  allow;
* prefill steps carry one packed ``(ΣC,)`` token stream instead of a
  ``(B, C)`` rectangle: each packed row names its owning slot and
  absolute cache position, decoding slots ride along as single rows,
  and the step's compute scales with *live tokens* (``pack_tokens``
  budget) rather than ``B × C`` padding.

* **continuous** (default): the KV cache carries a per-slot position
  vector, so the engine is a scheduler loop — admit queued requests into
  free slots *mid-flight*, ingest each slot's remaining prompt in
  ``prefill_chunk``-token blocks through one compiled
  ``Model.prefill_chunk`` call (attention families batch the chunk
  through the flash kernel's ``q_start`` path; recurrent families scan
  it on-device), retire on EOS/budget, and immediately refill. Steps are
  **mixed**: slots mid-prefill consume chunks while decoding slots emit
  one token in the same dispatch, ragged tails masked via per-slot
  ``n_new``/``kv_len``. Once no slot is prefilling the engine drops back
  to the cheap (batch, 1) decode step. A retired slot is reset (its KV
  entries and position zeroed) before reuse, and per-slot causal masking
  keys every slot on its own length, so a recycled slot can never attend
  to the previous request's KV entries. No wave barrier, no fresh-cache
  restarts. ``prefill_chunk=1`` degenerates to streaming prefill (the
  baseline the chunked path is benchmarked against).

* **wave**: the historical scheduler — requests are packed into fixed
  slots wave by wave, every prompt token streamed through the decode
  step, and a finished wave pulls the next requests from the queue.
  Kept as the parity reference: under greedy decoding both schedulers
  produce identical per-request completions.

Both schedulers admit from one queue whose order is the configured
admission policy — ``"fifo"`` (arrival) or ``"sjf"`` (fewest remaining
prefill *steps* first: ``ceil(len(tail) / prefill_chunk)`` for the
continuous engine, the raw tail length for the streaming wave
scheduler) — and every request carries its own ``max_new`` budget
(``generate(prompts, max_new_tokens=[...])``; an int broadcasts).
``ServeStats`` tracks per-request time-to-first-token alongside the
step/occupancy accounting. Internally each scheduler is a *generator*
yielding once per compiled step, which is what lets the tiered engine
round-robin several sub-engines through one wall clock.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import census as _census
from repro.core.placement import PlacementRule
from repro.core.policy import (PhaseSpec, PrecisionPolicy, policy_params,
                               uniform_param_views)
from repro.core.quantize import use_rule
from repro.core.scope import PHASES, phase_scope
from repro.models.model_api import Model, build_model


def drafter_params(params, bits: int, mode: str = "rne"):
    """Mantissa-truncated weight views for the NEAT drafter: every float
    leaf reduced to ``bits`` effective mantissa bits (identity at native
    width), non-float leaves untouched. The drafter is the *same* model
    under these views plus the ambient drafter rule — no second set of
    trained weights.

    Deprecated thin wrapper over
    :func:`repro.core.policy.uniform_param_views` (the ``weights=True``
    phase of a :class:`PrecisionPolicy` supersedes it)."""
    return uniform_param_views(params, bits, mode)


@dataclasses.dataclass
class SpecConfig:
    """Speculative-decoding policy for the continuous engine.

    The drafter is the serving model itself at reduced precision: its
    weights are mantissa-truncated views and its forward traces under
    the policy's "draft"-phase rule, which the fused attention path
    resolves through ``_ambient_dot_bits`` — the paper's genome applied
    to the draft phase of every request. Each step the drafter proposes
    ``k`` greedy tokens per decoding slot in ONE fused dispatch (a
    ``lax.scan`` of the decode cell with on-device argmax feedback,
    reading the *shared* KV prefix through the same block tables); the
    target model then verifies the whole window in one chunk-path
    dispatch. Greedy parity with the non-speculative engine is exact by
    construction — the emitted tokens are always the target's own
    argmax.

    ``drafter_bits``/``mode`` are the *deprecated* precision knobs: they
    apply only when no explicit ``policy=`` is passed to the engine
    (the legacy surface), folding into the policy's draft phase via
    ``PrecisionPolicy.drafter(bits, mode)`` semantics. New callers set
    the draft phase on the policy instead."""
    #: draft tokens proposed per slot per step (the window is k+1 rows)
    k: int = 4
    #: DEPRECATED drafter mantissa bits incl. the implicit bit (fp32:
    #: 1..24; 24 = identity drafter, acceptance is exactly 1); ignored
    #: when the engine is given an explicit PrecisionPolicy
    drafter_bits: int = 10
    #: DEPRECATED rounding mode for weight views + fused truncation
    mode: str = "rne"
    #: scale each slot's draft budget by its trailing acceptance EMA
    #: (deterministic; resets to 1.0 on admission)
    adaptive: bool = False
    #: explicit drafter weights (a genuinely different draft model);
    #: None derives mantissa-truncated views of the serving weights
    draft_params: Optional[object] = None


#: default for ``ServeConfig.debug_invariants`` when the field is left
#: None — the test-suite conftest flips this to True so the page-pool
#: accounting invariant runs on every scheduler step in tier-1
DEBUG_INVARIANTS_DEFAULT = False


@dataclasses.dataclass
class Request:
    """One queued serving request as the schedulers see it.

    ``tail`` is the cache-truncated prompt tail still to ingest;
    ``budget`` the remaining completion allowance at submit time.
    ``priority`` orders admission (higher first) and shields a slot from
    preemption by lower-priority work; ``deadline_s`` is a TTFT SLA
    relative to ``arrival_s`` (both relative to generate() start) — a
    request still *queued* past its deadline is shed with status
    ``shed_deadline`` (a running slot is never shed on deadline: it has
    its first token by definition). ``restore`` is the preemption swap
    payload: the slot's host-gathered KV/dense state plus its scheduler
    registers, written back verbatim on re-admission."""
    rid: int
    tail: List[int]
    budget: int
    priority: int = 0
    deadline_s: Optional[float] = None
    arrival_s: float = 0.0
    preempts: int = 0
    restore: Optional[dict] = None


@dataclasses.dataclass
class KVConfig:
    """KV-cache memory layout for the continuous engine.

    ``page_size == 0`` keeps the contiguous per-slot ``(B, max_len)``
    strips; ``> 0`` switches to the paged pool + block tables + packed
    ragged prefill. ``ServeConfig`` still accepts the historical flat
    ``page_size=/kv_pages=/pack_tokens=`` kwargs as a shim — they fold
    into (and must agree with) this nested config."""
    #: KV page size in tokens; 0 = contiguous (B, max_len) strips.
    #: Must divide ``max_len`` so the paged logical length equals the
    #: contiguous S axis (keeps the attention reductions identical).
    page_size: int = 0
    #: total pool pages; 0 derives ``batch_slots * ceil(max_len /
    #: page_size)`` — the same token capacity as the contiguous layout.
    #: Smaller pools trade concurrency headroom for memory; admission
    #: blocks (backpressure) rather than overcommitting.
    pages: int = 0
    #: packed-stream width per compiled prefill step (ΣC); 0 derives
    #: ``batch_slots * prefill_chunk``. Must be >= batch_slots so every
    #: active slot gets at least one row per step.
    pack_tokens: int = 0
    #: block-table entries the paged flash kernel streams per KV grid
    #: step (``block_k = pages_per_block * page_size``) — lets small
    #: pages fill the MXU tile without changing the pool layout or the
    #: logical attention math (greedy completions are identical across
    #: values). Requires the paged layout; 1 = one page per block.
    pages_per_block: int = 1


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    batch_slots: int = 8
    temperature: float = 0.0          # 0 = greedy
    eos_token: Optional[int] = None
    seed: int = 0
    engine: str = "continuous"        # "continuous" | "wave"
    #: queue admission order: "fifo" (arrival) or "sjf" (shortest job
    #: first — short requests stop convoying behind long prefills; a
    #: stable sort keeps arrival order among equal keys). The sjf key is
    #: the post-chunking remaining-prefill length: the number of compiled
    #: prefill steps the admitted tail will actually consume — with a
    #: **page-availability tie-break** on the paged engine: among equal
    #: step keys, the request needing fewer KV pages sorts first (then
    #: arrival order), so a short-prompt request with a huge completion
    #: budget cannot hold the queue head while cheaper requests could
    #: already run. Completions are returned in request order either
    #: way, and greedy outputs are admission-order independent.
    admission: str = "fifo"
    #: tokens each prefilling slot ingests per compiled step (continuous
    #: engine only; 1 = legacy streaming prefill, token by token)
    prefill_chunk: int = 32
    #: pure-decode steps fused into ONE device dispatch (a "megastep"
    #: ``lax.while_loop`` with on-device token feedback and per-slot
    #: EOS/budget stop — see ``models/decode_loop``); the host syncs
    #: once per window instead of once per token. 1 = the historical
    #: sync-every-token loop. > 1 requires the continuous engine; the
    #: engine drops back to single steps whenever scheduling events are
    #: possible (prefilling slots, speculative windows, or — under
    #: sampling — pending admissions), and greedy output is
    #: byte-identical across megastep boundaries by construction.
    sync_every: int = 1
    #: DEPRECATED flat paging kwargs — the shim for the nested ``kv``
    #: config below. None defers to ``kv``; setting both to conflicting
    #: values is an error.
    page_size: Optional[int] = None
    kv_pages: Optional[int] = None
    pack_tokens: Optional[int] = None
    #: nested KV/paging layout; None derives from the flat kwargs (or
    #: all-contiguous defaults). After ``__post_init__`` the flat fields
    #: are plain ints kept in sync with this, so both surfaces read the
    #: same truth.
    kv: Optional[KVConfig] = None
    #: speculative decoding policy; None serves non-speculatively.
    #: Requires the continuous engine and greedy (temperature 0).
    spec: Optional[SpecConfig] = None
    #: assert the page-pool accounting invariant (free + resident ==
    #: total, swapped-out count consistent) after every step — cheap,
    #: host-side; None defers to the module default (the test-suite
    #: conftest turns it on for every tier-1 engine)
    debug_invariants: Optional[bool] = None
    #: paged admission reservation: "lazy" reserves only the prompt's
    #: pages plus one decode page (the scheduler grows the slot at page
    #: boundaries, preempting if the pool is empty), "worst_case" the
    #: historical full ``ceil((tail + budget) / page_size)`` up front
    #: (growth never triggers; backpressure blocks admission instead)
    reserve: str = "lazy"
    #: allow the scheduler to preempt the lowest-priority / most-
    #: recently-admitted slot (KV swapped to host, request re-queued
    #: with a restore payload) when growth finds the pool empty or a
    #: higher-priority request cannot be placed. False falls back to
    #: stalling (and, as a last resort, shedding) instead.
    preempt: bool = True
    #: fault injection for tests/benches: request ids forcibly swapped
    #: out once, as soon as the slot has emitted its first token —
    #: exercises the snapshot/free/restore path on ANY schedule,
    #: independent of pool pressure or priority inversions (continuous
    #: engines only; the wave scheduler never preempts).
    force_preempt: Sequence[int] = ()
    #: SLA precision tiers: ordered {name: PrecisionPolicy}, best
    #: (most exact / most expensive) first. Non-None partitions
    #: ``batch_slots`` (and the page pool / pack budget) into per-tier
    #: sub-engines; ``generate(..., tiers=...)`` routes requests.
    tiers: Optional[Dict[str, PrecisionPolicy]] = None
    #: slots per tier; None splits ``batch_slots`` evenly (earlier tiers
    #: take the remainder). Must sum to <= batch_slots, each >= 1.
    tier_slots: Optional[Dict[str, int]] = None
    #: the worst tier admission may downgrade a request to; None = the
    #: last (cheapest) tier.
    tier_floor: Optional[str] = None
    #: backlog-pressure downgrade threshold: at submit time a request
    #: whose tier already has >= tier_backlog * tier_slots requests
    #: assigned in this batch walks down to the next tier (never past
    #: the floor). 0 disables downgrading.
    tier_backlog: int = 0
    #: fill ``ServeStats.est_pj`` after generate: per-phase row counts
    #: times an abstractly-profiled decode-cell cost under that phase's
    #: rule (jaxpr walk on ShapeDtypeStructs — zero device dispatches).
    estimate_energy: bool = False

    def __post_init__(self):
        # -- KV/paging: nested KVConfig with the flat-kwarg shim
        flats = (("page_size", self.page_size, "page_size"),
                 ("kv_pages", self.kv_pages, "pages"),
                 ("pack_tokens", self.pack_tokens, "pack_tokens"))
        if self.kv is None:
            self.kv = KVConfig(page_size=self.page_size or 0,
                               pages=self.kv_pages or 0,
                               pack_tokens=self.pack_tokens or 0)
        else:
            for flat_name, flat_val, kv_name in flats:
                kv_val = getattr(self.kv, kv_name)
                if flat_val is not None and int(flat_val) != kv_val:
                    raise ValueError(
                        f"conflicting paging config: {flat_name}="
                        f"{flat_val} but kv.{kv_name}={kv_val}; set the "
                        "paging layout through KVConfig (or the flat "
                        "kwargs) — not both")
        self.page_size = self.kv.page_size
        self.kv_pages = self.kv.pages
        self.pack_tokens = self.kv.pack_tokens
        if self.debug_invariants is None:
            self.debug_invariants = DEBUG_INVARIANTS_DEFAULT
        # -- validation: catch implicit invalid combos at construction
        if self.reserve not in ("lazy", "worst_case"):
            raise ValueError(f"unknown reserve mode {self.reserve!r}; "
                             "one of ('lazy', 'worst_case')")
        if self.engine not in ("continuous", "wave"):
            raise ValueError(f"unknown engine {self.engine!r}; one of "
                             "('continuous', 'wave')")
        if self.admission not in ("fifo", "sjf"):
            raise ValueError(f"unknown admission policy "
                             f"{self.admission!r}; one of ('fifo', 'sjf')")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.sync_every < 1:
            raise ValueError(f"sync_every must be >= 1; got "
                             f"{self.sync_every}")
        if self.sync_every > 1 and self.engine != "continuous":
            raise ValueError(
                "fused decode megasteps (sync_every > 1) require the "
                f"continuous engine; got engine={self.engine!r}")
        if self.page_size < 0 or self.kv_pages < 0 or self.pack_tokens < 0:
            raise ValueError("page_size/kv_pages/pack_tokens must be >= 0")
        if self.page_size and self.engine != "continuous":
            raise ValueError("paged KV (page_size > 0) requires the "
                             "continuous engine; got engine="
                             f"{self.engine!r}")
        if self.page_size and self.max_len % self.page_size != 0:
            raise ValueError(
                f"page_size={self.page_size} must divide max_len="
                f"{self.max_len} so the paged logical length equals the "
                "contiguous S axis; pick e.g. page_size="
                f"{self._suggest_page_size()}")
        ppb = self.kv.pages_per_block
        if ppb < 1:
            raise ValueError(
                f"kv.pages_per_block must be >= 1; got {ppb}")
        if ppb != 1 and not self.page_size:
            raise ValueError(
                f"kv.pages_per_block={ppb} requires the paged KV layout "
                "(page_size > 0): it widens the paged flash kernel's KV "
                "block to block_k = pages_per_block * page_size, which "
                "the contiguous layout has no block table to feed")
        if ppb != 1 and ppb * self.page_size > self.max_len:
            raise ValueError(
                f"kv.pages_per_block={ppb} * page_size={self.page_size} "
                f"= {ppb * self.page_size} exceeds max_len={self.max_len}"
                ": the KV block would be wider than the whole logical "
                "sequence; pick pages_per_block <= "
                f"{max(1, self.max_len // max(self.page_size, 1))}")
        if self.page_size and self.pack_tokens \
                and self.pack_tokens < self.batch_slots:
            raise ValueError(
                f"pack_tokens={self.pack_tokens} < batch_slots="
                f"{self.batch_slots}: every active slot needs at least "
                "one packed row per step; raise pack_tokens (or leave it "
                "0 to derive batch_slots * prefill_chunk)")
        if self.spec is not None:
            if self.engine != "continuous":
                raise ValueError(
                    "speculative decoding requires the continuous "
                    f"engine; got engine={self.engine!r}")
            if self.temperature > 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only; got "
                    f"temperature={self.temperature} (set it to 0 or "
                    "drop spec)")
            if self.spec.k < 1:
                raise ValueError(f"spec.k must be >= 1; got {self.spec.k}")
        if self.tiers is not None:
            names = list(self.tiers)
            if not names:
                raise ValueError("tiers must name at least one tier")
            if self.tier_slots is not None:
                unknown = set(self.tier_slots) - set(names)
                if unknown:
                    raise ValueError(f"tier_slots names unknown tiers "
                                     f"{sorted(unknown)}")
                if any(v < 1 for v in self.tier_slots.values()):
                    raise ValueError("every tier needs >= 1 slot")
                if sum(self.tier_slots.values()) > self.batch_slots:
                    raise ValueError(
                        f"tier_slots sum to "
                        f"{sum(self.tier_slots.values())} > batch_slots="
                        f"{self.batch_slots}")
            elif len(names) > self.batch_slots:
                raise ValueError(f"{len(names)} tiers need at least "
                                 f"{len(names)} batch_slots")
            if self.tier_floor is not None and self.tier_floor not in names:
                raise ValueError(f"tier_floor {self.tier_floor!r} is not "
                                 f"a configured tier {names}")
            if self.tier_backlog < 0:
                raise ValueError("tier_backlog must be >= 0")

    def _suggest_page_size(self) -> int:
        for cand in range(min(self.page_size, self.max_len), 0, -1):
            if self.max_len % cand == 0:
                return cand
        return 1


def _percentile(vals: Sequence[float], q: float) -> float:
    """True nearest-rank percentile: the ``ceil(q * n)``-th smallest
    value (1-indexed), ``q`` in [0, 1]. The historical
    ``round(q * (n - 1))`` form biased small-sample p99 low (banker's
    rounding pulled the rank toward the median). 0.0 on empty input."""
    if not vals:
        return 0.0
    vals = sorted(vals)
    n = len(vals)
    return vals[min(n - 1, max(0, math.ceil(q * n) - 1))]


@dataclasses.dataclass
class ServeStats:
    """Occupancy + latency accounting for the last ``generate`` call."""
    steps: int = 0                    # logical decode/prefill steps
    active_slot_steps: int = 0        # slot-steps spent on a live request
    slot_steps: int = 0               # steps * batch_slots
    tokens_out: int = 0               # completion tokens emitted
    n_requests: int = 0
    prefill_steps: int = 0            # steps where >= 1 slot ate a chunk
    prefill_tokens: int = 0           # prompt tokens ingested
    #: paged engine: pool size, high-water mark of allocated pages and
    #: of concurrently admitted requests (0 on the contiguous path)
    pool_pages: int = 0
    peak_resident_pages: int = 0
    peak_active_requests: int = 0
    #: per-request time-to-first-token, seconds since generate() started
    ttft_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    #: fused decode windows consumed (``sync_every > 1`` only); each one
    #: covered up to ``sync_every`` of the logical ``steps`` above in a
    #: single device dispatch
    megasteps: int = 0
    #: blocking device→host pulls the scheduler performed (one per
    #: ``_pull`` sync point — the async loop's denominator: at
    #: ``sync_every = N`` pure-decode syncs drop ~N-fold)
    host_syncs: int = 0
    #: wall seconds spent blocked inside those pulls waiting on device
    #: results — the "device" side of the host/device wall split;
    #: ``host_sched_s`` is the remainder
    dispatch_wait_s: float = 0.0
    #: per-token emission latency samples, seconds: each step boundary's
    #: wall time divided evenly over the tokens it emitted (a fused
    #: window's tokens share its window wall — what a streaming client
    #: observes); feeds ``p50_tok_lat_s``/``p99_tok_lat_s``
    tok_lat_s: List[float] = dataclasses.field(default_factory=list)
    #: speculative decoding accounting (zeros outside spec mode)
    draft_steps: int = 0              # fused k-step drafter dispatches
    verify_steps: int = 0             # target verify dispatches
    spec_windows: int = 0             # per-slot speculation windows run
    draft_tokens: int = 0             # draft tokens actually proposed
    accepted_tokens: int = 0          # drafts the target accepted
    #: per-window accepted-draft histogram: {n_accepted: windows}
    accepted_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: packed-step width-bucket histogram: {width: steps}
    packed_widths: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: valid rows dispatched per serving phase (billed to the phase of
    #: the compiled program that processed them — a prefill chunk riding
    #: a verify dispatch bills as "verify"); the draft phase bills the
    #: full ``batch_slots * k`` rows its fused scan computes
    phase_rows: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: wall-clock seconds generate() ran
    wall_s: float = 0.0
    #: estimated energy (picojoules) for the run: per-phase row counts
    #: times the abstract decode-cell cost under each phase's rule;
    #: 0.0 unless ``ServeConfig.estimate_energy``
    est_pj: float = 0.0
    #: measured per-phase dynamic bit census: the §III-C trailing-zero
    #: counts fused into the attention/matmul kernel epilogues (VMEM
    #: tiles summed into an SMEM scalar riding each step program — zero
    #: extra dispatches); empty unless ``ServeConfig.estimate_energy``
    phase_census: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: measured energy (picojoules) from the fused census: total active
    #: mantissa bits times the fp32 dot-op energy per full-width bit;
    #: 0.0 unless ``ServeConfig.estimate_energy``
    measured_pj: float = 0.0
    #: tiered serving: per-tier stats, request -> tier assignment, and
    #: how many requests admission downgraded below their asked tier
    per_tier: Dict[str, "ServeStats"] = dataclasses.field(
        default_factory=dict)
    tier_of: Dict[int, str] = dataclasses.field(default_factory=dict)
    downgraded: int = 0
    #: production-hardening accounting: structured per-request outcome
    #: (``ok | shed_deadline | shed_capacity | preempted_n``) instead of
    #: a raise anywhere in the scheduler
    status: Dict[int, str] = dataclasses.field(default_factory=dict)
    shed_deadline: int = 0            # expired while still queued
    shed_capacity: int = 0            # unplaceable (footprint > pool)
    preemptions: int = 0              # slots swapped out mid-flight
    swap_out_bytes: int = 0           # KV/state gathered to host
    swap_in_bytes: int = 0            # KV/state restored to device
    #: completion tokens from requests that actually finished (status
    #: ``ok`` or ``preempted_n``) — shed requests' partial output is
    #: wasted work and does not count; the serving number that survives
    #: overload, gated by the serve-burst bench
    goodput_tokens: int = 0

    @property
    def shed_rate(self) -> float:
        """Fraction of submitted requests shed (deadline or capacity)."""
        return ((self.shed_deadline + self.shed_capacity)
                / max(self.n_requests, 1))

    @property
    def goodput_per_s(self) -> float:
        return self.goodput_tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def occupancy(self) -> float:
        return self.active_slot_steps / max(self.slot_steps, 1)

    @property
    def mean_ttft_s(self) -> float:
        return (sum(self.ttft_s.values()) / len(self.ttft_s)
                if self.ttft_s else 0.0)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        return self.accepted_tokens / max(self.draft_tokens, 1)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def est_pj_per_token(self) -> float:
        return self.est_pj / max(self.tokens_out, 1)

    @property
    def measured_pj_per_token(self) -> float:
        return self.measured_pj / max(self.tokens_out, 1)

    @property
    def host_sched_s(self) -> float:
        """Wall seconds spent on host scheduling (admission, emission,
        retirement, Python loop) — everything not blocked on device."""
        return max(0.0, self.wall_s - self.dispatch_wait_s)

    def ttft_percentile(self, q: float) -> float:
        """Nearest-rank TTFT percentile over completed requests,
        ``q`` in [0, 1]. 0.0 with no requests recorded."""
        return _percentile(list(self.ttft_s.values()), q)

    @property
    def p50_ttft_s(self) -> float:
        return self.ttft_percentile(0.50)

    @property
    def p99_ttft_s(self) -> float:
        return self.ttft_percentile(0.99)

    def tok_lat_percentile(self, q: float) -> float:
        """Nearest-rank per-token latency percentile, ``q`` in [0, 1]."""
        return _percentile(self.tok_lat_s, q)

    @property
    def p50_tok_lat_s(self) -> float:
        return self.tok_lat_percentile(0.50)

    @property
    def p99_tok_lat_s(self) -> float:
        return self.tok_lat_percentile(0.99)


class PageAllocator:
    """Host-side free-list allocator over the shared KV pool.

    Pages are plain ints indexing every layer's pool identically. The
    free list is FIFO (freed pages recycle oldest-first), so allocation
    is deterministic for a fixed workload — the paged engine's step
    sequence, and therefore its stats, are reproducible."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages))
        #: pages' worth of KV currently swapped out to host buffers
        #: (preempted requests awaiting re-admission) — the swapped KV
        #: holds no pool pages, but the invariant cross-checks the
        #: engine's view of how much is parked on host
        self.swapped_out = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages, or None (and no change) if fewer are free."""
        if n > len(self._free):
            return None
        taken, self._free = self._free[:n], self._free[n:]
        return taken

    def free(self, pages: List[int]) -> None:
        self._free.extend(pages)

    def rollback(self, pages: List[int], committed_tokens: int,
                 page_size: int) -> int:
        """Resolve a slot's rejected speculative tail.

        The KV entries themselves are invalidated by the engine
        rewinding the slot's position vector — entries past the
        committed position are hidden by the per-slot ``kv_len``/causal
        masks and overwritten verbatim on the next genuine ingest — so
        the allocator's side of the contract is bookkeeping: the pages
        stay with the slot (admission reserved the worst case, so a
        rewind never shrinks ownership), and this checks the committed
        prefix still fits the reservation. Returns the number of pages
        the committed prefix actually references. Must run before the
        slot's pages can be freed — a retire mid-speculation-window
        frees pages only after the rollback resolved."""
        need = -(-committed_tokens // page_size) if committed_tokens else 0
        if need > len(pages):
            raise AssertionError(
                f"rollback: {committed_tokens} committed tokens need "
                f"{need} pages but the slot holds {len(pages)}")
        return need

    def note_swap_out(self, n: int) -> None:
        """Record ``n`` pages' worth of KV gathered to host (the pages
        themselves return to the free list via ``free``)."""
        self.swapped_out += n

    def note_swap_in(self, n: int) -> None:
        """Record ``n`` swapped pages restored to (newly allocated) pool
        pages — or discarded outright when a preempted request is shed
        before it could resume."""
        self.swapped_out -= n
        if self.swapped_out < 0:
            raise AssertionError(
                f"swap accounting broken: {n}-page swap-in drove the "
                "swapped-out count negative")

    def assert_invariant(self, resident: int,
                         swapped: Optional[int] = None) -> None:
        """``free + resident == total``: every pool page is exactly one
        of free or owned by a live slot. A retire that double-freed
        (e.g. mid-speculation EOS handled twice) or leaked pages trips
        this. ``swapped`` (when given) additionally cross-checks the
        engine's count of preempted pages parked in host buffers against
        the allocator's swap ledger."""
        if len(self._free) + resident != self.num_pages:
            raise AssertionError(
                f"page accounting broken: {len(self._free)} free + "
                f"{resident} resident != {self.num_pages} total")
        if swapped is not None and swapped != self.swapped_out:
            raise AssertionError(
                f"swap accounting broken: engine sees {swapped} pages "
                f"swapped out but the allocator ledger says "
                f"{self.swapped_out}")


def _phase_programs(model: Model, cfg: ServeConfig,
                    ambient: Optional[PlacementRule],
                    spec: Optional[SpecConfig],
                    collect_census: bool = False) -> dict:
    """Compile the engine's step programs, each traced under the policy
    ambient rule plus its authoritative phase tag. ``use_rule`` /
    ``phase_scope`` are thread-local and consulted at *trace* time, so
    wrapping inside the to-be-jitted callable (not around ``jax.jit``)
    keeps lazy retraces — new shapes, new width buckets — under the
    same rule. Closures deliberately capture only ``model``/``cfg``
    values (never an engine), so tiers with equal policy signatures can
    share one program set.

    ``collect_census=True`` additionally opens a census scope inside
    every traced program: the fused kernel epilogues note their §III-C
    bit counts on the tape and each program returns ``(out, bits)`` —
    one extra int32 scalar riding the existing dispatch. The engine
    unwraps the pair host-side (``DecodeEngine._counted``), so call
    sites keep the original arity."""
    chunk = cfg.prefill_chunk

    def phased(phase, fn):
        def run(*args):
            with use_rule(ambient), phase_scope(phase):
                if not collect_census:
                    return fn(*args)
                with _census.census_scope() as tape:
                    out = fn(*args)
                    return out, tape.total()
        return run

    # Every program that REBINDS the cache donates it (donate_argnums
    # on the cache operand): the engine never reuses a cache it handed
    # to one of these, so XLA updates the KV pools in place instead of
    # copying every layer's (B, S, KV, Dh) buffers per dispatch. The
    # one deliberate exception is "draft" below — the engine discards
    # the drafter's trial cache and verifies from the SAME committed
    # cache, so donating it there would read a deleted buffer.
    progs = {
        "step": jax.jit(phased(
            "decode", lambda p, c, t: model.decode_step(p, c, t)),
            donate_argnums=1),
        # the chunked-prefill step: (B, C) tokens + per-slot n_new in
        # one dispatch (mixed prefill/decode); compiled lazily, so
        # wave engines never pay for it
        "chunk_step": jax.jit(phased(
            "prefill", lambda p, c, t, n: model.prefill_chunk(p, c, t, n)),
            donate_argnums=1),
        # the packed-prefill step: one (ΣC,) ragged stream + per-row
        # slot/position vectors; per-slot rows are capped at
        # prefill_chunk (static, for the recurrent unpack rectangle)
        "packed_step": jax.jit(phased(
            "prefill", lambda p, c, t, s, q, l: model.prefill_packed(
                p, c, t, s, q, l, chunk)),
            donate_argnums=1),
        "reset": jax.jit(phased(
            "decode", lambda c, m: model.reset_slots(c, m)),
            donate_argnums=0),
    }
    if cfg.sync_every > 1:
        # the fused decode megastep: up to sync_every decode cells in
        # one while_loop dispatch, on-device sampling feedback + stop
        # detection; the census tape threads the loop carry so measured
        # pJ/token equals the single-step path exactly
        n_mega = cfg.sync_every
        progs["megastep"] = jax.jit(phased(
            "decode", lambda p, c, cur, pos, left, done, key, flush:
                model.decode_loop(
                    p, c, cur, pos, left, done, key, flush,
                    n_steps=n_mega, temperature=cfg.temperature,
                    eos_token=cfg.eos_token, max_len=cfg.max_len)),
            donate_argnums=1)
    if spec is not None:
        k = spec.k

        # ONE fused dispatch drafts k greedy tokens per slot: a
        # lax.scan of the decode cell with on-device argmax feedback,
        # traced under the policy's "draft" phase (thread-local, applies
        # at trace time, so the reduced-precision fused qk/pv path is
        # baked into this jit and only this jit). The drafter's cache
        # writes ride the SAME pools/block tables as the target; the
        # post-draft cache is simply discarded (JAX functional
        # semantics = free snapshot), so verification always starts
        # from the committed prefix.
        def _draft_fn(p, c, t):
            # census-tape shield: the decode cell's notes inside the
            # scan body are inner tracers, so collect per draft step and
            # thread the count out as a scan output (see core.census)
            active = _census.census_active()

            def step(carry, _):
                cc, tok = carry
                if active:
                    (logits, cc), cnt = _census.collect(
                        lambda: model.decode_step(p, cc, tok))
                else:
                    logits, cc = model.decode_step(p, cc, tok)
                nxt = jnp.argmax(
                    logits[:, -1, :],
                    axis=-1).astype(jnp.int32)[:, None]
                y = nxt[:, 0]
                return (cc, nxt), ((y, cnt) if active else y)
            (_, _), seq = jax.lax.scan(step, (c, t), None, length=k)
            if active:
                seq, counts = seq
                _census.note_count(jnp.sum(counts, dtype=jnp.int32))
            return seq.T              # (B, k)

        # no donation: the engine verifies from the SAME cache it
        # drafted against (the drafter's trial cache is discarded)
        progs["draft"] = jax.jit(phased("draft", _draft_fn))
        # target verify over the k+1 candidate rows — the existing
        # chunk path's q_start/kv_len math under the "verify" phase
        # (identity unless the policy says otherwise)
        progs["verify"] = jax.jit(phased(
            "verify", lambda p, c, tok, n, d, sp: model.spec_verify(
                p, c, tok, n, d, sp)),
            donate_argnums=1)
        vcap = max(cfg.prefill_chunk, k + 1)
        progs["verify_packed"] = jax.jit(phased(
            "verify", lambda p, c, t, s, q, ri, n, d, sp:
                model.spec_verify_packed(p, c, t, s, q, ri, n,
                                         d, sp, vcap)),
            donate_argnums=1)
    return progs


class DecodeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 rule: Optional[PlacementRule] = None,
                 policy: Optional[PrecisionPolicy] = None,
                 _programs: Optional[dict] = None):
        if rule is not None and policy is not None:
            raise ValueError("pass either rule= (deprecated) or policy=, "
                             "not both")
        from repro.models.attention import max_pages_for
        # multi-page KV blocks: the serving knob lives on KVConfig; the
        # kernel reads it from ModelConfig, so rebuild the model facade
        # under the widened block when they disagree
        ppb = cfg.kv.pages_per_block
        if model.cfg.pages_per_block != ppb:
            model = build_model(
                dataclasses.replace(model.cfg, pages_per_block=ppb))
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rule = rule
        self.stats = ServeStats()
        self.paged = cfg.page_size > 0
        #: fuse the §III-C bit census into every step program (the
        #: kernels' epilogue accumulator) — measured energy rides the
        #: abstract estimate's flag at zero extra dispatches
        self._collect_census = bool(cfg.estimate_energy)
        self._census_pending: Dict[str, list] = {}
        if self.paged:
            self.max_pages = max_pages_for(cfg.max_len, cfg.page_size)
            self.num_pages = (cfg.kv_pages or
                              cfg.batch_slots * self.max_pages)
            self.pack_tokens = (cfg.pack_tokens or
                                cfg.batch_slots * cfg.prefill_chunk)
            if self.pack_tokens < cfg.batch_slots:
                raise ValueError("pack_tokens must be >= batch_slots "
                                 "(every active slot needs one row)")
        self._spec = cfg.spec
        self._force_preempt = set(cfg.force_preempt or ())
        self._row_pj_cache: Dict[object, float] = {}

        # -- resolve the precision policy: the one surface every legacy
        #    entry point (rule=, SpecConfig.drafter_bits) folds into
        pol = (policy if policy is not None
               else PrecisionPolicy.from_rule(rule))
        if self._spec is not None and policy is None:
            # legacy SpecConfig drafter knobs → the policy's draft phase
            # (an explicit policy= owns its draft phase and wins)
            dspec = PhaseSpec(family="wp", sites=("__program__",),
                              bits=(int(self._spec.drafter_bits),),
                              mode=self._spec.mode, weights=True)
            phases = dict(pol.phases)
            phases["draft"] = dspec
            raw = {k: v for k, v in pol.raw_rules.items() if k != "draft"}
            pol = PrecisionPolicy(phases=phases, name=pol.name,
                                  raw_rules=raw)
        self._policy = pol
        self._ambient = pol.as_rule()     # None for the identity policy

        # -- tiered serving: partition slots into per-tier sub-engines
        self._tiered = cfg.tiers is not None
        if self._tiered:
            self._build_tiers(_programs if _programs is not None else {})
            return

        # -- per-phase weight views (policy-keyed generalization of the
        #    PR-6 drafter_params); identical specs share one view
        views: Dict[PhaseSpec, object] = {}

        def view_for(ph: str):
            if (ph == "draft" and self._spec is not None
                    and self._spec.draft_params is not None):
                return self._spec.draft_params
            spec = pol.spec_for(ph)
            if (ph in pol.raw_rules or not spec.weights
                    or spec.is_identity()):
                return params
            if spec not in views:
                views[spec] = jax.jit(
                    lambda p, s=spec: policy_params(p, s))(params)
            return views[spec]

        self._phase_params = {ph: view_for(ph) for ph in PHASES}
        self._draft_params = self._phase_params["draft"]

        # -- compiled step programs: one cached set per distinct policy
        #    tier (signature) — tiers with equal policies share jits
        key = (id(model), pol.signature(), cfg.prefill_chunk,
               None if self._spec is None else self._spec.k,
               self._collect_census, ppb, cfg.sync_every)
        progs = None if _programs is None else _programs.get(key)
        if progs is None:
            progs = _phase_programs(model, cfg, self._ambient, self._spec,
                                    collect_census=self._collect_census)
            if _programs is not None:
                _programs[key] = progs
        self._step = self._counted("decode", progs["step"])
        self._chunk_step = self._counted("prefill", progs["chunk_step"])
        self._packed_step = self._counted("prefill", progs["packed_step"])
        self._reset = self._counted("decode", progs["reset"])
        self._mega = (self._counted("decode", progs["megastep"])
                      if "megastep" in progs else None)
        if self._spec is not None:
            self._draft = self._counted("draft", progs["draft"])
            self._verify = self._counted("verify", progs["verify"])
            self._verify_packed = self._counted("verify",
                                                progs["verify_packed"])

    def _counted(self, phase: str, jfn):
        """Host-side unwrap of a census-collecting step program: record
        the program's fused bit count (a lazy device scalar — no sync
        until ``_finish_stats`` folds it) and restore the original
        return arity. Identity when census collection is off."""
        if not self._collect_census:
            return jfn

        def run(*args, **kw):
            out, c = jfn(*args, **kw)
            self._census_pending.setdefault(phase, []).append(c)
            return out
        return run

    def _fold_census(self) -> None:
        """Fold the pending per-step census scalars into
        ``stats.phase_census`` / ``stats.measured_pj`` (the only point
        the device scalars are transferred)."""
        if not self._collect_census:
            return
        pc = self.stats.phase_census
        for ph, vals in self._census_pending.items():
            pc[ph] = pc.get(ph, 0) + sum(int(v) for v in vals)
        self._census_pending.clear()
        if pc:
            from repro.core.estimators import census_energy_pj
            self.stats.measured_pj = census_energy_pj(sum(pc.values()))

    # -- tiered construction -------------------------------------------------
    def _build_tiers(self, programs: dict) -> None:
        """Partition ``batch_slots`` (and the page pool / pack budget)
        into one sub-engine per tier. Sub-engines share ``programs``
        (compilation cache keyed on policy signature), the parent's
        params, and — during generate — one wall clock, interleaved one
        compiled step at a time."""
        cfg = self.cfg
        names = list(cfg.tiers)
        slots = dict(cfg.tier_slots or {})
        if not slots:
            base, extra = divmod(cfg.batch_slots, len(names))
            for i, n in enumerate(names):
                slots[n] = base + (1 if i < extra else 0)
        total = sum(slots.values())
        self._programs = programs
        self._tier_names = names
        self._tier_slots = slots
        self._floor_idx = (names.index(cfg.tier_floor)
                           if cfg.tier_floor is not None else len(names) - 1)
        self._sub: Dict[str, DecodeEngine] = {}
        for n in names:
            frac = slots[n] / max(total, 1)
            sub_cfg = dataclasses.replace(
                cfg, tiers=None, tier_slots=None, tier_floor=None,
                batch_slots=slots[n],
                kv=KVConfig(
                    page_size=cfg.page_size,
                    pages=(max(1, round(cfg.kv_pages * frac))
                           if cfg.kv_pages else 0),
                    pack_tokens=(max(slots[n],
                                     round(cfg.pack_tokens * frac))
                                 if cfg.pack_tokens else 0),
                    pages_per_block=cfg.kv.pages_per_block),
                page_size=None, kv_pages=None, pack_tokens=None)
            self._sub[n] = DecodeEngine(self.model, self.params, sub_cfg,
                                        policy=cfg.tiers[n],
                                        _programs=programs)

    def _admit_tier(self, asked: str, backlog: Dict[str, int]) -> str:
        """Submit-time tier assignment: walk down from the asked tier
        while its backlog exceeds ``tier_backlog`` times its slots,
        never past the floor."""
        names = self._tier_names
        i = names.index(asked)
        if self.cfg.tier_backlog > 0:
            while (i < self._floor_idx
                   and backlog[names[i]] >= self.cfg.tier_backlog
                   * self._tier_slots[names[i]]):
                i += 1
        return names[i]

    def _pull(self, *arrays):
        """THE scheduler's blocking device→host sync point: transfer the
        given device arrays, attributing the blocked wall time to
        ``stats.dispatch_wait_s`` and counting one ``host_syncs`` event
        (several arrays pulled together are one round trip)."""
        t0 = time.perf_counter()
        out = tuple(np.asarray(a) for a in arrays)
        self.stats.dispatch_wait_s += time.perf_counter() - t0
        self.stats.host_syncs += 1
        return out[0] if len(out) == 1 else out

    def _flush_tok_lat(self) -> None:
        """Per-token latency sampling at a step boundary: the elapsed
        wall since the last emitting boundary, divided evenly over the
        tokens this step emitted (a fused window's tokens share its
        window wall — what a streaming client observes). Boundaries
        that emit nothing (pure prefill) accrue into the next token."""
        if self._step_emits:
            now = time.perf_counter()
            per = (now - self._last_emit_t) / self._step_emits
            self.stats.tok_lat_s.extend([per] * self._step_emits)
            self._last_emit_t = now
            self._step_emits = 0

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature).astype(jnp.int32)

    def _prompt_tail(self, prompt, max_new_tokens: int) -> List[int]:
        # keep only the prompt tail that leaves cache room for the full
        # completion — otherwise a near-max_len prompt would exhaust the
        # cache mid-prefill and silently return a short/empty completion
        keep = max(1, self.cfg.max_len - 1 - max_new_tokens)
        return list(prompt)[-keep:] if prompt else [0]

    def _budgets(self, prompts,
                 max_new_tokens: Union[int, Sequence[int]]) -> List[int]:
        """Per-request completion budgets: one int broadcasts; a sequence
        gives each request its own ``max_new`` ceiling."""
        if isinstance(max_new_tokens, (int, np.integer)):
            budgets = [int(max_new_tokens)] * len(prompts)
        else:
            budgets = [int(b) for b in max_new_tokens]
        if len(budgets) != len(prompts):
            raise ValueError(f"{len(budgets)} max_new budgets for "
                             f"{len(prompts)} prompts")
        if any(b < 1 for b in budgets):
            raise ValueError("per-request max_new budgets must be >= 1")
        return budgets

    def _prefill_stride(self) -> int:
        """Prompt tokens one compiled step ingests per slot: the chunk
        size for the continuous engine, 1 for the streaming wave path."""
        return (self.cfg.prefill_chunk if self.cfg.engine == "continuous"
                else 1)

    def _pages_needed(self, tail_len: int, budget: int) -> int:
        """Worst-case KV pages one request can touch: its prompt tail
        plus its full completion budget (the engine retires a slot
        before writing past this, so admission-time reservation never
        has to grow — exhaustion can only block *admission*, never a
        running request), clamped to the block-table width — a slot
        retires at ``max_len - 1`` anyway, so reserving past
        ``max_pages`` could never be used (and wouldn't fit the
        table)."""
        if not (self.paged and self.model.paged_kv):
            return 0
        return min(-(-(tail_len + budget) // self.cfg.page_size),
                   self.max_pages)

    def _admission_order(self, queue: List["Request"]) -> List["Request"]:
        """Apply the configured admission policy to a Request queue.
        Priority always sorts first (higher-priority requests admit —
        and may preempt — ahead of lower ones; the default 0 leaves the
        historical ordering untouched). ``sjf`` then sorts by the
        post-chunking remaining-prefill length — the compiled prefill
        steps the admitted tail will consume, ``ceil(len /
        prefill_stride)`` — stably, so chunked prefill doesn't misorder
        on sub-chunk length differences that cost identical step counts.
        On the paged engine the sjf key adds ``pages_needed``: a
        request's KV-page demand covers its *completion budget* too, so
        a short-prompt request with a huge ``max_new`` (cheap to
        prefill, expensive to hold) no longer outranks an equally-cheap
        request that could actually be admitted — the documented
        page-availability tie-break."""
        if self.cfg.admission == "sjf":
            stride = self._prefill_stride()
            return sorted(queue, key=lambda r: (
                -r.priority,
                -(-len(r.tail) // stride),
                self._pages_needed(len(r.tail), r.budget)))
        return sorted(queue, key=lambda r: -r.priority)

    def _shed(self, req: "Request", why: str) -> None:
        """Retire a request with a structured failure status instead of
        raising — the batch keeps serving."""
        self.stats.status[req.rid] = why
        if why == "shed_deadline":
            self.stats.shed_deadline += 1
        else:
            self.stats.shed_capacity += 1

    def _mark_done(self, req: "Request") -> None:
        """Record a completed request's outcome: ``ok``, or
        ``preempted_n`` when it was swapped out ``n`` times on the way
        (still a successful completion — its tokens count as goodput)."""
        self.stats.status[req.rid] = (
            "ok" if req.preempts == 0 else f"preempted_{req.preempts}")

    def _poll_queue(self, queue: List["Request"],
                    alloc: Optional["PageAllocator"] = None
                    ) -> List["Request"]:
        """One queue poll: shed requests whose TTFT deadline expired
        while they were still waiting (a running slot is never shed —
        it has its first token), and return the arrived, admissible
        subset. Not-yet-arrived requests stay queued untouched."""
        now = time.perf_counter() - self._t0
        ready, waiting = [], []
        for req in queue:
            if (req.deadline_s is not None
                    and now - req.arrival_s > req.deadline_s):
                if req.restore is not None:
                    # a preempted request expiring in the queue drops
                    # its host swap buffer — release the swap ledger
                    n = req.restore.get("pages_n", 0)
                    if alloc is not None and n:
                        alloc.note_swap_in(n)
                self._shed(req, "shed_deadline")
            elif now >= req.arrival_s:
                ready.append(req)
            else:
                waiting.append(req)
        queue[:] = ready + waiting
        return ready

    def _admit_pages(self, req: "Request") -> int:
        """Pages admission must secure before the request can occupy a
        slot. ``worst_case`` reserves the full remaining footprint up
        front (growth never fires); ``lazy`` reserves only what the
        first step can touch — the prompt tail's pages plus one decode
        page for a fresh request, the swapped content plus one page for
        a restore — and lets growth allocate the rest at page-boundary
        crossings."""
        if not (self.paged and self.model.paged_kv):
            return 0
        ps = self.cfg.page_size
        if req.restore is not None:
            r = req.restore
            total = r["spos"] + len(req.tail) + r["left"]
            foot = min(-(-total // ps), self.max_pages)
            if self.cfg.reserve == "worst_case":
                return max(foot, r["pages_n"])
            return max(r["pages_n"], min(r["pages_n"] + 1, foot))
        foot = self._pages_needed(len(req.tail), req.budget)
        if self.cfg.reserve == "worst_case":
            return foot
        return min(-(-len(req.tail) // ps) + 1, foot)

    def _snapshot(self, cache, s: int, live: int, pages: List[int]):
        """Gather slot ``s``'s live KV/state to host and count the swap
        bytes; returns the restore payload's snapshot half."""
        snap = self.model.snapshot_slot(cache, s, live, pages)
        nbytes = int(sum(np.asarray(x).nbytes
                         for x in jax.tree.leaves(snap)))
        self.stats.swap_out_bytes += nbytes
        return snap, nbytes

    # -- energy accounting ---------------------------------------------------
    def _phase_row_pj(self, phase: str) -> float:
        """Estimated pJ one valid row costs under ``phase``'s rule: the
        (B, 1) decode cell profiled abstractly (jaxpr walk over
        ShapeDtypeStructs — zero device dispatches), divided by B.
        Cached per distinct phase rule."""
        pol = self._policy
        key = (("raw", id(pol.raw_rules[phase]))
               if phase in pol.raw_rules else pol.spec_for(phase))
        if key in self._row_pj_cache:
            return self._row_pj_cache[key]
        from repro.core.estimators import abstract_step_energy
        B, L = self.cfg.batch_slots, self.cfg.max_len
        a_params = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype),
            self.params)
        a_cache = jax.eval_shape(lambda: self.model.init_cache(B, L))
        a_toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        rep = abstract_step_energy(
            lambda p, c, t: self.model.decode_step(p, c, t),
            a_params, a_cache, a_toks, rule=pol.rule_for(phase))
        val = rep.total_pj / max(B, 1)
        self._row_pj_cache[key] = val
        return val

    def _estimate_energy(self) -> float:
        return sum(rows * self._phase_row_pj(ph)
                   for ph, rows in self.stats.phase_rows.items() if rows)

    def _note_rows(self, phase: str, n: int) -> None:
        pr = self.stats.phase_rows
        pr[phase] = pr.get(phase, 0) + int(n)

    @staticmethod
    def _per_request(val, n: int, default, name: str) -> list:
        """Broadcast a scalar (or None) per-request knob to n entries."""
        if val is None:
            return [default] * n
        if isinstance(val, (int, float, np.integer, np.floating)):
            return [val] * n
        out = list(val)
        if len(out) != n:
            raise ValueError(f"{len(out)} {name} values for {n} prompts")
        return out

    # -- generate ------------------------------------------------------------
    def generate(self, prompts: List[List[int]],
                 max_new_tokens: Union[int, Sequence[int]] = 32,
                 tiers: Union[None, str, Sequence[str]] = None,
                 priority: Union[None, int, Sequence[int]] = None,
                 deadline_s=None, arrival_s=None) -> List[List[int]]:
        """Serve a list of token prompts; returns completions per prompt.
        ``max_new_tokens`` is a global ceiling (int) or one budget per
        request. ``tiers`` (tiered engines only) names each request's
        asked SLA class (a str broadcasts; default = the best tier).
        ``priority`` (int, higher admits/preempts first), ``deadline_s``
        (TTFT SLA relative to the request's arrival) and ``arrival_s``
        (open-loop arrival offset from the call start) are per-request
        or broadcast; requests that expire queued or can never fit the
        KV pool are retired with a structured ``self.stats.status``
        entry (``shed_deadline`` / ``shed_capacity``) instead of
        raising. ``self.stats`` holds step/occupancy/TTFT accounting."""
        if self._tiered:
            return self._generate_tiered(prompts, max_new_tokens, tiers,
                                         priority, deadline_s, arrival_s)
        if tiers is not None:
            raise ValueError("tiers= requires ServeConfig.tiers")
        self.stats = ServeStats(n_requests=len(prompts))
        self._force_preempt = set(self.cfg.force_preempt or ())
        self._t0 = time.perf_counter()
        self._step_emits = 0
        self._last_emit_t = self._t0
        outputs: dict[int, List[int]] = {i: [] for i in range(len(prompts))}
        budgets = self._budgets(prompts, max_new_tokens)
        key = jax.random.key(self.cfg.seed)
        n = len(prompts)
        prios = self._per_request(priority, n, 0, "priority")
        deads = self._per_request(deadline_s, n, None, "deadline_s")
        arrs = self._per_request(arrival_s, n, 0.0, "arrival_s")
        # both schedulers admit the cache-truncated prompt tails, so
        # the sjf sort key is computed on the length actually prefilled
        queue = self._admission_order(
            [Request(rid=rid, tail=self._prompt_tail(p, budgets[rid]),
                     budget=budgets[rid], priority=int(prios[rid]),
                     deadline_s=deads[rid], arrival_s=float(arrs[rid]))
             for rid, p in enumerate(prompts)])
        for _ in self._scheduler(queue, outputs, key):
            pass
        self._finish_stats(outputs)
        return [outputs[i] for i in range(len(prompts))]

    def _scheduler(self, queue, outputs, key):
        """The engine's scheduler as a generator yielding once per
        compiled step — the unit the tiered engine round-robins."""
        if self.cfg.engine == "continuous" and self.paged:
            return self._run_packed(queue, outputs, key)
        if self.cfg.engine == "continuous":
            return self._run_continuous(queue, outputs, key)
        return self._run_waves(queue, outputs, key)

    def _finish_stats(self, outputs) -> None:
        self.stats.slot_steps = self.stats.steps * self.cfg.batch_slots
        self.stats.tokens_out = sum(len(o) for o in outputs.values())
        st = self.stats.status
        for rid in outputs:
            st.setdefault(rid, "ok")
        self.stats.goodput_tokens = sum(
            len(o) for rid, o in outputs.items()
            if st[rid] == "ok" or st[rid].startswith("preempted"))
        self.stats.wall_s = time.perf_counter() - self._t0
        if self.cfg.estimate_energy:
            self.stats.est_pj = self._estimate_energy()
            self._fold_census()

    def _generate_tiered(self, prompts, max_new_tokens, tiers,
                         priority=None, deadline_s=None, arrival_s=None
                         ) -> List[List[int]]:
        names = self._tier_names
        if tiers is None:
            asked = [names[0]] * len(prompts)
        elif isinstance(tiers, str):
            asked = [tiers] * len(prompts)
        else:
            asked = list(tiers)
        if len(asked) != len(prompts):
            raise ValueError(f"{len(asked)} tier names for "
                             f"{len(prompts)} prompts")
        unknown = set(asked) - set(names)
        if unknown:
            raise ValueError(f"unknown tiers {sorted(unknown)}; "
                             f"configured: {names}")
        budgets = self._budgets(prompts, max_new_tokens)
        n_req = len(prompts)
        prios = self._per_request(priority, n_req, 0, "priority")
        deads = self._per_request(deadline_s, n_req, None, "deadline_s")
        arrs = self._per_request(arrival_s, n_req, 0.0, "arrival_s")
        stats = ServeStats(n_requests=len(prompts))
        # submit-time tier assignment (downgrade under backlog pressure)
        backlog = {n: 0 for n in names}
        for rid, t in enumerate(asked):
            got = self._admit_tier(t, backlog)
            if got != t:
                stats.downgraded += 1
            backlog[got] += 1
            stats.tier_of[rid] = got
        by_tier = {n: [r for r in range(len(prompts))
                       if stats.tier_of[r] == n] for n in names}
        outputs: dict[int, List[int]] = {i: [] for i in range(len(prompts))}
        t0 = time.perf_counter()
        self._t0 = t0
        gens = []
        for i, n in enumerate(names):
            sub = self._sub[n]
            sub.stats = ServeStats(n_requests=len(by_tier[n]))
            sub._t0 = t0
            sub._step_emits = 0
            sub._last_emit_t = t0
            sub._force_preempt = set(sub.cfg.force_preempt or ())
            if not by_tier[n]:
                continue
            queue = sub._admission_order(
                [Request(rid=r,
                         tail=sub._prompt_tail(prompts[r], budgets[r]),
                         budget=budgets[r], priority=int(prios[r]),
                         deadline_s=deads[r], arrival_s=float(arrs[r]))
                 for r in by_tier[n]])
            gens.append(sub._scheduler(
                queue, outputs, jax.random.key(self.cfg.seed + i)))
        # round-robin: one compiled step per live tier per turn, so all
        # tiers share the wall clock instead of running serially
        while gens:
            alive = []
            for g in gens:
                try:
                    next(g)
                    alive.append(g)
                except StopIteration:
                    pass
            gens = alive
        wall = time.perf_counter() - t0
        for n in names:
            sub = self._sub[n]
            st = sub.stats
            st.slot_steps = st.steps * sub.cfg.batch_slots
            st.tokens_out = sum(len(outputs[r]) for r in by_tier[n])
            for r in by_tier[n]:
                st.status.setdefault(r, "ok")
            st.goodput_tokens = sum(
                len(outputs[r]) for r in by_tier[n]
                if st.status[r] == "ok"
                or st.status[r].startswith("preempted"))
            st.wall_s = wall
            if self.cfg.estimate_energy:
                st.est_pj = sub._estimate_energy()
                sub._fold_census()
            stats.per_tier[n] = st
            self._merge_stats(stats, st)
        stats.wall_s = wall
        stats.n_requests = len(prompts)
        self.stats = stats
        return [outputs[i] for i in range(len(prompts))]

    @staticmethod
    def _merge_stats(dst: ServeStats, src: ServeStats) -> None:
        for f in ("steps", "active_slot_steps", "slot_steps", "tokens_out",
                  "prefill_steps", "prefill_tokens", "pool_pages",
                  "draft_steps", "verify_steps", "spec_windows",
                  "draft_tokens", "accepted_tokens", "est_pj",
                  "measured_pj", "megasteps", "host_syncs",
                  "dispatch_wait_s", "shed_deadline", "shed_capacity",
                  "preemptions", "swap_out_bytes", "swap_in_bytes",
                  "goodput_tokens"):
            setattr(dst, f, getattr(dst, f) + getattr(src, f))
        dst.peak_resident_pages += src.peak_resident_pages
        dst.peak_active_requests += src.peak_active_requests
        dst.status.update(src.status)
        dst.ttft_s.update(src.ttft_s)
        dst.tok_lat_s.extend(src.tok_lat_s)
        for d_dst, d_src in ((dst.accepted_hist, src.accepted_hist),
                             (dst.packed_widths, src.packed_widths),
                             (dst.phase_rows, src.phase_rows),
                             (dst.phase_census, src.phase_census)):
            for k, v in d_src.items():
                d_dst[k] = d_dst.get(k, 0) + v

    def _first_token(self, rid: int) -> None:
        """Record time-to-first-token the moment a request's first
        completion token lands."""
        if rid not in self.stats.ttft_s:
            self.stats.ttft_s[rid] = time.perf_counter() - self._t0

    # -- speculative-decoding helpers ----------------------------------------
    def _bucket_width(self, rows: int) -> int:
        """Packed-step width bucket: the smallest power of two covering
        the live row count, clamped to ``pack_tokens``. One cached
        compilation per bucket; mostly-decode steps stop paying the full
        rectangle's padding."""
        w = 1
        while w < rows:
            w <<= 1
        w = min(w, self.pack_tokens)
        self.stats.packed_widths[w] = self.stats.packed_widths.get(w, 0) + 1
        return w

    def _draft_tokens(self, cache, cur, rid, rem, left, spos, ema):
        """Run the fused drafter over the decoding slots; returns the
        per-slot draft budget ``kvec`` and a host-side (n_slots, k)
        draft-token array. ``kvec[s]`` clamps the window so the emitted
        tokens can never exceed the slot's completion budget or cache
        room (the window also emits the target's bonus token, hence the
        ``- 1``s); adaptive mode scales by the trailing acceptance
        EMA."""
        sc = self._spec
        n_slots = self.cfg.batch_slots
        kvec = [0] * n_slots
        for s in range(n_slots):
            if rid[s] < 0 or rem[s]:
                continue
            kb = sc.k
            if sc.adaptive:
                kb = max(1, min(sc.k, int(round(sc.k * ema[s]))))
            kvec[s] = max(0, min(kb, left[s] - 1,
                                 self.cfg.max_len - 2 - spos[s]))
        drafts = np.zeros((n_slots, sc.k), np.int32)
        if any(kvec):
            cur_t = np.zeros((n_slots, 1), np.int32)
            for s in range(n_slots):
                if rid[s] >= 0 and not rem[s]:
                    cur_t[s, 0] = cur[s]
            drafts = self._pull(self._draft(self._draft_params, cache,
                                            jnp.asarray(cur_t)))
            self.stats.draft_steps += 1
            # the fused scan computes all B slots for k cells regardless
            # of the per-slot clamps — bill what was dispatched
            self._note_rows("draft", n_slots * sc.k)
        return kvec, drafts

    def _note_window(self, s: int, acc: int, ks: int, ema) -> None:
        """Account one resolved speculation window and feed the slot's
        acceptance EMA (adaptive k). ``draft_tokens`` counts the drafts
        a verify dispatch actually consumed, so ``acceptance_rate`` is
        exactly accepted / verified."""
        self.stats.spec_windows += 1
        self.stats.draft_tokens += ks
        self.stats.accepted_tokens += acc
        self.stats.accepted_hist[acc] = (
            self.stats.accepted_hist.get(acc, 0) + 1)
        if self._spec.adaptive and ks > 0:
            ema[s] = 0.5 * ema[s] + 0.5 * (acc / ks)

    def _emit(self, s, rid, left, spos, outputs, toks, rows0) -> bool:
        """Append accepted+bonus tokens for slot ``s``; True if the slot
        must retire (budget, EOS, or cache exhaustion). ``rows0`` is the
        cache rows consumed before the first emitted token (1 for a
        speculation window whose tokens land one row apart; ``take`` for
        a prefill-draining slot whose single token rides the chunk)."""
        cfg = self.cfg
        for j, tok in enumerate(toks):
            self._first_token(rid[s])
            outputs[rid[s]].append(int(tok))
            self._step_emits += 1
            left[s] -= 1
            if (left[s] <= 0
                    or (cfg.eos_token is not None
                        and int(tok) == cfg.eos_token)
                    or spos[s] + rows0 + j >= cfg.max_len - 1):
                return True
        return False

    # -- continuous scheduler ------------------------------------------------
    def _run_continuous(self, queue, outputs, key):
        """One scheduler loop over the compiled steps: admit the ordered
        (rid, prompt-tail, budget) queue into free slots, ingest each
        slot's remaining prompt in ``prefill_chunk``-token blocks (mixed
        with single-token decodes for slots already past prefill), retire
        on EOS/budget and refill mid-flight while other slots keep
        working. Yields once per compiled step."""
        cfg = self.cfg
        n_slots = cfg.batch_slots
        chunk = cfg.prefill_chunk
        cache = self.model.init_cache(n_slots, cfg.max_len)
        rid = [-1] * n_slots              # -1 = free slot
        reqs: List[Optional[Request]] = [None] * n_slots
        rem: List[List[int]] = [[] for _ in range(n_slots)]  # prompt left
        cur = [0] * n_slots               # next decode token per slot
        left = [0] * n_slots              # completion tokens still owed
        spos = [0] * n_slots              # slot's own cache position
        prio = [0] * n_slots              # admitted request's priority
        seq = [0] * n_slots               # admission sequence number
        next_seq = 0
        ema = [1.0] * n_slots             # trailing acceptance (adaptive k)
        mega = None                       # in-flight dispatched window

        def preempt_slot(t: int) -> Request:
            """Swap slot ``t`` out: snapshot its dense KV/state rows to
            host (nothing to snapshot before any token entered the
            cache), free the slot and re-queue the request with the
            restore payload."""
            req = reqs[t]
            payload = None
            if spos[t] > 0:
                snap, nbytes = self._snapshot(cache, t, spos[t], [])
                payload = {"snap": snap, "spos": spos[t], "cur": cur[t],
                           "left": left[t], "pages_n": 0,
                           "nbytes": nbytes}
            req.tail = list(rem[t])
            req.restore = payload
            req.preempts += 1
            self.stats.preemptions += 1
            rid[t] = -1
            rem[t] = []
            reqs[t] = None
            return req

        while queue or any(r >= 0 for r in rid):
            if mega is not None and not any(r >= 0 for r in rid):
                # the dispatch-ahead window was issued past the last
                # retirement: it runs zero iterations — drop it
                mega = None
            # admit: reset + refill every free slot from the arrived
            # queue (one compiled reset call per step regardless of how
            # many admit). Skipped entirely while a dispatch-ahead
            # window is in flight — the device is running a carry the
            # host hasn't consumed, so slot state must not move under
            # it (chains only start with an empty queue, so nothing is
            # ever actually delayed).
            admit = np.zeros((n_slots,), bool)
            if mega is None:
                forced: List[Request] = []
                if self._force_preempt:
                    # fault injection: swap the marked request out the
                    # first time we see it past its first emitted token
                    for t in range(n_slots):
                        if (rid[t] >= 0
                                and rid[t] in self._force_preempt
                                and outputs[rid[t]]):
                            self._force_preempt.discard(rid[t])
                            forced.append(preempt_slot(t))
                ready = self._poll_queue(queue)
                waiting = queue[len(ready):]
                pending: List[Request] = []
                bumped: List[Request] = []
                restores = []
                for req in self._admission_order(ready):
                    s = next((t for t in range(n_slots) if rid[t] < 0),
                             None)
                    if s is None and cfg.preempt:
                        # priority preemption: the lowest-priority,
                        # most-recently-admitted slot strictly below
                        # the waiting request's priority yields
                        victims = [t for t in range(n_slots)
                                   if rid[t] >= 0 and not admit[t]
                                   and prio[t] < req.priority]
                        if victims:
                            s = min(victims,
                                    key=lambda t: (prio[t], -seq[t]))
                            bumped.append(preempt_slot(s))
                    if s is None:
                        pending.append(req)
                        continue
                    rid[s] = req.rid
                    reqs[s] = req
                    rem[s] = list(req.tail)
                    left[s] = req.budget
                    spos[s] = 0
                    cur[s] = 0
                    prio[s] = req.priority
                    seq[s] = next_seq
                    next_seq += 1
                    ema[s] = 1.0
                    admit[s] = True
                    if req.restore is not None:
                        restores.append((s, req))
                queue[:] = forced + bumped + pending + waiting
                if admit.any():
                    cache = self._reset(cache, jnp.asarray(admit))
                for s, req in restores:
                    # write the swapped rows back AFTER the batched
                    # reset (which zeroed the slot) — the request
                    # resumes exactly where preemption cut it
                    r = req.restore
                    cache = self.model.restore_slot(cache, s, r["spos"],
                                                    [], r["snap"])
                    spos[s] = r["spos"]
                    cur[s] = r["cur"]
                    left[s] = r["left"]
                    self.stats.swap_in_bytes += r["nbytes"]
                    req.restore = None
            if not any(r >= 0 for r in rid):
                if queue:
                    # open-loop idle: nothing admitted yet, arrivals
                    # still pending — tick without burning a step
                    time.sleep(2e-4)
                    yield
                continue

            # speculative step: every decoding slot drafts up to k
            # tokens (one fused reduced-precision dispatch), then the
            # target verifies all windows in one chunk-path dispatch —
            # prefilling slots ride the same rectangle as ordinary
            # chunk rows (mixed step)
            if self._spec is not None and any(
                    rid[s] >= 0 and not rem[s] for s in range(n_slots)):
                sc = self._spec
                kvec, drafts = self._draft_tokens(cache, cur, rid, rem,
                                                  left, spos, ema)
                prefilling = any(rid[s] >= 0 and rem[s]
                                 for s in range(n_slots))
                width = max(chunk, sc.k + 1) if prefilling else sc.k + 1
                toks = np.zeros((n_slots, width), np.int32)
                n_new = np.ones((n_slots,), np.int32)
                specv = np.zeros((n_slots,), bool)
                took = [0] * n_slots
                for s in range(n_slots):
                    if rid[s] < 0:
                        continue
                    if rem[s]:
                        take = rem[s][:chunk]
                        took[s] = len(take)
                        n_new[s] = len(take)
                        toks[s, :len(take)] = take
                        self.stats.prefill_tokens += len(take)
                    else:
                        ks = kvec[s]
                        toks[s, 0] = cur[s]
                        toks[s, 1:1 + ks] = drafts[s, :ks]
                        n_new[s] = ks + 1
                        specv[s] = True
                greedy, n_acc, cache = self._verify(
                    self._phase_params["verify"], cache, jnp.asarray(toks),
                    jnp.asarray(n_new), jnp.asarray(drafts),
                    jnp.asarray(specv))
                greedy, n_acc = self._pull(greedy, n_acc)
                self.stats.steps += 1
                self.stats.verify_steps += 1
                self._note_rows("verify", sum(
                    int(n_new[s]) for s in range(n_slots) if rid[s] >= 0))
                if prefilling:
                    self.stats.prefill_steps += 1
                for s in range(n_slots):
                    if rid[s] < 0:
                        continue
                    self.stats.active_slot_steps += 1
                    if took[s]:
                        rem[s] = rem[s][took[s]:]
                        adv = int(n_new[s])
                        if rem[s]:
                            spos[s] += adv
                            continue      # still prefilling next step
                        # prompt just drained: the chunk's last valid
                        # column produced the first completion token
                        tok = int(greedy[s, adv - 1])
                        if self._emit(s, rid, left, spos, outputs,
                                      [tok], adv):
                            self._mark_done(reqs[s])
                            reqs[s] = None
                            rid[s] = -1   # retire; refill next step
                        else:
                            spos[s] += adv
                            cur[s] = tok
                        continue
                    acc = int(n_acc[s])
                    if kvec[s] > 0:
                        self._note_window(s, acc, kvec[s], ema)
                    # emit the accepted drafts + the target's bonus
                    # token; the bonus is NOT ingested — it is next
                    # step's cur, exactly the non-speculative contract
                    emitted = [int(t) for t in greedy[s, :acc + 1]]
                    if self._emit(s, rid, left, spos, outputs, emitted,
                                  1):
                        self._mark_done(reqs[s])
                        reqs[s] = None
                        rid[s] = -1
                    else:
                        spos[s] += acc + 1
                        cur[s] = emitted[-1]
                self._flush_tok_lat()
                yield
                continue

            # fused megastep: every live slot is past its prompt — run
            # up to sync_every decode steps in ONE dispatch (see
            # models/decode_loop), syncing once per window. With
            # pending admissions the window flushes on the first
            # retirement (greedy only: that is exactly the step
            # boundary the single-step scheduler admits at, so output
            # stays byte-identical); sampled runs with a pending queue
            # stay single-step to keep the shared RNG stream aligned.
            if (self._mega is not None and self._spec is None
                    and any(r >= 0 for r in rid)
                    and not any(rem[s] for s in range(n_slots)
                                if rid[s] >= 0)
                    and (not queue or cfg.temperature <= 0.0)):
                if mega is None:
                    cur_a = np.zeros((n_slots, 1), np.int32)
                    pos_a = np.zeros((n_slots,), np.int32)
                    left_a = np.zeros((n_slots,), np.int32)
                    done_a = np.ones((n_slots,), bool)
                    for s in range(n_slots):
                        if rid[s] >= 0:
                            cur_a[s, 0] = cur[s]
                            pos_a[s] = spos[s]
                            left_a[s] = left[s]
                            done_a[s] = False
                    mega, cache = self._mega(
                        self._phase_params["decode"], cache,
                        jnp.asarray(cur_a), jnp.asarray(pos_a),
                        jnp.asarray(left_a), jnp.asarray(done_a), key,
                        jnp.asarray(bool(queue)))
                (ring_d, nem_d, done_d, cur_d, pos_d, left_d, key,
                 ns_d) = mega
                mega = None
                if not queue:
                    # dispatch-ahead double buffering: no admissions
                    # are possible, so the returned carry IS the next
                    # window's input — launch it before syncing this
                    # one (host emission overlaps device compute; a
                    # window dispatched past the last live slot runs
                    # zero iterations and is simply abandoned)
                    mega, cache = self._mega(
                        self._phase_params["decode"], cache, cur_d,
                        pos_d, left_d, done_d, key, jnp.asarray(False))
                ring, nem, done_h, ns = self._pull(ring_d, nem_d,
                                                   done_d, ns_d)
                tot = 0
                for s in range(n_slots):
                    if rid[s] < 0:
                        continue
                    k = int(nem[s])
                    tot += k
                    for t in ring[s, :k]:
                        self._first_token(rid[s])
                        outputs[rid[s]].append(int(t))
                        self._step_emits += 1
                    spos[s] += k
                    left[s] -= k
                    if done_h[s]:
                        self._mark_done(reqs[s])
                        reqs[s] = None
                        rid[s] = -1       # retire; refill next step
                    elif k:
                        cur[s] = int(ring[s, k - 1])
                self.stats.steps += int(ns)
                self.stats.megasteps += 1
                self.stats.active_slot_steps += tot
                self._note_rows("decode", tot)
                self._flush_tok_lat()
                yield
                continue

            key, sub = jax.random.split(key)
            took = [0] * n_slots
            if any(rid[s] >= 0 and rem[s] for s in range(n_slots)):
                # mixed chunked step: prefilling slots eat a chunk,
                # decoding slots ride along with n_new == 1
                toks = np.zeros((n_slots, chunk), np.int32)
                n_new = np.ones((n_slots,), np.int32)
                for s in range(n_slots):
                    if rid[s] < 0:
                        continue
                    if rem[s]:
                        take = rem[s][:chunk]
                        took[s] = len(take)
                        n_new[s] = len(take)
                        toks[s, :len(take)] = take
                        self.stats.prefill_tokens += len(take)
                    else:
                        toks[s, 0] = cur[s]
                logits, cache = self._chunk_step(
                    self._phase_params["prefill"], cache, jnp.asarray(toks),
                    jnp.asarray(n_new))
                self.stats.prefill_steps += 1
                self._note_rows("prefill", sum(
                    int(n_new[s]) for s in range(n_slots) if rid[s] >= 0))
            else:
                # pure decode step: the cheap (B, 1) path
                toks = np.zeros((n_slots, 1), np.int32)
                n_new = np.ones((n_slots,), np.int32)
                for s in range(n_slots):
                    if rid[s] >= 0:
                        toks[s, 0] = cur[s]
                logits, cache = self._step(self._phase_params["decode"],
                                           cache, jnp.asarray(toks))
                self._note_rows("decode",
                                sum(1 for r in rid if r >= 0))
            nxt = self._pull(self._sample(logits, sub))
            self.stats.steps += 1

            for s in range(n_slots):
                if rid[s] < 0:
                    continue
                self.stats.active_slot_steps += 1
                spos[s] += int(n_new[s])
                if took[s]:
                    rem[s] = rem[s][took[s]:]
                    if rem[s]:
                        continue              # still prefilling next step
                # prompt fully in cache: the sample is a completion token
                # (for a slot that just drained its prompt, the chunk's
                # last valid column produced it — first token for free)
                tok = int(nxt[s])
                self._first_token(rid[s])
                outputs[rid[s]].append(tok)
                self._step_emits += 1
                left[s] -= 1
                if (left[s] <= 0
                        or (cfg.eos_token is not None
                            and tok == cfg.eos_token)
                        or spos[s] >= cfg.max_len - 1):
                    self._mark_done(reqs[s])
                    reqs[s] = None
                    rid[s] = -1               # retire; refill next step
                else:
                    cur[s] = tok
            self._flush_tok_lat()
            yield

    # -- paged scheduler (packed ragged prefill) -----------------------------
    def _run_packed(self, queue, outputs, key):
        """Continuous scheduling over the paged KV pool.

        Admission walks the ordered queue and admits every request that
        can get both a free slot and its worst-case page reservation
        (``ceil((tail + budget) / page_size)``); a request that cannot
        get pages blocks later requests **unless they need strictly
        fewer pages** (bounded bypass: a cheaper request can never delay
        the blocked head, whose reservation the bypassing one couldn't
        have satisfied anyway — and the head retains priority the
        moment its pages exist). Retiring a slot frees its pages and
        sentinels its block-table row immediately, so a recycled page
        can never be written through a stale table.

        While any slot holds un-ingested prompt, the step is one packed
        ``(pack_tokens,)`` stream: every active slot contributes at
        least one row (decoding slots exactly one — their next token),
        prefilling slots up to ``prefill_chunk`` rows as the budget
        allows, and the remainder is padding (slot index B, masked
        everywhere). Pure-decode steps drop to the (B, 1) path.
        Yields once per compiled step.
        """
        cfg = self.cfg
        n_slots = cfg.batch_slots
        chunk = cfg.prefill_chunk
        ps = cfg.page_size
        virtual = not self.model.paged_kv     # recurrent: nothing to page
        alloc = PageAllocator(self.num_pages)
        self.stats.pool_pages = 0 if virtual else self.num_pages
        # structured failure instead of fail-fast: a request whose live
        # KV could never fit the whole pool is shed (status
        # shed_capacity) and the rest of the batch keeps serving
        keep = []
        for req in queue:
            need = self._pages_needed(len(req.tail), req.budget)
            if need > self.num_pages:
                self._shed(req, "shed_capacity")
            else:
                keep.append(req)
        queue[:] = keep
        if virtual:
            cache = self.model.init_cache(n_slots, cfg.max_len)
        else:
            cache = self.model.init_paged_cache(
                n_slots, cfg.max_len, ps, self.num_pages)
        tables = np.full((n_slots, self.max_pages), self.num_pages,
                         np.int32)
        tables_dirty = not virtual
        slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        rid = [-1] * n_slots
        reqs: List[Optional[Request]] = [None] * n_slots
        rem: List[List[int]] = [[] for _ in range(n_slots)]
        cur = [0] * n_slots
        left = [0] * n_slots
        spos = [0] * n_slots
        prio = [0] * n_slots              # admitted request's priority
        seq = [0] * n_slots               # admission sequence number
        next_seq = 0
        ema = [1.0] * n_slots             # trailing acceptance (adaptive k)
        mega = None                       # in-flight dispatched window
        #: pages freed by a retirement while a dispatch-ahead window is
        #: still in flight: the retired slot's stale table keeps writing
        #: token-0 junk through them until the chain ends, so they only
        #: rejoin the free list once no window is outstanding
        deferred: List[int] = []
        #: preempted requests waiting to rejoin the queue head
        bumped: List[Request] = []

        def set_tables(c):
            # the block table may nest under "attn" (hybrid family)
            c = dict(c)
            if "block_tables" in c:
                c["block_tables"] = jnp.asarray(tables)
            elif "attn" in c and "block_tables" in c["attn"]:
                c["attn"] = dict(c["attn"])
                c["attn"]["block_tables"] = jnp.asarray(tables)
            return c

        def swapped_pages() -> int:
            """Engine-side view of pages' worth of KV parked on host —
            cross-checked against the allocator's swap ledger."""
            return (sum(r.restore["pages_n"] for r in queue if r.restore)
                    + sum(r.restore["pages_n"] for r in bumped
                          if r.restore))

        def flush_bumped() -> None:
            if bumped:
                queue[:] = bumped + queue
                bumped.clear()

        def preempt_slot(t: int) -> None:
            """Swap slot ``t`` out: gather its live pages (resolved
            through the block table) / dense state to host, free the
            pages, and re-queue the request with the restore payload."""
            nonlocal tables_dirty
            req = reqs[t]
            payload = None
            if spos[t] > 0:
                content = (0 if virtual else
                           min(-(-spos[t] // ps), len(slot_pages[t])))
                pages_c = [] if virtual else slot_pages[t][:content]
                snap, nbytes = self._snapshot(cache, t, spos[t], pages_c)
                if content:
                    alloc.note_swap_out(content)
                payload = {"snap": snap, "spos": spos[t], "cur": cur[t],
                           "left": left[t], "pages_n": content,
                           "nbytes": nbytes}
            req.tail = list(rem[t])
            req.restore = payload
            req.preempts += 1
            self.stats.preemptions += 1
            alloc.free(slot_pages[t])
            slot_pages[t] = []
            tables[t, :] = self.num_pages
            tables_dirty = tables_dirty or not virtual
            rid[t] = -1
            rem[t] = []
            reqs[t] = None
            bumped.append(req)

        def grow_to(s: int, want_tokens: int) -> None:
            """Lazy page growth: extend slot ``s``'s page run to cover
            ``want_tokens`` of KV. Takes free pages greedily; when the
            pool runs dry and preemption is allowed, the lowest-
            priority / most-recently-admitted slot (possibly ``s``
            itself) is swapped out and its pages reused. Without
            preemption the slot simply ends short — the caller clamps
            its step to the pages it actually holds (or stalls it)."""
            nonlocal tables_dirty
            want = min(want_tokens, cfg.max_len)
            need = -(-want // ps) - len(slot_pages[s])
            while need > 0:
                take = min(need, alloc.free_pages)
                got = alloc.alloc(take) if take > 0 else None
                if got:
                    base = len(slot_pages[s])
                    slot_pages[s].extend(got)
                    tables[s, base:base + len(got)] = got
                    tables_dirty = True
                    need -= len(got)
                    continue
                if not cfg.preempt:
                    return
                victims = [t for t in range(n_slots) if rid[t] >= 0]
                v = min(victims, key=lambda t: (prio[t], -seq[t]))
                preempt_slot(v)
                if v == s:
                    return            # the grower itself was evicted

        while queue or any(r >= 0 for r in rid):
            if mega is not None and not any(r >= 0 for r in rid):
                # the dispatch-ahead window was issued past the last
                # retirement: it runs zero iterations — drop it
                mega = None
            admit = np.zeros((n_slots,), bool)
            if mega is None:
                if deferred:
                    alloc.free(deferred)
                    deferred = []
                if self._force_preempt:
                    # fault injection: swap the marked request out the
                    # first time we see it past its first emitted token
                    # (preempt_slot re-queues it through ``bumped``)
                    for t in range(n_slots):
                        if (rid[t] >= 0
                                and rid[t] in self._force_preempt
                                and outputs[rid[t]]):
                            self._force_preempt.discard(rid[t])
                            preempt_slot(t)
                # admit: free slots + page reservations, bounded bypass.
                # Skipped while a dispatch-ahead window is in flight —
                # chains only start with an empty queue, and slot/table
                # state must not move under the device's carry.
                ready = self._poll_queue(queue, alloc)
                waiting = queue[len(ready):]
                blocked_need = None
                pending = []
                restores = []
                for req in self._admission_order(ready):
                    need = self._admit_pages(req)
                    free_slot = next((t for t in range(n_slots)
                                      if rid[t] < 0 and not admit[t]),
                                     None)
                    bypass_ok = blocked_need is None or need < blocked_need
                    pages = None
                    if bypass_ok and cfg.preempt:
                        # priority preemption: strictly-lower-priority
                        # slots yield their slot/pages to a waiting
                        # higher-priority request (lowest priority,
                        # most recent first)
                        def victims():
                            return sorted(
                                (t for t in range(n_slots)
                                 if rid[t] >= 0 and not admit[t]
                                 and prio[t] < req.priority),
                                key=lambda t: (prio[t], -seq[t]))
                        while free_slot is None and victims():
                            preempt_slot(victims()[0])
                            free_slot = next(
                                (t for t in range(n_slots)
                                 if rid[t] < 0 and not admit[t]), None)
                        while (free_slot is not None
                               and alloc.free_pages < need and victims()):
                            preempt_slot(victims()[0])
                    if free_slot is not None and bypass_ok:
                        pages = alloc.alloc(need)
                    if free_slot is None or (need and pages is None) \
                            or not bypass_ok:
                        if blocked_need is None or need < blocked_need:
                            blocked_need = need
                        pending.append(req)
                        continue
                    s = free_slot
                    rid[s], rem[s], left[s] = req.rid, list(req.tail), \
                        req.budget
                    reqs[s] = req
                    spos[s] = 0
                    cur[s] = 0
                    prio[s] = req.priority
                    seq[s] = next_seq
                    next_seq += 1
                    ema[s] = 1.0
                    slot_pages[s] = pages or []
                    tables[s, :] = self.num_pages
                    tables[s, :len(slot_pages[s])] = slot_pages[s]
                    tables_dirty = tables_dirty or not virtual
                    admit[s] = True
                    if req.restore is not None:
                        restores.append((s, req))
                queue[:] = pending + waiting
                flush_bumped()
                if admit.any():
                    cache = self._reset(cache, jnp.asarray(admit))
                if tables_dirty and not virtual:
                    cache = set_tables(cache)
                    tables_dirty = False
                for s, req in restores:
                    # paged_write the swapped KV back into the slot's
                    # (new) pages AFTER the batched reset — restore
                    # addresses the pool directly, so table state is
                    # irrelevant to the write itself
                    r = req.restore
                    pages_c = ([] if virtual
                               else slot_pages[s][:r["pages_n"]])
                    cache = self.model.restore_slot(
                        cache, s, r["spos"], pages_c, r["snap"])
                    spos[s] = r["spos"]
                    cur[s] = r["cur"]
                    left[s] = r["left"]
                    self.stats.swap_in_bytes += r["nbytes"]
                    if r["pages_n"]:
                        alloc.note_swap_in(r["pages_n"])
                    req.restore = None
            self.stats.peak_resident_pages = max(
                self.stats.peak_resident_pages,
                0 if virtual else alloc.used_pages)
            self.stats.peak_active_requests = max(
                self.stats.peak_active_requests,
                sum(r >= 0 for r in rid))
            if not any(r >= 0 for r in rid):
                if queue:
                    # open-loop idle: arrivals still pending — tick
                    # without burning a compiled step
                    time.sleep(2e-4)
                    yield
                continue

            # -- lazy page growth: secure exactly the pages the coming
            #    step will write, at page-boundary crossings (no-op
            #    under worst_case reservation — the pages all exist)
            live = [s for s in range(n_slots) if rid[s] >= 0]
            mega_able = (self._mega is not None and self._spec is None
                         and not any(rem[s] for s in live)
                         and (not queue or cfg.temperature <= 0.0))
            chain_able = mega_able and not queue
            if not virtual and mega is None:
                for s in sorted(live, key=lambda t: seq[t]):
                    if rid[s] < 0:
                        continue      # preempted by an earlier grower
                    if rem[s]:
                        want = spos[s] + min(len(rem[s]), chunk)
                    elif self._spec is not None:
                        kb = max(0, min(self._spec.k, left[s] - 1,
                                        cfg.max_len - 2 - spos[s]))
                        want = spos[s] + kb + 1
                    elif chain_able:
                        # dispatch-ahead chains run with no host
                        # scheduling points: pre-grow to the full
                        # remaining bound so no growth is ever needed
                        # mid-chain
                        want = spos[s] + left[s]
                    elif mega_able:
                        want = spos[s] + min(left[s], cfg.sync_every)
                    else:
                        want = spos[s] + 1
                    grow_to(s, want)
                flush_bumped()
                if tables_dirty:
                    cache = set_tables(cache)
                    tables_dirty = False
            live = [s for s in range(n_slots) if rid[s] >= 0]
            if not live:
                continue
            if virtual:
                capv = {s: 1 << 30 for s in live}
            else:
                capv = {s: len(slot_pages[s]) * ps for s in live}
            stalled = {s for s in live if capv[s] <= spos[s]}
            mega_ok = all(
                capv[s] >= min(spos[s] + min(left[s], cfg.sync_every),
                               cfg.max_len) for s in live)
            ahead_ok = all(capv[s] >= min(spos[s] + left[s], cfg.max_len)
                           for s in live)
            if len(stalled) == len(live) and not admit.any():
                # no-preempt deadlock break: every live slot is wedged
                # waiting for pages nobody will free — shed the most
                # recent admission (structured, never a raise) so the
                # rest can grow
                v = max(live, key=lambda t: seq[t])
                self._shed(reqs[v], "shed_capacity")
                reqs[v] = None
                rid[v] = -1
                rem[v] = []
                alloc.free(slot_pages[v])
                slot_pages[v] = []
                tables[v, :] = self.num_pages
                tables_dirty = tables_dirty or not virtual
                continue

            # speculative step over the packed stream: decoding slots
            # contribute k+1-row speculation windows (cur + drafts),
            # prefilling slots pack their chunk rows alongside; the
            # drafter reads the shared KV prefix through the same block
            # tables and its trial cache is discarded
            if self._spec is not None and any(
                    rid[s] >= 0 and not rem[s] for s in range(n_slots)):
                sc = self._spec
                kvec, drafts = self._draft_tokens(cache, cur, rid, rem,
                                                  left, spos, ema)
                cap = max(chunk, sc.k + 1)
                # stalled slots (no pages for their next token) sit out:
                # they contribute zero rows, so the packed step advances
                # their device position by exactly nothing
                active = [s for s in range(n_slots)
                          if rid[s] >= 0 and s not in stalled]
                prefilling = any(rem[s] for s in active)
                tok_l: List[int] = []
                start = [0] * n_slots
                rows = [0] * n_slots
                took = [0] * n_slots
                slot_l: List[int] = []
                qpos_l: List[int] = []
                for j, s in enumerate(active):
                    reserve = len(active) - j - 1
                    room = self.pack_tokens - len(tok_l) - reserve
                    start[s] = len(tok_l)
                    if rem[s]:
                        take = max(1, min(len(rem[s]), chunk, room,
                                          capv[s] - spos[s]))
                        took[s] = take
                        rows[s] = take
                        vals = rem[s][:take]
                        self.stats.prefill_tokens += take
                    else:
                        ks = max(0, min(kvec[s], room - 1,
                                        capv[s] - spos[s] - 1))
                        kvec[s] = ks
                        rows[s] = ks + 1
                        vals = [cur[s]] + [int(t) for t in
                                           drafts[s, :ks]]
                    tok_l.extend(vals)
                    slot_l.extend([s] * rows[s])
                    qpos_l.extend(range(spos[s], spos[s] + rows[s]))
                width = self._bucket_width(len(tok_l))
                toks = np.zeros((width,), np.int32)
                slot_v = np.full((width,), n_slots, np.int32)
                qpos = np.zeros((width,), np.int32)
                toks[:len(tok_l)] = tok_l
                slot_v[:len(slot_l)] = slot_l
                qpos[:len(qpos_l)] = qpos_l
                rowidx = np.zeros((n_slots, cap), np.int32)
                n_new = np.ones((n_slots,), np.int32)
                specv = np.zeros((n_slots,), bool)
                for s in active:
                    n_new[s] = rows[s]
                    specv[s] = not rem[s]
                    rowidx[s, :rows[s]] = np.arange(
                        start[s], start[s] + rows[s])
                greedy, n_acc, cache = self._verify_packed(
                    self._phase_params["verify"], cache, jnp.asarray(toks),
                    jnp.asarray(slot_v), jnp.asarray(qpos),
                    jnp.asarray(rowidx), jnp.asarray(n_new),
                    jnp.asarray(drafts), jnp.asarray(specv))
                greedy, n_acc = self._pull(greedy, n_acc)
                self.stats.steps += 1
                self.stats.verify_steps += 1
                self._note_rows("verify", len(tok_l))
                if prefilling:
                    self.stats.prefill_steps += 1
                for s in range(n_slots):
                    if rid[s] < 0:
                        continue
                    if rows[s] == 0 and took[s] == 0:
                        continue          # stalled: sat this step out
                    self.stats.active_slot_steps += 1

                    def _retire_slot(s=s):
                        alloc.free(slot_pages[s])
                        slot_pages[s] = []
                        tables[s, :] = self.num_pages

                    if took[s]:
                        rem[s] = rem[s][took[s]:]
                        adv = rows[s]
                        if rem[s]:
                            spos[s] += adv
                            continue      # still prefilling next step
                        tok = int(greedy[s, adv - 1])
                        if self._emit(s, rid, left, spos, outputs,
                                      [tok], adv):
                            self._mark_done(reqs[s])
                            reqs[s] = None
                            rid[s] = -1
                            _retire_slot()
                            tables_dirty = tables_dirty or not virtual
                        else:
                            spos[s] += adv
                            cur[s] = tok
                        continue
                    acc = int(n_acc[s])
                    if kvec[s] > 0:
                        self._note_window(s, acc, kvec[s], ema)
                    adv = acc + 1
                    if not virtual and kvec[s] > acc:
                        # rejected speculative tail: resolve the
                        # rollback (position rewind already happened on
                        # device) BEFORE the slot's pages may be freed
                        alloc.rollback(slot_pages[s], spos[s] + adv, ps)
                    emitted = [int(t) for t in greedy[s, :adv]]
                    if self._emit(s, rid, left, spos, outputs, emitted,
                                  1):
                        self._mark_done(reqs[s])
                        reqs[s] = None
                        rid[s] = -1       # retire mid-window: free only
                        _retire_slot()    # after the rollback resolved
                        tables_dirty = tables_dirty or not virtual
                    else:
                        spos[s] += adv
                        cur[s] = emitted[-1]
                if cfg.debug_invariants and not virtual:
                    alloc.assert_invariant(
                        sum(len(p) for p in slot_pages) + len(deferred),
                        swapped_pages())
                self._flush_tok_lat()
                yield
                continue

            # fused megastep over the paged cache: identical contract to
            # the contiguous branch (the block tables ride the while
            # carry unchanged). Requires every live slot pre-grown to
            # its full window bound (mega_ok) — there are no host
            # scheduling points inside the window, so no page can be
            # granted mid-flight. During a dispatch-ahead window a
            # just-retired slot still writes through its stale table,
            # so its pages go to `deferred` and rejoin the free list
            # only once no window is outstanding.
            if (self._mega is not None and self._spec is None
                    and any(r >= 0 for r in rid)
                    and not any(rem[s] for s in range(n_slots)
                                if rid[s] >= 0)
                    and (not queue or cfg.temperature <= 0.0)
                    and (mega is not None or mega_ok)):
                if mega is None:
                    cur_a = np.zeros((n_slots, 1), np.int32)
                    pos_a = np.zeros((n_slots,), np.int32)
                    left_a = np.zeros((n_slots,), np.int32)
                    done_a = np.ones((n_slots,), bool)
                    for s in range(n_slots):
                        if rid[s] >= 0:
                            cur_a[s, 0] = cur[s]
                            pos_a[s] = spos[s]
                            left_a[s] = left[s]
                            done_a[s] = False
                    mega, cache = self._mega(
                        self._phase_params["decode"], cache,
                        jnp.asarray(cur_a), jnp.asarray(pos_a),
                        jnp.asarray(left_a), jnp.asarray(done_a), key,
                        jnp.asarray(bool(queue)))
                (ring_d, nem_d, done_d, cur_d, pos_d, left_d, key,
                 ns_d) = mega
                mega = None
                if not queue and ahead_ok:
                    # dispatch-ahead only when every live slot already
                    # holds pages for its full remaining bound — the
                    # chained window may run to completion
                    mega, cache = self._mega(
                        self._phase_params["decode"], cache, cur_d,
                        pos_d, left_d, done_d, key, jnp.asarray(False))
                ring, nem, done_h, ns = self._pull(ring_d, nem_d,
                                                   done_d, ns_d)
                tot = 0
                for s in range(n_slots):
                    if rid[s] < 0:
                        continue
                    k = int(nem[s])
                    tot += k
                    for t in ring[s, :k]:
                        self._first_token(rid[s])
                        outputs[rid[s]].append(int(t))
                        self._step_emits += 1
                    spos[s] += k
                    left[s] -= k
                    if done_h[s]:
                        self._mark_done(reqs[s])
                        reqs[s] = None
                        rid[s] = -1
                        if mega is not None:
                            # a chained window is still in flight and
                            # this slot's stale table writes through
                            # these pages until it lands — park them
                            deferred.extend(slot_pages[s])
                        else:
                            alloc.free(slot_pages[s])
                        slot_pages[s] = []
                        tables[s, :] = self.num_pages
                        tables_dirty = tables_dirty or not virtual
                    elif k:
                        cur[s] = int(ring[s, k - 1])
                self.stats.steps += int(ns)
                self.stats.megasteps += 1
                self.stats.active_slot_steps += tot
                self._note_rows("decode", tot)
                if cfg.debug_invariants and not virtual:
                    alloc.assert_invariant(
                        sum(len(p) for p in slot_pages) + len(deferred),
                        swapped_pages())
                self._flush_tok_lat()
                yield
                continue

            key, sub = jax.random.split(key)
            took = [0] * n_slots
            rows = [0] * n_slots              # packed rows per slot
            if any(rid[s] >= 0 and rem[s] for s in range(n_slots)) \
                    or stalled:
                # packed step: lay out each active slot's rows in slot
                # order, reserving one row for every active slot after.
                # Stalled slots must route through here (not the (B, 1)
                # step, which advances device positions for EVERY slot):
                # they own zero rows, so their position moves by nothing
                active = [s for s in range(n_slots)
                          if rid[s] >= 0 and s not in stalled]
                toks = np.zeros((self.pack_tokens,), np.int32)
                slot_v = np.full((self.pack_tokens,), n_slots, np.int32)
                qpos = np.zeros((self.pack_tokens,), np.int32)
                last = np.zeros((n_slots,), np.int32)
                cursor = 0
                for j, s in enumerate(active):
                    reserve = len(active) - j - 1
                    if rem[s]:
                        take = min(len(rem[s]), chunk,
                                   self.pack_tokens - cursor - reserve,
                                   capv[s] - spos[s])
                        take = max(take, 1)
                        took[s] = take
                        rows[s] = take
                        toks[cursor:cursor + take] = rem[s][:take]
                        self.stats.prefill_tokens += take
                    else:
                        rows[s] = 1
                        toks[cursor] = cur[s]
                    n = rows[s]
                    slot_v[cursor:cursor + n] = s
                    qpos[cursor:cursor + n] = np.arange(
                        spos[s], spos[s] + n)
                    cursor += n
                    last[s] = cursor - 1
                # width bucket: ship the smallest power-of-two prefix
                # covering the live rows (padding rows carry slot == B
                # and are masked everywhere)
                w = self._bucket_width(cursor)
                logits, cache = self._packed_step(
                    self._phase_params["prefill"], cache,
                    jnp.asarray(toks[:w]),
                    jnp.asarray(slot_v[:w]), jnp.asarray(qpos[:w]),
                    jnp.asarray(last))
                self.stats.prefill_steps += 1
                self._note_rows("prefill", cursor)
            else:
                # pure decode step: the cheap (B, 1) path
                toks = np.zeros((n_slots, 1), np.int32)
                for s in range(n_slots):
                    if rid[s] >= 0:
                        toks[s, 0] = cur[s]
                        rows[s] = 1
                logits, cache = self._step(self._phase_params["decode"],
                                           cache, jnp.asarray(toks))
                self._note_rows("decode",
                                sum(1 for r in rid if r >= 0))
            nxt = self._pull(self._sample(logits, sub))
            self.stats.steps += 1

            for s in range(n_slots):
                if rid[s] < 0:
                    continue
                if rows[s] == 0 and took[s] == 0:
                    continue              # stalled: sat this step out
                self.stats.active_slot_steps += 1
                spos[s] += rows[s]
                if took[s]:
                    rem[s] = rem[s][took[s]:]
                    if rem[s]:
                        continue              # still prefilling next step
                tok = int(nxt[s])
                self._first_token(rid[s])
                outputs[rid[s]].append(tok)
                self._step_emits += 1
                left[s] -= 1
                if (left[s] <= 0
                        or (cfg.eos_token is not None
                            and tok == cfg.eos_token)
                        or spos[s] >= cfg.max_len - 1):
                    self._mark_done(reqs[s])
                    reqs[s] = None
                    rid[s] = -1               # retire: free pages now
                    alloc.free(slot_pages[s])
                    slot_pages[s] = []
                    tables[s, :] = self.num_pages
                    tables_dirty = tables_dirty or not virtual
                else:
                    cur[s] = tok
            if cfg.debug_invariants and not virtual:
                alloc.assert_invariant(
                    sum(len(p) for p in slot_pages) + len(deferred),
                    swapped_pages())
            self._flush_tok_lat()
            yield

    # -- wave scheduler (parity reference) -----------------------------------
    def _run_waves(self, queue, outputs, key):
        """Drive the wave scheduler wave by wave (generator form)."""
        while queue:
            ready = self._poll_queue(queue)   # sheds expired deadlines
            if not ready:
                if queue:
                    time.sleep(2e-4)
                    yield
                continue
            n = min(self.cfg.batch_slots, len(ready))
            wave = queue[:n]
            del queue[:n]
            key = yield from self._run_wave(wave, outputs, key)
            for req in wave:
                self._mark_done(req)

    def _run_wave(self, wave, outputs, key):
        """Serve one wave of Request objects (<= batch_slots) from a
        fresh cache.

        Streams each slot's prompt through the compiled step token by
        token (prefill), then keeps stepping to decode; a slot flips from
        prefill to decode independently once its prompt is exhausted.
        Yields once per compiled step.
        """
        cfg = self.cfg
        n_slots = cfg.batch_slots
        prompts = [r.tail for r in wave]     # tails already truncated
        rids = [r.rid for r in wave]
        left = [r.budget for r in wave]
        done = [False] * len(wave)
        cache = self.model.init_cache(n_slots, cfg.max_len)
        cur = np.zeros((n_slots, 1), np.int32)
        for s, p in enumerate(prompts):
            cur[s, 0] = p[0]

        pos = 0                        # step index (slots move in lockstep)
        while not all(done):
            key, sub = jax.random.split(key)
            logits, cache = self._step(self._phase_params["decode"],
                                       cache, jnp.asarray(cur))
            nxt = self._pull(self._sample(logits, sub))
            self.stats.steps += 1
            self.stats.active_slot_steps += sum(not d for d in done)
            self._note_rows("decode", sum(not d for d in done))
            for s in range(len(wave)):
                if done[s]:
                    continue
                if pos < len(prompts[s]):
                    self.stats.prefill_tokens += 1
                if pos + 1 < len(prompts[s]):
                    cur[s, 0] = prompts[s][pos + 1]   # still prefilling
                    continue
                tok = int(nxt[s])                     # prompt fully in cache
                self._first_token(rids[s])
                outputs[rids[s]].append(tok)
                self._step_emits += 1
                left[s] -= 1
                if left[s] <= 0 or (cfg.eos_token is not None
                                    and tok == cfg.eos_token):
                    done[s] = True
                else:
                    cur[s, 0] = tok
            pos += 1
            self._flush_tok_lat()
            yield
            if pos >= cfg.max_len - 1:
                break
        return key
