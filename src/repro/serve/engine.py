"""Decode engine: prefill + greedy/temperature decode against the model's
KV cache, with NEAT placement support for reduced-precision serving.

Two schedulers share one compiled (batch, 1)-token step function:

* **continuous** (default): the KV cache carries a per-slot position
  vector, so the engine is a scheduler loop — admit queued requests into
  free slots *mid-flight*, stream each slot's prompt left-aligned at its
  own position (prefill), retire on EOS/budget, and immediately refill.
  A retired slot is reset (its KV entries and position zeroed) before
  reuse, and per-slot causal masking keys every slot on its own length,
  so a recycled slot can never attend to the previous request's KV
  entries. No wave barrier, no fresh-cache restarts.

* **wave**: the historical scheduler — requests are packed into fixed
  slots wave by wave and a finished wave pulls the next requests from the
  queue; slots idle once their request finishes until the whole wave
  drains. Kept as the parity reference: under greedy decoding both
  schedulers produce identical per-request completions.

Prefill is real in both: every prompt token is stepped through the
compiled decode step, so the KV cache holds the whole prompt and
completions condition on all of it.

Both schedulers admit from one queue whose order is the configured
admission policy — ``"fifo"`` (arrival) or ``"sjf"`` (shortest prompt
first) — and every request carries its own ``max_new`` budget
(``generate(prompts, max_new_tokens=[...])``; an int broadcasts).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementRule
from repro.core.quantize import use_rule
from repro.models.model_api import Model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    batch_slots: int = 8
    temperature: float = 0.0          # 0 = greedy
    eos_token: Optional[int] = None
    seed: int = 0
    engine: str = "continuous"        # "continuous" | "wave"
    #: queue admission order: "fifo" (arrival) or "sjf" (shortest prompt
    #: first — short requests stop convoying behind long prefills; a
    #: stable sort keeps arrival order among equal lengths). Completions
    #: are returned in request order either way, and greedy outputs are
    #: admission-order independent.
    admission: str = "fifo"


@dataclasses.dataclass
class ServeStats:
    """Occupancy accounting for the last ``generate`` call."""
    steps: int = 0                    # compiled decode-step dispatches
    active_slot_steps: int = 0        # slot-steps spent on a live request
    slot_steps: int = 0               # steps * batch_slots
    tokens_out: int = 0               # completion tokens emitted
    n_requests: int = 0

    @property
    def occupancy(self) -> float:
        return self.active_slot_steps / max(self.slot_steps, 1)


class DecodeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 rule: Optional[PlacementRule] = None):
        if cfg.engine not in ("continuous", "wave"):
            raise ValueError(f"unknown engine {cfg.engine!r}")
        if cfg.admission not in ("fifo", "sjf"):
            raise ValueError(f"unknown admission policy {cfg.admission!r}")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rule = rule
        self.stats = ServeStats()
        with use_rule(rule):
            self._step = jax.jit(
                lambda p, c, t: model.decode_step(p, c, t))
            # donate the cache: the reset runs on the admit hot path and
            # the caller always rebinds, so XLA may update it in place
            # instead of copying every layer's (B, S, KV, Dh) buffers
            self._reset = jax.jit(lambda c, m: model.reset_slots(c, m),
                                  donate_argnums=0)

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature).astype(jnp.int32)

    def _prompt_tail(self, prompt, max_new_tokens: int) -> List[int]:
        # keep only the prompt tail that leaves cache room for the full
        # completion — otherwise a near-max_len prompt would exhaust the
        # cache mid-prefill and silently return a short/empty completion
        keep = max(1, self.cfg.max_len - 1 - max_new_tokens)
        return list(prompt)[-keep:] if prompt else [0]

    def _budgets(self, prompts,
                 max_new_tokens: Union[int, Sequence[int]]) -> List[int]:
        """Per-request completion budgets: one int broadcasts; a sequence
        gives each request its own ``max_new`` ceiling."""
        if isinstance(max_new_tokens, (int, np.integer)):
            budgets = [int(max_new_tokens)] * len(prompts)
        else:
            budgets = [int(b) for b in max_new_tokens]
        if len(budgets) != len(prompts):
            raise ValueError(f"{len(budgets)} max_new budgets for "
                             f"{len(prompts)} prompts")
        if any(b < 1 for b in budgets):
            raise ValueError("per-request max_new budgets must be >= 1")
        return budgets

    def _admission_order(self, queue: List[tuple]) -> List[tuple]:
        """Apply the configured admission policy to a (rid, prompt, budget)
        queue. ``sjf`` sorts by prompt length, stably."""
        if self.cfg.admission == "sjf":
            return sorted(queue, key=lambda e: len(e[1]))
        return list(queue)

    def generate(self, prompts: List[List[int]],
                 max_new_tokens: Union[int, Sequence[int]] = 32
                 ) -> List[List[int]]:
        """Serve a list of token prompts; returns completions per prompt.
        ``max_new_tokens`` is a global ceiling (int) or one budget per
        request. ``self.stats`` holds step/occupancy accounting."""
        self.stats = ServeStats(n_requests=len(prompts))
        outputs: dict[int, List[int]] = {i: [] for i in range(len(prompts))}
        budgets = self._budgets(prompts, max_new_tokens)
        key = jax.random.key(self.cfg.seed)
        with use_rule(self.rule):
            # both schedulers admit the cache-truncated prompt tails, so
            # the sjf sort key is the length actually prefilled
            queue = self._admission_order(
                [(rid, self._prompt_tail(p, budgets[rid]), budgets[rid])
                 for rid, p in enumerate(prompts)])
            if self.cfg.engine == "continuous":
                self._run_continuous(queue, outputs, key)
            else:
                while queue:
                    wave = [queue.pop(0) for _ in
                            range(min(self.cfg.batch_slots, len(queue)))]
                    key = self._run_wave(wave, outputs, key)
        self.stats.slot_steps = self.stats.steps * self.cfg.batch_slots
        self.stats.tokens_out = sum(len(o) for o in outputs.values())
        return [outputs[i] for i in range(len(prompts))]

    # -- continuous scheduler ------------------------------------------------
    def _run_continuous(self, queue, outputs, key):
        """One scheduler loop over the compiled step: admit the ordered
        (rid, prompt-tail, budget) queue into free slots, prefill each
        slot at its own position, retire on EOS/budget and refill
        mid-flight while other slots keep decoding."""
        cfg = self.cfg
        n_slots = cfg.batch_slots
        cache = self.model.init_cache(n_slots, cfg.max_len)
        cur = np.zeros((n_slots, 1), np.int32)
        rid = [-1] * n_slots              # -1 = free slot
        prompt = [[0]] * n_slots
        ppos = [0] * n_slots              # index of the token in `cur`
        left = [0] * n_slots              # completion tokens still owed
        spos = [0] * n_slots              # slot's own cache position

        while queue or any(r >= 0 for r in rid):
            # admit: reset + refill every free slot from the queue (one
            # compiled reset call per step regardless of how many admit)
            admit = np.zeros((n_slots,), bool)
            for s in range(n_slots):
                if rid[s] < 0 and queue:
                    rid[s], prompt[s], budget = queue.pop(0)
                    ppos[s], spos[s] = 0, 0
                    left[s] = budget
                    cur[s, 0] = prompt[s][0]
                    admit[s] = True
            if admit.any():
                cache = self._reset(cache, jnp.asarray(admit))

            key, sub = jax.random.split(key)
            logits, cache = self._step(self.params, cache, jnp.asarray(cur))
            nxt = np.asarray(self._sample(logits, sub))
            self.stats.steps += 1

            for s in range(n_slots):
                if rid[s] < 0:
                    continue
                self.stats.active_slot_steps += 1
                spos[s] += 1
                if ppos[s] + 1 < len(prompt[s]):
                    ppos[s] += 1                      # still prefilling
                    cur[s, 0] = prompt[s][ppos[s]]
                    continue
                tok = int(nxt[s])                     # prompt fully in cache
                outputs[rid[s]].append(tok)
                left[s] -= 1
                if (left[s] <= 0
                        or (cfg.eos_token is not None
                            and tok == cfg.eos_token)
                        or spos[s] >= cfg.max_len - 1):
                    rid[s] = -1                       # retire; refill next step
                else:
                    cur[s, 0] = tok

    # -- wave scheduler (parity reference) -----------------------------------
    def _run_wave(self, wave, outputs, key):
        """Serve one wave of (rid, prompt, budget) requests (<= batch_slots)
        from a fresh cache.

        Streams each slot's prompt through the compiled step token by
        token (prefill), then keeps stepping to decode; a slot flips from
        prefill to decode independently once its prompt is exhausted.
        """
        cfg = self.cfg
        n_slots = cfg.batch_slots
        prompts = [p for _, p, _ in wave]    # tails already truncated
        rids = [r for r, _, _ in wave]
        left = [b for _, _, b in wave]
        done = [False] * len(wave)
        cache = self.model.init_cache(n_slots, cfg.max_len)
        cur = np.zeros((n_slots, 1), np.int32)
        for s, p in enumerate(prompts):
            cur[s, 0] = p[0]

        pos = 0                        # step index (slots move in lockstep)
        while not all(done):
            key, sub = jax.random.split(key)
            logits, cache = self._step(self.params, cache, jnp.asarray(cur))
            nxt = np.asarray(self._sample(logits, sub))
            self.stats.steps += 1
            self.stats.active_slot_steps += sum(not d for d in done)
            for s in range(len(wave)):
                if done[s]:
                    continue
                if pos + 1 < len(prompts[s]):
                    cur[s, 0] = prompts[s][pos + 1]   # still prefilling
                    continue
                tok = int(nxt[s])                     # prompt fully in cache
                outputs[rids[s]].append(tok)
                left[s] -= 1
                if left[s] <= 0 or (cfg.eos_token is not None
                                    and tok == cfg.eos_token):
                    done[s] = True
                else:
                    cur[s, 0] = tok
            pos += 1
            if pos >= cfg.max_len - 1:
                break
        return key
