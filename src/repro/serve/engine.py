"""Decode engine: prefill + greedy/temperature decode against the model's
KV cache, with NEAT placement support for reduced-precision serving.

Two schedulers share one compiled (batch, 1)-token decode step; the
continuous scheduler additionally runs a compiled **chunked-prefill**
step:

* **continuous** (default): the KV cache carries a per-slot position
  vector, so the engine is a scheduler loop — admit queued requests into
  free slots *mid-flight*, ingest each slot's remaining prompt in
  ``prefill_chunk``-token blocks through one compiled
  ``Model.prefill_chunk`` call (attention families batch the chunk
  through the flash kernel's ``q_start`` path; recurrent families scan
  it on-device), retire on EOS/budget, and immediately refill. Steps are
  **mixed**: slots mid-prefill consume chunks while decoding slots emit
  one token in the same dispatch, ragged tails masked via per-slot
  ``n_new``/``kv_len``. Once no slot is prefilling the engine drops back
  to the cheap (batch, 1) decode step. A retired slot is reset (its KV
  entries and position zeroed) before reuse, and per-slot causal masking
  keys every slot on its own length, so a recycled slot can never attend
  to the previous request's KV entries. No wave barrier, no fresh-cache
  restarts. ``prefill_chunk=1`` degenerates to streaming prefill (the
  baseline the chunked path is benchmarked against).

* **wave**: the historical scheduler — requests are packed into fixed
  slots wave by wave, every prompt token streamed through the decode
  step, and a finished wave pulls the next requests from the queue.
  Kept as the parity reference: under greedy decoding both schedulers
  produce identical per-request completions.

Both schedulers admit from one queue whose order is the configured
admission policy — ``"fifo"`` (arrival) or ``"sjf"`` (fewest remaining
prefill *steps* first: ``ceil(len(tail) / prefill_chunk)`` for the
continuous engine, the raw tail length for the streaming wave
scheduler) — and every request carries its own ``max_new`` budget
(``generate(prompts, max_new_tokens=[...])``; an int broadcasts).
``ServeStats`` tracks per-request time-to-first-token alongside the
step/occupancy accounting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementRule
from repro.core.quantize import use_rule
from repro.models.model_api import Model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    batch_slots: int = 8
    temperature: float = 0.0          # 0 = greedy
    eos_token: Optional[int] = None
    seed: int = 0
    engine: str = "continuous"        # "continuous" | "wave"
    #: queue admission order: "fifo" (arrival) or "sjf" (shortest job
    #: first — short requests stop convoying behind long prefills; a
    #: stable sort keeps arrival order among equal keys). The sjf key is
    #: the post-chunking remaining-prefill length: the number of compiled
    #: prefill steps the admitted tail will actually consume. Completions
    #: are returned in request order either way, and greedy outputs are
    #: admission-order independent.
    admission: str = "fifo"
    #: tokens each prefilling slot ingests per compiled step (continuous
    #: engine only; 1 = legacy streaming prefill, token by token)
    prefill_chunk: int = 32


@dataclasses.dataclass
class ServeStats:
    """Occupancy + latency accounting for the last ``generate`` call."""
    steps: int = 0                    # compiled step dispatches
    active_slot_steps: int = 0        # slot-steps spent on a live request
    slot_steps: int = 0               # steps * batch_slots
    tokens_out: int = 0               # completion tokens emitted
    n_requests: int = 0
    prefill_steps: int = 0            # steps where >= 1 slot ate a chunk
    prefill_tokens: int = 0           # prompt tokens ingested
    #: per-request time-to-first-token, seconds since generate() started
    ttft_s: Dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def occupancy(self) -> float:
        return self.active_slot_steps / max(self.slot_steps, 1)

    @property
    def mean_ttft_s(self) -> float:
        return (sum(self.ttft_s.values()) / len(self.ttft_s)
                if self.ttft_s else 0.0)


class DecodeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 rule: Optional[PlacementRule] = None):
        if cfg.engine not in ("continuous", "wave"):
            raise ValueError(f"unknown engine {cfg.engine!r}")
        if cfg.admission not in ("fifo", "sjf"):
            raise ValueError(f"unknown admission policy {cfg.admission!r}")
        if cfg.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rule = rule
        self.stats = ServeStats()
        with use_rule(rule):
            self._step = jax.jit(
                lambda p, c, t: model.decode_step(p, c, t))
            # the chunked-prefill step: (B, C) tokens + per-slot n_new in
            # one dispatch (mixed prefill/decode); compiled lazily, so
            # wave engines never pay for it
            self._chunk_step = jax.jit(
                lambda p, c, t, n: model.prefill_chunk(p, c, t, n))
            # donate the cache: the reset runs on the admit hot path and
            # the caller always rebinds, so XLA may update it in place
            # instead of copying every layer's (B, S, KV, Dh) buffers
            self._reset = jax.jit(lambda c, m: model.reset_slots(c, m),
                                  donate_argnums=0)

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature).astype(jnp.int32)

    def _prompt_tail(self, prompt, max_new_tokens: int) -> List[int]:
        # keep only the prompt tail that leaves cache room for the full
        # completion — otherwise a near-max_len prompt would exhaust the
        # cache mid-prefill and silently return a short/empty completion
        keep = max(1, self.cfg.max_len - 1 - max_new_tokens)
        return list(prompt)[-keep:] if prompt else [0]

    def _budgets(self, prompts,
                 max_new_tokens: Union[int, Sequence[int]]) -> List[int]:
        """Per-request completion budgets: one int broadcasts; a sequence
        gives each request its own ``max_new`` ceiling."""
        if isinstance(max_new_tokens, (int, np.integer)):
            budgets = [int(max_new_tokens)] * len(prompts)
        else:
            budgets = [int(b) for b in max_new_tokens]
        if len(budgets) != len(prompts):
            raise ValueError(f"{len(budgets)} max_new budgets for "
                             f"{len(prompts)} prompts")
        if any(b < 1 for b in budgets):
            raise ValueError("per-request max_new budgets must be >= 1")
        return budgets

    def _prefill_stride(self) -> int:
        """Prompt tokens one compiled step ingests per slot: the chunk
        size for the continuous engine, 1 for the streaming wave path."""
        return (self.cfg.prefill_chunk if self.cfg.engine == "continuous"
                else 1)

    def _admission_order(self, queue: List[tuple]) -> List[tuple]:
        """Apply the configured admission policy to a (rid, prompt, budget)
        queue. ``sjf`` sorts by the post-chunking remaining-prefill
        length — the compiled prefill steps the admitted tail will
        consume, ``ceil(len / prefill_stride)`` — stably, so chunked
        prefill doesn't misorder on sub-chunk length differences that
        cost identical step counts."""
        if self.cfg.admission == "sjf":
            stride = self._prefill_stride()
            return sorted(queue, key=lambda e: -(-len(e[1]) // stride))
        return list(queue)

    def generate(self, prompts: List[List[int]],
                 max_new_tokens: Union[int, Sequence[int]] = 32
                 ) -> List[List[int]]:
        """Serve a list of token prompts; returns completions per prompt.
        ``max_new_tokens`` is a global ceiling (int) or one budget per
        request. ``self.stats`` holds step/occupancy/TTFT accounting."""
        self.stats = ServeStats(n_requests=len(prompts))
        self._t0 = time.perf_counter()
        outputs: dict[int, List[int]] = {i: [] for i in range(len(prompts))}
        budgets = self._budgets(prompts, max_new_tokens)
        key = jax.random.key(self.cfg.seed)
        with use_rule(self.rule):
            # both schedulers admit the cache-truncated prompt tails, so
            # the sjf sort key is computed on the length actually prefilled
            queue = self._admission_order(
                [(rid, self._prompt_tail(p, budgets[rid]), budgets[rid])
                 for rid, p in enumerate(prompts)])
            if self.cfg.engine == "continuous":
                self._run_continuous(queue, outputs, key)
            else:
                while queue:
                    wave = [queue.pop(0) for _ in
                            range(min(self.cfg.batch_slots, len(queue)))]
                    key = self._run_wave(wave, outputs, key)
        self.stats.slot_steps = self.stats.steps * self.cfg.batch_slots
        self.stats.tokens_out = sum(len(o) for o in outputs.values())
        return [outputs[i] for i in range(len(prompts))]

    def _first_token(self, rid: int) -> None:
        """Record time-to-first-token the moment a request's first
        completion token lands."""
        if rid not in self.stats.ttft_s:
            self.stats.ttft_s[rid] = time.perf_counter() - self._t0

    # -- continuous scheduler ------------------------------------------------
    def _run_continuous(self, queue, outputs, key):
        """One scheduler loop over the compiled steps: admit the ordered
        (rid, prompt-tail, budget) queue into free slots, ingest each
        slot's remaining prompt in ``prefill_chunk``-token blocks (mixed
        with single-token decodes for slots already past prefill), retire
        on EOS/budget and refill mid-flight while other slots keep
        working."""
        cfg = self.cfg
        n_slots = cfg.batch_slots
        chunk = cfg.prefill_chunk
        cache = self.model.init_cache(n_slots, cfg.max_len)
        rid = [-1] * n_slots              # -1 = free slot
        rem: List[List[int]] = [[] for _ in range(n_slots)]  # prompt left
        cur = [0] * n_slots               # next decode token per slot
        left = [0] * n_slots              # completion tokens still owed
        spos = [0] * n_slots              # slot's own cache position

        while queue or any(r >= 0 for r in rid):
            # admit: reset + refill every free slot from the queue (one
            # compiled reset call per step regardless of how many admit)
            admit = np.zeros((n_slots,), bool)
            for s in range(n_slots):
                if rid[s] < 0 and queue:
                    rid[s], prompt, budget = queue.pop(0)
                    rem[s] = list(prompt)
                    left[s] = budget
                    spos[s] = 0
                    admit[s] = True
            if admit.any():
                cache = self._reset(cache, jnp.asarray(admit))

            key, sub = jax.random.split(key)
            took = [0] * n_slots
            if any(rid[s] >= 0 and rem[s] for s in range(n_slots)):
                # mixed chunked step: prefilling slots eat a chunk,
                # decoding slots ride along with n_new == 1
                toks = np.zeros((n_slots, chunk), np.int32)
                n_new = np.ones((n_slots,), np.int32)
                for s in range(n_slots):
                    if rid[s] < 0:
                        continue
                    if rem[s]:
                        take = rem[s][:chunk]
                        took[s] = len(take)
                        n_new[s] = len(take)
                        toks[s, :len(take)] = take
                        self.stats.prefill_tokens += len(take)
                    else:
                        toks[s, 0] = cur[s]
                logits, cache = self._chunk_step(
                    self.params, cache, jnp.asarray(toks),
                    jnp.asarray(n_new))
                self.stats.prefill_steps += 1
            else:
                # pure decode step: the cheap (B, 1) path
                toks = np.zeros((n_slots, 1), np.int32)
                n_new = np.ones((n_slots,), np.int32)
                for s in range(n_slots):
                    if rid[s] >= 0:
                        toks[s, 0] = cur[s]
                logits, cache = self._step(self.params, cache,
                                           jnp.asarray(toks))
            nxt = np.asarray(self._sample(logits, sub))
            self.stats.steps += 1

            for s in range(n_slots):
                if rid[s] < 0:
                    continue
                self.stats.active_slot_steps += 1
                spos[s] += int(n_new[s])
                if took[s]:
                    rem[s] = rem[s][took[s]:]
                    if rem[s]:
                        continue              # still prefilling next step
                # prompt fully in cache: the sample is a completion token
                # (for a slot that just drained its prompt, the chunk's
                # last valid column produced it — first token for free)
                tok = int(nxt[s])
                self._first_token(rid[s])
                outputs[rid[s]].append(tok)
                left[s] -= 1
                if (left[s] <= 0
                        or (cfg.eos_token is not None
                            and tok == cfg.eos_token)
                        or spos[s] >= cfg.max_len - 1):
                    rid[s] = -1               # retire; refill next step
                else:
                    cur[s] = tok

    # -- wave scheduler (parity reference) -----------------------------------
    def _run_wave(self, wave, outputs, key):
        """Serve one wave of (rid, prompt, budget) requests (<= batch_slots)
        from a fresh cache.

        Streams each slot's prompt through the compiled step token by
        token (prefill), then keeps stepping to decode; a slot flips from
        prefill to decode independently once its prompt is exhausted.
        """
        cfg = self.cfg
        n_slots = cfg.batch_slots
        prompts = [p for _, p, _ in wave]    # tails already truncated
        rids = [r for r, _, _ in wave]
        left = [b for _, _, b in wave]
        done = [False] * len(wave)
        cache = self.model.init_cache(n_slots, cfg.max_len)
        cur = np.zeros((n_slots, 1), np.int32)
        for s, p in enumerate(prompts):
            cur[s, 0] = p[0]

        pos = 0                        # step index (slots move in lockstep)
        while not all(done):
            key, sub = jax.random.split(key)
            logits, cache = self._step(self.params, cache, jnp.asarray(cur))
            nxt = np.asarray(self._sample(logits, sub))
            self.stats.steps += 1
            self.stats.active_slot_steps += sum(not d for d in done)
            for s in range(len(wave)):
                if done[s]:
                    continue
                if pos < len(prompts[s]):
                    self.stats.prefill_tokens += 1
                if pos + 1 < len(prompts[s]):
                    cur[s, 0] = prompts[s][pos + 1]   # still prefilling
                    continue
                tok = int(nxt[s])                     # prompt fully in cache
                self._first_token(rids[s])
                outputs[rids[s]].append(tok)
                left[s] -= 1
                if left[s] <= 0 or (cfg.eos_token is not None
                                    and tok == cfg.eos_token):
                    done[s] = True
                else:
                    cur[s, 0] = tok
            pos += 1
            if pos >= cfg.max_len - 1:
                break
        return key
