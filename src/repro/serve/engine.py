"""Batched decode engine: prefill + greedy/temperature decode against the
model's KV cache, with fixed-slot wave batching (requests are packed into
slots and a finished wave pulls the next requests from the queue without
recompiling) and NEAT placement support for reduced-precision serving.

Prefill is real: every prompt token is stepped through the compiled
decode step, so the KV cache holds the whole prompt and completions
condition on all of it. Prompts in a wave are left-aligned — shorter
prompts finish prefill and start sampling while longer prompts are still
streaming theirs — which keeps a single compiled (batch, 1)-token step
function for both phases. Because the cache carries one global position
scalar shared by all slots, slots are refilled between waves (each wave
starts from a fresh cache) rather than mid-wave, which would leak the
previous request's KV entries into the new request's attention window.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementRule
from repro.core.quantize import use_rule
from repro.models.model_api import Model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    batch_slots: int = 8
    temperature: float = 0.0          # 0 = greedy
    eos_token: Optional[int] = None
    seed: int = 0


class DecodeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 rule: Optional[PlacementRule] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.rule = rule
        with use_rule(rule):
            self._step = jax.jit(
                lambda p, c, t: model.decode_step(p, c, t))

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature).astype(jnp.int32)

    def _run_wave(self, wave, outputs, max_new_tokens, key):
        """Serve one wave of requests (<= batch_slots) from a fresh cache.

        Streams each slot's prompt through the compiled step token by
        token (prefill), then keeps stepping to decode; a slot flips from
        prefill to decode independently once its prompt is exhausted.
        """
        cfg = self.cfg
        n_slots = cfg.batch_slots
        # keep only the prompt tail that leaves cache room for the full
        # completion — otherwise a near-max_len prompt would exhaust the
        # cache mid-prefill and silently return a short/empty completion
        keep = max(1, cfg.max_len - 1 - max_new_tokens)
        prompts = [list(p)[-keep:] if p else [0] for _, p in wave]
        rids = [rid for rid, _ in wave]
        left = [max_new_tokens] * len(wave)
        done = [False] * len(wave)
        cache = self.model.init_cache(n_slots, cfg.max_len)
        cur = np.zeros((n_slots, 1), np.int32)
        for s, p in enumerate(prompts):
            cur[s, 0] = p[0]

        pos = 0                        # global cache position == step index
        while not all(done):
            key, sub = jax.random.split(key)
            logits, cache = self._step(self.params, cache, jnp.asarray(cur))
            nxt = np.asarray(self._sample(logits, sub))
            for s in range(len(wave)):
                if done[s]:
                    continue
                if pos + 1 < len(prompts[s]):
                    cur[s, 0] = prompts[s][pos + 1]   # still prefilling
                    continue
                tok = int(nxt[s])                     # prompt fully in cache
                outputs[rids[s]].append(tok)
                left[s] -= 1
                if left[s] <= 0 or (cfg.eos_token is not None
                                    and tok == cfg.eos_token):
                    done[s] = True
                else:
                    cur[s, 0] = tok
            pos += 1
            if pos >= cfg.max_len - 1:
                break
        return key

    def generate(self, prompts: List[List[int]],
                 max_new_tokens: int = 32) -> List[List[int]]:
        """Serve a list of token prompts; returns completions per prompt.
        Requests are packed into fixed slots wave by wave; each wave runs
        prefill + decode through one compiled step function."""
        queue = list(enumerate(prompts))
        outputs: dict[int, List[int]] = {i: [] for i in range(len(prompts))}
        key = jax.random.key(self.cfg.seed)

        with use_rule(self.rule):
            while queue:
                wave = [queue.pop(0) for _ in
                        range(min(self.cfg.batch_slots, len(queue)))]
                key = self._run_wave(wave, outputs, max_new_tokens, key)
        return [outputs[i] for i in range(len(prompts))]
