"""Mixture-of-Experts FFN: top-k routing with two dispatch strategies.

* ``ragged`` (default) — dropless MegaBlocks-style dispatch adapted to TPU:
  tokens are sorted by expert and fed through ``jax.lax.ragged_dot``
  (grouped GEMM on the MXU). Under the production mesh the expert (group)
  dim is sharded on "model" (EP) and tokens on "data"/"pod".
* ``dense`` — every expert computes every token, masked-combined. E× the
  FLOPs; used for tiny smoke configs and as a numerically transparent
  oracle for tests.

The router always runs in fp32 and is excluded from NEAT placement (its
FLOP share is negligible and routing decisions are precision-critical —
documented in DESIGN.md).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import quantize_here
from repro.core.scope import pscope
from repro.models.config import ModelConfig
from repro.models.layers import init_linear


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    scale = 1.0 / (d ** 0.5)
    p = {
        "router": init_linear(ks[0], d, e, dtype),
        "gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
               * scale).astype(dtype),
        "down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                 * (1.0 / f ** 0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = {
            "gate": init_linear(ks[4], d, fs, dtype),
            "up": init_linear(ks[4], d, fs, dtype),
            "down": init_linear(ks[4], fs, d, dtype),
        }
    return p


def _route(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routing. Returns (weights (S,k), idx (S,k)) for x: (S, D)."""
    logits = jnp.einsum("sd,de->se", x.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, idx


def _expert_ffn_dense(p, x, cfg: ModelConfig, weights, idx):
    """Masked-dense combine: every expert runs on every token."""
    e = cfg.n_experts
    # (S, E) combine matrix from the top-k weights
    comb = jnp.zeros((x.shape[0], e), jnp.float32).at[
        jnp.arange(x.shape[0])[:, None], idx].set(weights)
    g = jnp.einsum("sd,edf->sef", x, p["gate"].astype(x.dtype))
    u = jnp.einsum("sd,edf->sef", x, p["up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("sef,efd->sed", h, p["down"].astype(x.dtype))
    return jnp.einsum("sed,se->sd", y.astype(jnp.float32), comb).astype(x.dtype)


def _expert_ffn_ragged(p, x, cfg: ModelConfig, weights, idx):
    """Dropless dispatch: sort token-replicas by expert, grouped GEMM,
    weighted scatter-add back."""
    s, d = x.shape
    k, e = cfg.top_k, cfg.n_experts
    flat_idx = idx.reshape(-1)                      # (S*k,)
    order = jnp.argsort(flat_idx)                   # stable
    token_of = order // k                           # source token per replica
    xs = jnp.take(x, token_of, axis=0)              # (S*k, D) sorted by expert
    group_sizes = jnp.bincount(flat_idx, length=e).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, p["gate"].astype(xs.dtype), group_sizes)
    u = jax.lax.ragged_dot(xs, p["up"].astype(xs.dtype), group_sizes)
    h = jax.nn.silu(g) * u
    y = jax.lax.ragged_dot(h, p["down"].astype(xs.dtype), group_sizes)

    w_sorted = jnp.take(weights.reshape(-1), order)  # (S*k,)
    contrib = y.astype(jnp.float32) * w_sorted[:, None]
    out = jnp.zeros((s, d), jnp.float32).at[token_of].add(contrib)
    return out.astype(x.dtype)


def _expert_ffn_ep(p, x, cfg: ModelConfig, rules, capacity_factor=1.25):
    """Expert parallelism under shard_map: experts live on the "model"
    axis; tokens (replicated along the model row, sharded over dp) are
    dispatched to the local expert slice with a fixed per-expert capacity,
    computed with dense GEMMs, and combined with one psum over "model" —
    the Megatron EP schedule, with FSDP all-gather of expert weights over
    the dp axes.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    e, k = cfg.n_experts, cfg.top_k
    tp = rules.tp_axis
    dp = rules.dp_axes
    tp_size = rules.axis_size(tp)
    e_loc = e // tp_size
    s_global = x.shape[0]
    s_loc = s_global // rules.axis_size(dp)
    cap = max(8, int(capacity_factor * s_loc * k / e))

    def local_moe(xb, router_w, gate, up, down):
        # xb: (S_loc, D); experts sharded: gate (E_loc, D/dp?, F) — we
        # requested no-dp on experts below, so blocks are (E_loc, D, F).
        logits = jnp.einsum("sd,de->se", xb.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        w_topk, idx = jax.lax.top_k(probs, k)
        w_topk = w_topk / jnp.sum(w_topk, axis=-1, keepdims=True)
        # local expert ids for this model shard
        shard = jax.lax.axis_index(tp)
        e0 = shard * e_loc
        flat_e = idx.reshape(-1)                  # (S*k,)
        flat_w = w_topk.reshape(-1)
        tok = jnp.repeat(jnp.arange(s_loc), k)
        local = (flat_e >= e0) & (flat_e < e0 + e_loc)
        rel = jnp.where(local, flat_e - e0, e_loc)   # e_loc = trash bin
        # capacity selection: rank within expert by arrival order
        onehot = jax.nn.one_hot(rel, e_loc + 1, dtype=jnp.int32)
        rank = jnp.cumsum(onehot, axis=0) * onehot   # 1-based rank
        keep = (rank <= cap) & (onehot > 0)
        # build (E_loc, cap) token index table
        slot = (rank - 1).clip(0)
        table = jnp.full((e_loc + 1, cap), s_loc, jnp.int32)  # s_loc = pad
        wtab = jnp.zeros((e_loc + 1, cap), jnp.float32)
        # scatter via .at with (expert, slot) coordinates per replica
        exp_ids = rel
        slots = jnp.sum(slot * onehot, axis=1)
        valid = jnp.any(keep, axis=1)
        table = table.at[exp_ids, slots].set(
            jnp.where(valid, tok, s_loc), mode="drop")
        wtab = wtab.at[exp_ids, slots].set(
            jnp.where(valid, flat_w, 0.0), mode="drop")
        table = table[:e_loc]
        wtab = wtab[:e_loc]
        # gather tokens -> (E_loc, cap, D); pad row = zeros
        xpad = jnp.concatenate([xb, jnp.zeros((1, xb.shape[1]), xb.dtype)])
        xin = xpad[table]
        g = jnp.einsum("ecd,edf->ecf", xin, gate.astype(xin.dtype))
        u = jnp.einsum("ecd,edf->ecf", xin, up.astype(xin.dtype))
        h = jax.nn.silu(g) * u
        yexp = jnp.einsum("ecf,efd->ecd", h, down.astype(xin.dtype))
        # combine back to tokens, weighted
        contrib = (yexp.astype(jnp.float32)
                   * wtab[..., None]).reshape(-1, xb.shape[1])
        flat_tok = table.reshape(-1)
        y = jnp.zeros((s_loc + 1, xb.shape[1]), jnp.float32
                      ).at[flat_tok].add(contrib)[:s_loc]
        # sum partial expert outputs across the model row
        y = jax.lax.psum(y, tp)
        return y.astype(xb.dtype)

    in_specs = (P(dp, None), P(None, None),
                P(tp, None, None), P(tp, None, None), P(tp, None, None))
    out_specs = P(dp, None)
    fn = shard_map(local_moe, mesh=rules.mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(x, p["router"]["w"], p["gate"], p["up"], p["down"])


def moe_ffn(p, x, cfg: ModelConfig, *, impl: str = "ragged"):
    """x: (B, T, D) -> (B, T, D)."""
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    with pscope("moe"):
        if impl == "ep":
            from repro.sharding.specs import activation_rules
            rules = activation_rules()
            if rules is None:
                impl = "ragged"   # no mesh context: single-device path
        with pscope("router"):
            if impl != "ep":
                weights, idx = _route(p, xf, cfg)
        with pscope("experts"):
            if impl == "dense":
                y = _expert_ffn_dense(p, xf, cfg, weights, idx)
            elif impl == "ep":
                y = _expert_ffn_ep(p, xf, cfg, rules)
            else:
                y = _expert_ffn_ragged(p, xf, cfg, weights, idx)
            y = quantize_here(y, "dot")
        if "shared" in p:
            with pscope("shared_expert"):
                g = jnp.einsum("sd,df->sf", xf, p["shared"]["gate"]["w"]
                               .astype(x.dtype))
                u = jnp.einsum("sd,df->sf", xf, p["shared"]["up"]["w"]
                               .astype(x.dtype))
                h = jax.nn.silu(g) * u
                y = y + quantize_here(
                    jnp.einsum("sf,fd->sd", h, p["shared"]["down"]["w"]
                               .astype(x.dtype)), "dot")
    return y.reshape(b, t, d)


def load_balance_loss(p, x, cfg: ModelConfig) -> jnp.ndarray:
    """Switch-style auxiliary load-balancing loss (fraction x probability)."""
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    logits = jnp.einsum("sd,de->se", xf.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.top_k)
    counts = jnp.zeros((cfg.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
