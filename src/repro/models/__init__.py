from repro.models.config import ModelConfig
from repro.models.model_api import Model, build_model, abstract_params
