"""Model configuration — one dataclass covering every assigned family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu | relu
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0

    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0              # mamba2 heads (d_inner // head_dim)
    attn_period: int = 0            # hybrid: shared attn block every N layers
    block_kinds: Tuple[str, ...] = ()  # xlstm: per-layer "mlstm" | "slstm"

    # encoder-decoder
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # numerics
    dtype: str = "bfloat16"         # compute dtype
    param_dtype: str = "float32"

    # NEAT / kernels integration
    kernel_backend: str = "auto"    # auto | pallas | interpret | ref
    # paged flash: table entries streamed per KV grid step (block_k =
    # pages_per_block * page_size) — lets small pool pages fill the MXU
    # tile; serving validates it against the pool geometry (KVConfig)
    pages_per_block: int = 1

    # distribution / memory policy
    remat: bool = False             # per-layer activation checkpointing
    remat_policy: str = "full"      # full | dots (save dot outputs)
    attn_block_q: int = 1024        # q-block for scanned attention
    ssd_chunk: int = 128            # SSD chunk length
    moe_impl: str = "ragged"        # ragged | dense | ep (shard_map)
    # scan-over-layers: stacked params + lax.scan. Collapses the HLO to
    # one block body (compile time O(1) in depth — the MaxText approach).
    # Mutually exclusive with per-layer-INSTANCE NEAT placement (PLI);
    # WP/PLC/FCS rules apply unchanged inside the scanned body.
    scan_layers: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:       # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run long_500k? SSM/hybrid/sliding-window yes."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    @property
    def has_decoder(self) -> bool:
        return True   # no encoder-only archs in the assigned pool

    def reduced(self, *, n_layers: int = 2, d_model: int = 64,
                n_heads: int = 4, d_ff: int = 128, vocab: int = 512,
                seq: int = 0) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kv = max(1, min(self.n_kv_heads, n_heads))
        changes = dict(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=kv, d_ff=d_ff,
            vocab_size=min(self.vocab_size, vocab), head_dim=None,
            dtype="float32", param_dtype="float32",
        )
        if self.n_experts:
            changes.update(n_experts=min(self.n_experts, 8),
                           top_k=min(self.top_k, 2))
        if self.family == "ssm":
            changes.update(ssm_state=min(self.ssm_state or 16, 16),
                           ssm_heads=2,
                           block_kinds=tuple(self.block_kinds[:n_layers])
                           or ("mlstm", "slstm")[:n_layers])
        if self.family == "hybrid":
            changes.update(ssm_state=min(self.ssm_state or 16, 16),
                           ssm_heads=2, attn_period=2)
        if self.family == "encdec":
            changes.update(n_enc_layers=max(1, n_layers // 2),
                           n_dec_layers=max(1, n_layers // 2))
        if self.sliding_window:
            changes["sliding_window"] = 32
        return dataclasses.replace(self, **changes)

    # -- analytic parameter/FLOP counts (roofline + energy model) -----------
    def param_count(self) -> int:
        V, D, L, H, KV, Dh, F = (self.vocab_size, self.d_model, self.n_layers,
                                 self.n_heads, self.n_kv_heads, self.head_dim,
                                 self.d_ff)
        embed = V * D * (1 if self.tie_embeddings else 2)
        attn = D * (H * Dh) + 2 * D * (KV * Dh) + (H * Dh) * D
        if self.act == "swiglu":
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        per_layer = attn + mlp
        if self.family == "moe":
            expert = mlp
            per_layer = attn + self.n_experts * expert + D * self.n_experts
        if self.family == "ssm":
            di = self.d_inner
            per_layer = (D * 2 * di + di * D + di * (self.ssm_conv)
                         + di * 2 * self.ssm_state)
        if self.family == "hybrid":
            di = self.d_inner
            mamba = (D * 2 * di + di * D + di * self.ssm_conv
                     + di * 2 * self.ssm_state)
            n_attn = max(1, L // max(self.attn_period, 1))
            # shared attn block counted once (weight sharing)
            return embed + L * mamba + (attn + mlp) + 2 * L * D
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp)
            dec = self.n_dec_layers * (2 * attn + mlp)   # + cross attn
            return embed + enc + dec
        return embed + L * per_layer + 2 * L * D

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        V, D, L, F = (self.vocab_size, self.d_model, self.n_layers, self.d_ff)
        H, KV, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        embed = V * D * (1 if self.tie_embeddings else 2)
        attn = D * (H * Dh) + 2 * D * (KV * Dh) + (H * Dh) * D
        mlp = 3 * D * F if self.act == "swiglu" else 2 * D * F
        active = attn + (self.top_k + self.n_shared_experts) * mlp \
            + D * self.n_experts
        return embed + L * active + 2 * L * D
