"""Unified Model facade — one protocol across all assigned families."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer, xlstm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable                 # (rng) -> params
    forward: Callable              # (params, tokens_or_batch) -> logits
    loss: Callable                 # (params, batch) -> (loss, metrics)
    init_cache: Callable           # (batch, max_len) -> cache
    decode_step: Callable          # (params, cache, tokens) -> (logits, cache)
    reset_slots: Callable          # (cache, (B,) bool mask) -> cache
    #: chunked prefill: (params, cache, (B, C) tokens, (B,) n_new) ->
    #: ((B, 1, V) last-valid-column logits, cache advanced by n_new)
    prefill_chunk: Callable


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
    elif fam == "ssm":
        mod = xlstm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "encdec":
        mod = encdec
    else:
        raise ValueError(f"unknown family {fam!r}")
    return Model(
        cfg=cfg,
        init=lambda rng: mod.init_params(rng, cfg),
        forward=lambda p, tok: mod.forward(p, tok, cfg),
        loss=lambda p, batch: mod.loss_fn(p, batch, cfg),
        init_cache=lambda b, s: mod.init_cache(cfg, b, s),
        decode_step=lambda p, c, tok: mod.decode_step(p, c, tok, cfg),
        reset_slots=lambda c, m: mod.reset_slots(cfg, c, m),
        prefill_chunk=lambda p, c, tok, n: mod.prefill_chunk(p, c, tok, n,
                                                             cfg),
    )


def abstract_params(model: Model, seed: int = 0):
    """ShapeDtypeStruct params (no allocation) — dry-run currency."""
    return jax.eval_shape(lambda: model.init(jax.random.key(seed)))
