"""Unified Model facade — one protocol across all assigned families."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer, xlstm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable                 # (rng) -> params
    forward: Callable              # (params, tokens_or_batch) -> logits
    loss: Callable                 # (params, batch) -> (loss, metrics)
    init_cache: Callable           # (batch, max_len) -> cache
    decode_step: Callable          # (params, cache, tokens) -> (logits, cache)
    reset_slots: Callable          # (cache, (B,) bool mask) -> cache
    #: chunked prefill: (params, cache, (B, C) tokens, (B,) n_new) ->
    #: ((B, 1, V) last-valid-column logits, cache advanced by n_new)
    prefill_chunk: Callable
    #: paged cache: (batch, max_len, page_size, num_pages) -> cache with
    #: per-layer KV pools + (B, max_pages) block table (recurrent
    #: families return their dense cache — nothing to page)
    init_paged_cache: Callable
    #: packed ragged prefill: (params, cache, (T,) tokens, (T,) slot,
    #: (T,) qpos, (B,) last, cap) -> ((B, 1, V) logits, cache); ``cap``
    #: is the static per-slot row ceiling (recurrent families unpack
    #: into a (B, cap) rectangle)
    prefill_packed: Callable
    #: speculative verify, rectangle form: (params, cache, (B, C) window
    #: tokens, (B,) n_new, (B, K) draft, (B,) spec) -> ((B, C) greedy,
    #: (B,) n_acc, cache committed by the accepted advance) — the target
    #: model runs every window row through the chunk path and the cache
    #: position rewinds past rejected rows (attention families) or the
    #: scan merge never commits them (recurrent families)
    spec_verify: Callable = None
    #: speculative verify, packed ragged form: (params, cache, (T,)
    #: tokens, (T,) slot, (T,) qpos, (B, C) rowidx, (B,) n_new, (B, K)
    #: draft, (B,) spec, cap) -> ((B, C) greedy, (B,) n_acc, cache);
    #: speculation windows ride the same packed stream as prefill chunks
    spec_verify_packed: Callable = None
    #: fused multi-step decode ("megastep"): (params, cache, (B, 1) cur,
    #: (B,) pos, (B,) left, (B,) done, key, flush, *, n_steps,
    #: temperature, eos_token, max_len) -> ((ring, n_emitted, done, cur,
    #: pos, left, key, steps_run), cache) — up to n_steps decode steps
    #: in one jitted while_loop, host syncs once per window
    decode_loop: Callable = None
    #: preemption swap-out: (cache, slot, live, pages) -> host pytree of
    #: the slot's first ``live`` tokens of KV/state — paged families
    #: gather the listed pages out of each layer pool, contiguous ones
    #: copy the slot's cache rows, recurrent ones snapshot dense state
    snapshot_slot: Callable = None
    #: preemption swap-in: (cache, slot, live, pages, snap) -> cache with
    #: the snapshot written back (into the slot's *new* pages for paged
    #: layouts) and the slot's position set to ``live``
    restore_slot: Callable = None
    #: True when init_paged_cache really pages KV (block tables present),
    #: i.e. the engine's page allocator governs this family's memory
    paged_kv: bool = False


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
    elif fam == "ssm":
        mod = xlstm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "encdec":
        mod = encdec
    else:
        raise ValueError(f"unknown family {fam!r}")
    return Model(
        cfg=cfg,
        init=lambda rng: mod.init_params(rng, cfg),
        forward=lambda p, tok: mod.forward(p, tok, cfg),
        loss=lambda p, batch: mod.loss_fn(p, batch, cfg),
        init_cache=lambda b, s: mod.init_cache(cfg, b, s),
        decode_step=lambda p, c, tok: mod.decode_step(p, c, tok, cfg),
        reset_slots=lambda c, m: mod.reset_slots(cfg, c, m),
        prefill_chunk=lambda p, c, tok, n: mod.prefill_chunk(p, c, tok, n,
                                                             cfg),
        init_paged_cache=lambda b, s, ps, np_: mod.init_paged_cache(
            cfg, b, s, ps, np_),
        prefill_packed=lambda p, c, t, s, q, l, cap: mod.prefill_packed(
            p, c, t, s, q, l, cfg, cap=cap),
        spec_verify=lambda p, c, tok, n, d, sp: mod.spec_verify(
            p, c, tok, n, d, sp, cfg),
        spec_verify_packed=lambda p, c, t, s, q, ri, n, d, sp, cap:
            mod.spec_verify_packed(p, c, t, s, q, ri, n, d, sp, cfg,
                                   cap=cap),
        decode_loop=lambda p, c, cur, pos, left, done, key, flush, **kw:
            mod.decode_loop(p, c, cur, pos, left, done, key, flush, cfg,
                            **kw),
        snapshot_slot=lambda c, s, live, pages: mod.snapshot_slot(
            cfg, c, s, live, pages),
        restore_slot=lambda c, s, live, pages, snap: mod.restore_slot(
            cfg, c, s, live, pages, snap),
        paged_kv=fam != "ssm",
    )


def abstract_params(model: Model, seed: int = 0):
    """ShapeDtypeStruct params (no allocation) — dry-run currency."""
    return jax.eval_shape(lambda: model.init(jax.random.key(seed)))
