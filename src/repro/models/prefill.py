"""Shared chunked/packed prefill machinery for the serving engine.

Attention families ingest a (B, C) token chunk through one batched
``prefill_attention`` call per layer (the flash kernel's ``q_start``
path), or — the ragged form — a packed (ΣC,) token stream through
``packed_attention``, where each packed row carries its owning slot and
absolute cache position instead of padding every slot to the same C.
Recurrent / state-space families have no parallel form for their
streaming decode cell, so they scan the chunk **on-device**: one
``lax.scan`` of the family's single-token decode step over the chunk's
columns, inside one compiled dispatch, instead of round-tripping to the
host per token. Columns at or beyond a slot's ``n_new`` leave that
slot's state untouched (a masked merge), which is what makes mixed
prefill/decode batches — and ragged chunk tails — safe. The packed
entry for these families unpacks the stream back into a rectangle
bounded by the engine's per-slot chunk cap and rides the same scan.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import census as _census


def broadcast_n_new(n_new, batch: int) -> jnp.ndarray:
    """Normalize a per-slot valid-token count to (B,) int32 (a scalar
    broadcasts, mirroring the cache's position-vector convention)."""
    return jnp.broadcast_to(jnp.atleast_1d(
        jnp.asarray(n_new, jnp.int32)), (batch,))


def gather_last_logits(logits: jnp.ndarray, n_new: jnp.ndarray
                       ) -> jnp.ndarray:
    """(B, C, V) chunk logits -> (B, 1, V) logits of each slot's last
    *valid* column (``n_new[b] - 1``) — the one the engine samples.
    Slots with ``n_new == 0`` (inactive in a packed step) clamp to
    column 0; their logits are garbage the caller ignores."""
    idx = jnp.clip(n_new.astype(jnp.int32) - 1, 0)[:, None, None]
    return jnp.take_along_axis(logits, idx, axis=1)


def unpack_stream(tokens: jnp.ndarray, slot: jnp.ndarray, batch: int,
                  cap: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unpack a packed (T,) token stream into a (B, cap) rectangle.

    ``slot[i]`` names row i's owning slot (== ``batch`` for padding
    rows). Rows keep their stream order within a slot; ``cap`` is the
    static per-slot ceiling (the engine's prefill chunk), so the
    rectangle is (B, cap) regardless of T. Returns the rectangle and the
    (B,) per-slot counts (0 for slots with no rows). Rows past a slot's
    ``cap`` would be dropped — the engine never packs more than ``cap``
    rows per slot."""
    slot = slot.astype(jnp.int32)
    valid = slot < batch
    onehot = (slot[:, None] == jnp.arange(batch)[None, :]) & valid[:, None]
    rank = jnp.cumsum(onehot, axis=0, dtype=jnp.int32) - 1   # (T, B)
    rank = jnp.take_along_axis(
        rank, jnp.clip(slot, 0, batch - 1)[:, None], axis=1)[:, 0]
    counts = jnp.sum(onehot, axis=0, dtype=jnp.int32)        # (B,)
    rect = jnp.zeros((batch, cap), jnp.int32)
    rows = jnp.where(valid, jnp.clip(slot, 0, batch - 1), batch)
    cols = jnp.where(valid & (rank < cap), rank, cap)
    rect = rect.at[rows, cols].set(tokens.astype(jnp.int32), mode="drop")
    return rect, counts


def merge_slotwise(new_cache, old_cache, keep: jnp.ndarray):
    """Per-slot cache merge: take ``new`` for slots where ``keep`` is
    True, ``old`` elsewhere. Every slot-major leaf (leading axis B) is
    merged; **paged KV pools are left as written** — a pool is shared
    across slots, so it cannot be merged per slot, and it doesn't need
    to be: a masked slot's write this column landed at its *unadvanced*
    position, where it is hidden by the slot's ``kv_len`` mask and
    overwritten verbatim when the slot really ingests that position.
    Pool leaves are recognized as the ``layers`` subtree of a dict that
    also carries ``block_tables`` (the paged-cache signature)."""
    b = keep.shape[0]

    def rec(new, old):
        if isinstance(new, dict):
            paged = "block_tables" in new
            return {k: (new[k] if (paged and k == "layers")
                        else rec(new[k], old[k])) for k in new}
        if isinstance(new, (list, tuple)):
            merged = [rec(n, o) for n, o in zip(new, old)]
            return type(new)(merged)
        return jnp.where(keep.reshape((b,) + (1,) * (new.ndim - 1)),
                         new, old)

    return rec(new_cache, old_cache)


def spec_acceptance(logits: jnp.ndarray, draft: jnp.ndarray,
                    n_new: jnp.ndarray, spec: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Greedy draft-token acceptance for speculative decoding.

    ``logits``: (B, C, V) — column j's logits after ingesting the
    window's row j (row 0 is the slot's current token, rows 1..k its
    draft tokens); ``draft``: (B, K) drafted tokens (``K <= C``);
    ``n_new``: (B,) rows actually ingested this step (``k_s + 1`` for a
    speculating slot, the chunk take for a prefilling one); ``spec``:
    (B,) bool — True for slots whose rows are a speculation window.

    Returns ``(greedy, n_acc, adv)``: the (B, C) per-column greedy
    tokens, the (B,) count of *leading* draft matches (``draft[:, i] ==
    greedy[:, i]`` — column i's greedy token is the target's next token
    after draft i-1, i.e. what draft i claims to be), and the (B,)
    position advance to commit: ``n_acc + 1`` rows for a spec slot (its
    current token plus the accepted drafts — the bonus token
    ``greedy[:, n_acc]`` is *not* ingested, it becomes the next step's
    current token, exactly the non-speculative contract), ``n_new`` for
    everyone else. Only columns ``0..n_acc`` are ever read by the
    caller, and those are conditioned exclusively on committed rows —
    which is what makes verification exact under greedy decoding."""
    b, c, _ = logits.shape
    kmax = draft.shape[1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # (B, C)
    n_new = broadcast_n_new(n_new, b)
    cols = jnp.arange(kmax, dtype=jnp.int32)[None, :]
    match = ((draft.astype(jnp.int32) == greedy[:, :kmax])
             & (cols < (n_new - 1)[:, None]) & spec[:, None])
    # leading-run length: cumprod kills everything after the first miss
    n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    adv = jnp.where(spec, jnp.minimum(n_acc + 1, n_new), n_new)
    return greedy, n_acc.astype(jnp.int32), adv.astype(jnp.int32)


def spec_scan_verify(decode_step: Callable, params, cache,
                     tokens: jnp.ndarray, n_new: jnp.ndarray,
                     draft: jnp.ndarray, spec: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """Speculative verify for recurrent/hybrid families: one masked scan
    of the decode cell that *commits as it accepts*.

    A recurrent state cannot be position-rewound like a KV cache, so the
    rollback contract is implemented in the scan's merge mask instead:
    the carry tracks a per-slot ``alive`` flag that drops the moment a
    draft token mismatches the cell's own greedy prediction, and a
    column's state update is kept only while ``alive`` — the committed
    state is therefore exactly the state after ingesting the current
    token plus the accepted drafts, never the rejected tail. Columns
    past the first mismatch still *run* (their logits are collected, as
    in :func:`masked_scan_prefill` their writes are masked), but every
    column the caller reads (``0..n_acc``) was conditioned purely on
    committed rows, so verification is exact. Non-spec slots behave as
    in :func:`masked_scan_prefill` (``alive`` pinned True).

    Returns ``(greedy (B, C), n_acc (B,), cache)`` with the cache
    advanced by ``adv`` per slot (see :func:`spec_acceptance`)."""
    b, c = tokens.shape
    n_new = broadcast_n_new(n_new, b)
    spec = jnp.asarray(spec, bool)
    nxt = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1)

    # census-tape shield: notes inside the scan body are inner tracers,
    # so the body collects locally and threads the per-column total out
    # as a scan output (see core.census.collect)
    active = _census.census_active()

    def step(carry, xs):
        cc, alive = carry
        tok, ntok, col = xs                      # (B,), (B,), scalar
        if active:
            (logits, new_cache), cnt = _census.collect(
                lambda: decode_step(params, cc, tok[:, None]))
        else:
            logits, new_cache = decode_step(params, cc, tok[:, None])
        keep = alive & (col < n_new)
        merged = merge_slotwise(new_cache, cc, keep)
        g = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        # next column survives only if this column committed AND the
        # next token (the following draft) is the cell's own prediction
        alive = jnp.where(spec, keep & (ntok.astype(jnp.int32) == g),
                          True)
        y = logits[:, -1]
        return (merged, alive), ((y, cnt) if active else y)

    (cache, _), seq = jax.lax.scan(
        step, (cache, jnp.ones((b,), bool)),
        (tokens.T, nxt.T, jnp.arange(c, dtype=jnp.int32)))
    if active:
        seq, counts = seq
        _census.note_count(jnp.sum(counts, dtype=jnp.int32))
    logits = seq.transpose(1, 0, 2)              # (B, C, V)
    greedy, n_acc, _ = spec_acceptance(logits, draft, n_new, spec)
    return greedy, n_acc, cache


def packed_spec_scan_verify(decode_step: Callable, params, cache,
                            tokens: jnp.ndarray, slot: jnp.ndarray,
                            batch: int, cap: int, n_new: jnp.ndarray,
                            draft: jnp.ndarray, spec: jnp.ndarray
                            ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """Packed-stream speculative verify for recurrent families: unpack
    the (T,) stream into the (B, cap) rectangle (rows keep stream order,
    so a speculating slot's rows come out as ``[cur, d_1 .. d_k]``) and
    ride :func:`spec_scan_verify`."""
    rect, _ = unpack_stream(tokens, slot, batch, cap)
    return spec_scan_verify(decode_step, params, cache, rect, n_new,
                            draft, spec)


def masked_scan_prefill(decode_step: Callable, params, cache,
                        tokens: jnp.ndarray, n_new: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, dict]:
    """Chunked prefill by scanning a single-token decode cell.

    ``decode_step(params, cache, (B, 1) tokens) -> (logits, cache)`` is
    the family's streaming step; ``tokens``: (B, C); ``n_new``: (B,)
    valid tokens per slot. Column i's state update is kept only for
    slots with ``i < n_new[b]`` (every slot-major cache leaf carries the
    slot axis first; shared paged pools self-heal instead — see
    :func:`merge_slotwise`), so the scan is arithmetically identical to
    streaming each slot's valid tokens through ``decode_step`` one
    dispatch at a time — greedy parity with the streaming engine is
    bit-exact. Returns the (B, 1, V) logits of each slot's last valid
    column and the new cache.
    """
    b, c = tokens.shape
    n_new = broadcast_n_new(n_new, b)

    # census-tape shield: see spec_scan_verify
    active = _census.census_active()

    def step(carry, xs):
        tok, col = xs                               # (B,), scalar
        if active:
            (logits, new_cache), cnt = _census.collect(
                lambda: decode_step(params, carry, tok[:, None]))
        else:
            logits, new_cache = decode_step(params, carry, tok[:, None])
        merged = merge_slotwise(new_cache, carry, col < n_new)
        y = logits[:, 0]                            # (B, V)
        return merged, ((y, cnt) if active else y)

    cache, seq = jax.lax.scan(
        step, cache, (tokens.T, jnp.arange(c, dtype=jnp.int32)))
    if active:
        seq, counts = seq
        _census.note_count(jnp.sum(counts, dtype=jnp.int32))
    return gather_last_logits(seq.transpose(1, 0, 2), n_new), cache


def packed_scan_prefill(decode_step: Callable, params, cache,
                        tokens: jnp.ndarray, slot: jnp.ndarray,
                        batch: int, cap: int
                        ) -> Tuple[jnp.ndarray, dict]:
    """Packed-stream prefill for recurrent families: unpack the (T,)
    stream into a (B, cap) rectangle (rows keep stream order; ``cap``
    is the engine's static per-slot chunk ceiling) and scan the family's
    decode cell over its columns. The dense recurrent state rides the
    per-slot masked merge exactly as in :func:`masked_scan_prefill`;
    the packed layout only changes the *token plumbing*, not the
    arithmetic."""
    rect, counts = unpack_stream(tokens, slot, batch, cap)
    return masked_scan_prefill(decode_step, params, cache, rect, counts)
