"""Shared chunked-prefill machinery for the serving engine.

Attention families ingest a (B, C) token chunk through one batched
``prefill_attention`` call per layer (the flash kernel's ``q_start``
path). Recurrent / state-space families have no parallel form for their
streaming decode cell, so they scan the chunk **on-device**: one
``lax.scan`` of the family's single-token decode step over the chunk's
columns, inside one compiled dispatch, instead of round-tripping to the
host per token. Columns at or beyond a slot's ``n_new`` leave that
slot's state untouched (a masked merge), which is what makes mixed
prefill/decode batches — and ragged chunk tails — safe.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def broadcast_n_new(n_new, batch: int) -> jnp.ndarray:
    """Normalize a per-slot valid-token count to (B,) int32 (a scalar
    broadcasts, mirroring the cache's position-vector convention)."""
    return jnp.broadcast_to(jnp.atleast_1d(
        jnp.asarray(n_new, jnp.int32)), (batch,))


def gather_last_logits(logits: jnp.ndarray, n_new: jnp.ndarray
                       ) -> jnp.ndarray:
    """(B, C, V) chunk logits -> (B, 1, V) logits of each slot's last
    *valid* column (``n_new[b] - 1``) — the one the engine samples."""
    idx = (n_new.astype(jnp.int32) - 1)[:, None, None]
    return jnp.take_along_axis(logits, idx, axis=1)


def masked_scan_prefill(decode_step: Callable, params, cache,
                        tokens: jnp.ndarray, n_new: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, dict]:
    """Chunked prefill by scanning a single-token decode cell.

    ``decode_step(params, cache, (B, 1) tokens) -> (logits, cache)`` is
    the family's streaming step; ``tokens``: (B, C); ``n_new``: (B,)
    valid tokens per slot. Column i's state update is kept only for
    slots with ``i < n_new[b]`` (every cache leaf carries the slot axis
    first), so the scan is arithmetically identical to streaming each
    slot's valid tokens through ``decode_step`` one dispatch at a time —
    greedy parity with the streaming engine is bit-exact. Returns the
    (B, 1, V) logits of each slot's last valid column and the new cache.
    """
    b, c = tokens.shape
    n_new = broadcast_n_new(n_new, b)

    def step(carry, xs):
        tok, col = xs                               # (B,), scalar
        logits, new_cache = decode_step(params, carry, tok[:, None])
        keep = col < n_new                          # (B,)
        merged = jax.tree.map(
            lambda n, o: jnp.where(
                keep.reshape((b,) + (1,) * (n.ndim - 1)), n, o),
            new_cache, carry)
        return merged, logits[:, 0]                 # (B, V)

    cache, seq = jax.lax.scan(
        step, cache, (tokens.T, jnp.arange(c, dtype=jnp.int32)))
    return gather_last_logits(seq.transpose(1, 0, 2), n_new), cache
