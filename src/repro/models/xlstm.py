"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM (matrix memory,
parallelizable) and sLSTM (scalar memory, sequential recurrence).

The mLSTM recurrence C_t = f_t C_{t-1} + i_t v_t k_t^T is the same algebra
as Mamba-2's SSD, so training reuses ``chunked_linear_recurrence`` — the
chunk-parallel MXU-friendly engine — with a = sigmoid(f) and v scaled by
the input gate (stabilized sigmoid-gate variant; the paper's exponential
gating with running max is implemented in the decode step where it is
cheap; DESIGN.md records this adaptation). sLSTM keeps the paper's
sequential form via lax.scan (no parallel form exists — the recurrent
R h_{t-1} term forbids it, as the xLSTM paper notes).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import quantize_here
from repro.core.scope import pscope, tag_phase
from repro.models.config import ModelConfig
from repro.models.layers import (init_linear, init_norm, linear,
                                 maybe_remat, norm)
from repro.models.ssm import chunked_linear_recurrence, recurrence_step
from repro.sharding.specs import shard_activations


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "wq": init_linear(ks[0], d, d, dtype),
        "wk": init_linear(ks[1], d, d, dtype),
        "wv": init_linear(ks[2], d, d, dtype),
        "wi": init_linear(ks[3], d, h, dtype),       # input gate (per head)
        "wf": init_linear(ks[4], d, h, dtype),       # forget gate
        "wo_gate": init_linear(ks[5], d, d, dtype),  # output gate
        "out_norm": init_norm(dh, dtype),
        "out_proj": init_linear(ks[6], d, d, dtype),
    }


def _mlstm_gates(p, x):
    i = jax.nn.sigmoid(linear(p["wi"], x).astype(jnp.float32))  # (B,T,H)
    f = jax.nn.sigmoid(linear(p["wf"], x).astype(jnp.float32) + 3.0)
    return i, f


def mlstm_forward(p, x, cfg: ModelConfig, *, chunk: int = 128):
    b, t, d = x.shape
    h = cfg.n_heads
    dh = d // h
    with pscope("mlstm"):
        with pscope("qkv"):
            q = linear(p["wq"], x).reshape(b, t, h, dh)
            k = linear(p["wk"], x).reshape(b, t, h, dh) / (dh ** 0.5)
            v = linear(p["wv"], x).reshape(b, t, h, dh)
        i, f = _mlstm_gates(p, x)
        with pscope("memory"):
            # matrix memory: C = f C + i v k^T ; numerator = q . C
            num, _ = chunked_linear_recurrence(
                f, k, (v.astype(jnp.float32) * i[..., None]).astype(x.dtype),
                q, chunk=chunk)
            # normalizer: n = f n + i k ; denom = |q . n|
            den, _ = chunked_linear_recurrence(
                f, k, i[..., None].astype(x.dtype),
                q, chunk=chunk)
            y = num / jnp.maximum(jnp.abs(den), 1.0)
            y = quantize_here(y, "dot").astype(x.dtype)
        y = norm(p["out_norm"], y)
        o = jax.nn.sigmoid(linear(p["wo_gate"], x)).reshape(b, t, h, dh)
        y = (y * o).reshape(b, t, d)
        with pscope("out_proj"):
            return linear(p["out_proj"], y)


def mlstm_init_cache(cfg: ModelConfig, batch: int):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh, 1), jnp.float32)}


def mlstm_step(p, x, cfg: ModelConfig, cache):
    b, _, d = x.shape
    h = cfg.n_heads
    dh = d // h
    with pscope("mlstm"):
        with pscope("qkv"):
            q = linear(p["wq"], x).reshape(b, h, dh)
            k = linear(p["wk"], x).reshape(b, h, dh) / (dh ** 0.5)
            v = linear(p["wv"], x).reshape(b, h, dh)
        i, f = _mlstm_gates(p, x)
        i, f = i[:, 0], f[:, 0]                               # (B,H)
        with pscope("memory"):
            num, C = recurrence_step(
                cache["C"], f, k.astype(jnp.float32),
                v.astype(jnp.float32) * i[..., None], q.astype(jnp.float32))
            den, n = recurrence_step(
                cache["n"], f, k.astype(jnp.float32),
                i[..., None], q.astype(jnp.float32))
            y = num.astype(jnp.float32) / jnp.maximum(jnp.abs(den), 1.0)
            y = quantize_here(y, "dot").astype(x.dtype)
        y = norm(p["out_norm"], y)
        o = jax.nn.sigmoid(linear(p["wo_gate"], x)).reshape(b, h, dh)
        y = (y * o).reshape(b, 1, d)
        with pscope("out_proj"):
            out = linear(p["out_proj"], y)
    return out, {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # 4 gates (i, f, z, o), each with input + block-diagonal recurrent weights
    return {
        "wx": init_linear(ks[0], d, 4 * d, dtype),
        "r": (jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32)
              / (dh ** 0.5)).astype(dtype),
        "bias": jnp.zeros((4 * d,), dtype),
        "out_norm": init_norm(d, dtype),
        "up": init_linear(ks[2], d, int(d * 4 / 3), dtype),
        "gate": init_linear(ks[3], d, int(d * 4 / 3), dtype),
        "down": init_linear(ks[4], int(d * 4 / 3), d, dtype),
    }


def slstm_init_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32),
            "nrm": jnp.zeros((batch, d), jnp.float32)}


def _slstm_cell(p, cfg: ModelConfig, state, wx_t):
    """One sLSTM step with exponential-gate stabilization."""
    b = wx_t.shape[0]
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    hid = state["h"].reshape(b, h, dh)
    rec = jnp.einsum("bhd,hdf->bhf", hid.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    pre = wx_t.astype(jnp.float32) + rec + p["bias"].astype(jnp.float32)
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    # stabilized exponential gating (xLSTM eq. 15-17)
    m_new = jnp.maximum(fi + state["m"], ii)
    i_g = jnp.exp(ii - m_new)
    f_g = jnp.exp(fi + state["m"] - m_new)
    c = f_g * state["c"] + i_g * z
    nrm = f_g * state["nrm"] + i_g
    h_new = o * c / jnp.maximum(nrm, 1.0)
    return {"c": c, "h": h_new, "m": m_new, "nrm": nrm}


def slstm_forward(p, x, cfg: ModelConfig):
    b, t, d = x.shape
    with pscope("slstm"):
        with pscope("in_proj"):
            wx = linear(p["wx"], x)                    # (B,T,4D)

        def step(state, wx_t):
            new = _slstm_cell(p, cfg, state, wx_t)
            return new, new["h"]

        init = slstm_init_cache(cfg, b)
        with pscope("recurrence"):
            _, hs = jax.lax.scan(step, init, wx.transpose(1, 0, 2))
        y = hs.transpose(1, 0, 2).astype(x.dtype)      # (B,T,D)
        y = norm(p["out_norm"], y)
        with pscope("ffn"):
            u = linear(p["up"], y)
            g = jax.nn.sigmoid(linear(p["gate"], y))
            y = linear(p["down"], u * g)
        return quantize_here(y, "dot")


def slstm_step(p, x, cfg: ModelConfig, cache):
    with pscope("slstm"):
        with pscope("in_proj"):
            wx = linear(p["wx"], x)[:, 0]
        new = _slstm_cell(p, cfg, cache, wx)
        y = new["h"][:, None, :].astype(x.dtype)
        y = norm(p["out_norm"], y)
        with pscope("ffn"):
            u = linear(p["up"], y)
            g = jax.nn.sigmoid(linear(p["gate"], y))
            y = linear(p["down"], u * g)
        return quantize_here(y, "dot"), new


# ---------------------------------------------------------------------------
# Full xLSTM language model (stack of mLSTM/sLSTM blocks per block_kinds)
# ---------------------------------------------------------------------------

from repro.models.layers import (cross_entropy, embedding, init_embedding,
                                 unembed)


def block_kinds(cfg: ModelConfig):
    if cfg.block_kinds:
        return cfg.block_kinds
    # xLSTM[7:1] default: every 8th block is sLSTM
    return tuple("slstm" if (i % 8) == 7 else "mlstm"
                 for i in range(cfg.n_layers))


def _kind_runs(kinds):
    """Group consecutive identical kinds: [('mlstm', 7), ('slstm', 1)]..."""
    runs = []
    for kind in kinds:
        if runs and runs[-1][0] == kind:
            runs[-1] = (kind, runs[-1][1] + 1)
        else:
            runs.append((kind, 1))
    return runs


def _init_block(k, cfg: ModelConfig, kind: str):
    dtype = jnp.dtype(cfg.param_dtype)
    init = init_mlstm if kind == "mlstm" else init_slstm
    return {"norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "core": init(k, cfg)}


def init_params(key, cfg: ModelConfig):
    kinds = block_kinds(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.n_layers + 2)
    if cfg.scan_layers:
        runs = _kind_runs(kinds)
        blocks = []
        i = 0
        for kind, count in runs:
            rkeys = jax.random.split(ks[i + 1], count)
            blocks.append(jax.vmap(
                lambda k, _kind=kind: _init_block(k, cfg, _kind))(rkeys))
            i += count
    else:
        blocks = [_init_block(ks[i + 1], cfg, kind)
                  for i, kind in enumerate(kinds)]
    return {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": init_norm(cfg.d_model, dtype, cfg.norm),
        "head": init_linear(ks[-1], cfg.d_model, cfg.vocab_size, dtype),
    }


def forward(params, tokens, cfg: ModelConfig) -> jnp.ndarray:
    kinds = block_kinds(cfg)

    def _layer(blk, y, i):
        with pscope(f"layer{i:02d}"):
            h = norm(blk["norm"], y, cfg.norm)
            if kinds[i] == "mlstm":
                y = y + mlstm_forward(blk["core"], h, cfg,
                                      chunk=cfg.ssd_chunk)
            else:
                y = y + slstm_forward(blk["core"], h, cfg)
            return shard_activations(y)

    with pscope("model"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        x = shard_activations(x)
        if cfg.scan_layers:
            runs = _kind_runs(kinds)
            for run_i, (kind, count) in enumerate(runs):
                stacked = params["blocks"][run_i]

                def body(y, blk, _kind=kind):
                    with pscope(_kind):
                        h = norm(blk["norm"], y, cfg.norm)
                        if _kind == "mlstm":
                            y = y + mlstm_forward(blk["core"], h, cfg,
                                                  chunk=cfg.ssd_chunk)
                        else:
                            y = y + slstm_forward(blk["core"], h, cfg)
                        return shard_activations(y), None

                x, _ = jax.lax.scan(maybe_remat(body, cfg), x, stacked)
        else:
            for i, blk in enumerate(params["blocks"]):
                fn = maybe_remat(lambda b, y, _i=i: _layer(b, y, _i), cfg)
                x = fn(blk, x)
        x = norm(params["final_norm"], x, cfg.norm)
        return unembed(params["head"], x, tied=False)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"ce": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    kinds = block_kinds(cfg)
    caches = []
    for kind in kinds:
        caches.append(mlstm_init_cache(cfg, batch) if kind == "mlstm"
                      else slstm_init_cache(cfg, batch))
    return {"blocks": caches, "pos": jnp.zeros((batch,), jnp.int32)}


def reset_slots(cfg: ModelConfig, cache, mask):
    """Restore the (B,) bool-masked slots' recurrent state to its initial
    value (sLSTM's stabilizer ``m`` starts at -1e30, not 0) so a retired
    slot can serve a fresh request mid-flight."""
    kinds = block_kinds(cfg)
    batch = mask.shape[0]
    blocks = []
    for kind, blk in zip(kinds, cache["blocks"]):
        init = (mlstm_init_cache(cfg, batch) if kind == "mlstm"
                else slstm_init_cache(cfg, batch))
        blocks.append(jax.tree.map(
            lambda cur, iv: jnp.where(
                mask.reshape((batch,) + (1,) * (cur.ndim - 1)), iv, cur),
            blk, init))
    return {"blocks": blocks, "pos": jnp.where(mask, 0, cache["pos"])}


def snapshot_slot(cfg: ModelConfig, cache, s: int, live: int, pages):
    """Preemption swap-out: the recurrent state is dense and per-slot —
    every leaf carries a leading batch axis, so slot ``s``'s state is
    the ``[s]`` slice of each (no pages involved)."""
    del pages
    return jax.device_get(jax.tree.map(lambda v: v[s], cache["blocks"]))


def restore_slot(cfg: ModelConfig, cache, s: int, live: int, pages, snap):
    """Preemption swap-in: scatter the dense snapshot back into slot
    ``s`` and set its position to ``live``."""
    del pages
    blocks = jax.tree.map(
        lambda v, sl: v.at[s].set(jnp.asarray(sl, v.dtype)),
        cache["blocks"], snap)
    return {"blocks": blocks, "pos": cache["pos"].at[s].set(live)}


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     page_size: int, num_pages: int):
    """A pure recurrent stack has no KV length axis to page — the dense
    per-slot state IS the cache. The paged engine therefore runs this
    family with its ordinary cache (and a virtual, never-exhausted page
    pool); only the packed-token plumbing is adopted."""
    del page_size, num_pages
    return init_cache(cfg, batch, max_len)


@tag_phase("prefill")
def prefill_chunk(params, cache, tokens, n_new, cfg: ModelConfig):
    """Chunked prefill for the recurrent stack: no parallel form exists
    for the streaming cells (sLSTM's R h_{t-1} term forbids it), so the
    chunk is scanned on-device — one compiled ``lax.scan`` of the decode
    cell over the chunk's columns with per-slot ``n_new`` state masking —
    instead of one host dispatch per token."""
    from repro.models.prefill import masked_scan_prefill
    return masked_scan_prefill(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        n_new)


@tag_phase("prefill")
def prefill_packed(params, cache, tokens, slot, qpos, last,
                   cfg: ModelConfig, *, cap: int):
    """Packed-stream prefill: unpack the (ΣC,) stream into a (B, cap)
    rectangle and ride the masked decode-cell scan (the state is dense,
    so only the token plumbing changes)."""
    del qpos, last
    from repro.models.prefill import packed_scan_prefill
    batch = cache["pos"].shape[0]
    return packed_scan_prefill(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        slot, batch, cap)


@tag_phase("verify")
def spec_verify(params, cache, tokens, n_new, draft, spec,
                cfg: ModelConfig):
    """Speculative verify for the pure-recurrent stack: the decode cell
    scanned over the window with commit-as-you-accept state masking —
    a recurrent state has no position axis to rewind, so rejection is a
    masked merge, not a rewind (``prefill.spec_scan_verify``)."""
    from repro.models.prefill import spec_scan_verify
    return spec_scan_verify(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        n_new, draft, spec)


@tag_phase("verify")
def spec_verify_packed(params, cache, tokens, slot, qpos, rowidx, n_new,
                       draft, spec, cfg: ModelConfig, *, cap: int):
    """Packed-stream speculative verify: unpack into the (B, cap)
    rectangle (rows keep stream order, so a window arrives as
    ``[cur, d_1 .. d_k]``) and ride the commit-as-you-accept scan."""
    del qpos, rowidx
    from repro.models.prefill import packed_spec_scan_verify
    batch = cache["pos"].shape[0]
    return packed_spec_scan_verify(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        slot, batch, cap, n_new, draft, spec)


@tag_phase("decode")
def decode_step(params, cache, tokens, cfg: ModelConfig):
    kinds = block_kinds(cfg)
    with pscope("model"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        new = []
        for i, blk in enumerate(params["blocks"]):
            with pscope(f"layer{i:02d}"):
                h = norm(blk["norm"], x, cfg.norm)
                if kinds[i] == "mlstm":
                    y, c = mlstm_step(blk["core"], h, cfg,
                                      cache["blocks"][i])
                else:
                    y, c = slstm_step(blk["core"], h, cfg,
                                      cache["blocks"][i])
                x = x + y
                new.append(c)
        x = norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["head"], x, tied=False)
    return logits, {"blocks": new, "pos": cache["pos"] + 1}


def decode_loop(params, cache, cur, pos, left, done, key, flush,
                cfg: ModelConfig, *, n_steps: int, temperature: float,
                eos_token, max_len: int):
    """Megastep: up to ``n_steps`` fused recurrence steps on device."""
    from repro.models.decode_loop import fused_decode_loop
    return fused_decode_loop(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, cur,
        pos, left, done, key, flush, n_steps=n_steps,
        temperature=temperature, eos_token=eos_token, max_len=max_len)
