"""Fused multi-step decode loop ("megastep"): up to ``n_steps``
consecutive pure-decode steps inside ONE jitted ``lax.while_loop``, with
the sampled/greedy token fed back on device — the serving engine syncs
with the host once per window instead of once per token.

The loop mirrors the engine's single-step scheduler exactly, which is
what makes byte-identical output across megastep boundaries a contract
rather than a hope:

* each iteration runs the family's ``decode_step`` on the full (B, 1)
  batch — retired/free slots feed token 0, exactly what the host loop
  dispatches for a free slot — then takes argmax (greedy) or a
  ``jax.random.categorical`` sample at ``temperature > 0``; the PRNG key
  is split once per iteration (the host loop's split schedule), so the
  sampled stream is bit-identical too;
* emitted tokens land in a per-slot **ring buffer** row ``ring[s, j]``
  (j-th token of the window; ``done`` is monotone, so each live slot
  fills a contiguous prefix of length ``n_emitted[s]``);
* per-slot stop is detected on device with the host's own retire rule,
  in the host's own order: advance the position, spend the budget, then
  retire on ``left <= 0``, EOS, or the cache-exhaustion guard
  ``pos >= max_len - 1``;
* the while condition early-exits once every slot is done — and, with
  ``flush_on_retire`` set (the engine passes it when admissions are
  pending), the moment ANY slot retires, so a freed slot is offered
  back to the scheduler at the same step boundary the single-step
  engine would have admitted into it;
* when a census scope is open (``ServeConfig.estimate_energy``), the
  fused kernel epilogues' bit counts are collected per iteration and
  threaded through the loop carry (the ``lax.scan`` shield of
  ``core.census``, applied to a while carry), then noted once on the
  enclosing tape — the megastep's measured census equals the sum of the
  single steps it replaces, exactly.

Returns ``((ring, n_emitted, done, cur, pos, left, key, steps_run),
cache)``; every array in the first tuple is the device-side carry the
engine feeds straight into the NEXT megastep (dispatch-ahead double
buffering) without a host round trip.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import census as _census


def fused_decode_loop(step_fn: Callable, params, cache,
                      cur: jnp.ndarray, pos: jnp.ndarray,
                      left: jnp.ndarray, done: jnp.ndarray,
                      key, flush_on_retire: jnp.ndarray, *,
                      n_steps: int, temperature: float,
                      eos_token: Optional[int], max_len: int):
    """Run up to ``n_steps`` decode steps of ``step_fn(params, cache,
    (B, 1) tokens) -> (logits, cache)`` on device.

    ``cur`` is (B, 1) int32 (next token per slot), ``pos``/``left`` are
    (B,) int32 (cache position / completion budget), ``done`` is (B,)
    bool (True for free slots), ``flush_on_retire`` a bool scalar
    operand (dynamic, so toggling it never retraces)."""
    B = cur.shape[0]
    done0 = done
    collect = _census.census_active()

    def cond(carry):
        i, _, _, _, _, done, _, _, _, _ = carry
        newly_retired = jnp.any(done & ~done0)
        return ((i < n_steps) & ~jnp.all(done)
                & ~(flush_on_retire & newly_retired))

    def body(carry):
        i, c, cur, pos, left, done, key, ring, nem, bits = carry
        tok_in = jnp.where(done[:, None], 0, cur)
        if collect:
            (logits, c), cnt = _census.collect(
                lambda: step_fn(params, c, tok_in))
            bits = bits + cnt
        else:
            logits, c = step_fn(params, c, tok_in)
        key, sub = jax.random.split(key)
        last = logits[:, -1, :]
        if temperature <= 0.0:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                sub, last / temperature).astype(jnp.int32)
        emit = ~done
        adv = emit.astype(jnp.int32)
        ring = ring.at[:, i].set(jnp.where(emit, nxt, 0))
        nem = nem + adv
        pos = pos + adv
        left = left - adv
        stop = (left <= 0) | (pos >= max_len - 1)
        if eos_token is not None:
            stop = stop | (nxt == eos_token)
        done = done | (emit & stop)
        cur = jnp.where(done[:, None], 0, nxt[:, None])
        return i + 1, c, cur, pos, left, done, key, ring, nem, bits

    carry = (jnp.zeros((), jnp.int32), cache, cur, pos, left, done, key,
             jnp.zeros((B, n_steps), jnp.int32), jnp.zeros((B,), jnp.int32),
             jnp.zeros((), jnp.int32))
    (i, cache, cur, pos, left, done, key, ring, nem,
     bits) = jax.lax.while_loop(cond, body, carry)
    if collect:
        _census.note_count(bits)
    return (ring, nem, done, cur, pos, left, key, i), cache
