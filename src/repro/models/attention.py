"""Attention: GQA/MHA with RoPE, optional QKV bias, QK-norm, sliding
window; a training path (flash kernel or jnp reference) and a decode path
against a preallocated KV cache (flash-decoding style, shardable)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import quantize_here
from repro.core.scope import pscope
from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.layers import init_linear, init_norm, linear, norm, rotary

NEG_INF = -1e30


def _sdpa_scan(q, k, v, *, causal: bool, window, block_q: int):
    """Memory-efficient attention: lax.scan over q blocks with an
    in-scan remat body — peak temp is one (B, H, bq, Tk) logits block and
    the backward recomputes it per block (flash semantics in pure jnp;
    the Pallas kernel replaces this on real TPUs).

    q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D); queries right-aligned.
    """
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    bq = min(block_q, tq)
    pad = (-tq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nq = (tq + pad) // bq
    qb = q.reshape(b, hq, nq, bq, d).transpose(2, 0, 1, 3, 4)
    starts = jnp.arange(nq) * bq
    kg = k.reshape(b, hkv, 1, tk, d)
    vg = v.reshape(b, hkv, 1, tk, d)

    def body(carry, xs):
        qblk, start = xs                       # (B,Hq,bq,D), scalar
        qr = qblk.reshape(b, hkv, group, bq, d)
        s = jnp.einsum("bhgqd,bhukd->bhgqk", qr.astype(jnp.float32),
                       kg.astype(jnp.float32)) * scale
        qpos = start + jnp.arange(bq)[:, None] + (tk - (tq + pad))
        kpos = jnp.arange(tk)[None, :]
        mask = jnp.ones((bq, tk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhukd->bhgqd", p, vg.astype(jnp.float32))
        return carry, o.reshape(b, hq, bq, d).astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(body), 0, (qb, starts))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, tq + pad, d)
    return out[:, :, :tq]


def _sdpa(q, k, v, cfg: ModelConfig, *, causal: bool):
    backend = cfg.kernel_backend
    if backend in ("pallas", "interpret"):
        return kops.flash_attention(q, k, v, causal=causal,
                                    window=cfg.sliding_window,
                                    backend=backend)
    tq, tk = q.shape[2], k.shape[2]
    if max(tq, tk) <= 2 * cfg.attn_block_q:
        return kops.flash_attention(q, k, v, causal=causal,
                                    window=cfg.sliding_window,
                                    backend="ref")
    return _sdpa_scan(q, k, v, causal=causal, window=cfg.sliding_window,
                      block_q=cfg.attn_block_q)


def init_attention(key, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, h * dh, dtype, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, kv * dh, dtype, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, kv * dh, dtype, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = init_norm(dh, dtype)
        p["knorm"] = init_norm(dh, dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    from repro.sharding.specs import shard_hint
    b, t, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    with pscope("qkv"):
        q = shard_hint(linear(p["wq"], x).reshape(b, t, h, dh), "heads")
        k = shard_hint(linear(p["wk"], x).reshape(b, t, kv, dh), "heads")
        v = shard_hint(linear(p["wv"], x).reshape(b, t, kv, dh), "heads")
    if cfg.qk_norm:
        q = norm(p["qnorm"], q)
        k = norm(p["knorm"], k)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    return q, k, v


def attention(p, x, cfg: ModelConfig, *, causal: bool = True,
              positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill). x: (B, T, D)."""
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t)
    with pscope("attn"):
        q, k, v = _project_qkv(p, x, cfg, positions)
        qh = q.transpose(0, 2, 1, 3)   # (B, H, T, Dh)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        with pscope("sdpa"):
            out = _sdpa(qh, kh, vh, cfg, causal=causal)
            out = quantize_here(out, "dot")
        out = out.transpose(0, 2, 1, 3).reshape(b, t, -1)
        with pscope("out_proj"):
            return linear(p["wo"], out)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None, dtype=None):
    """Preallocated cache: one (B, S, KV, Dh) K/V pair per layer."""
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    dt = dtype or cfg.compute_dtype
    n = n_layers if n_layers is not None else cfg.n_layers
    layer = lambda: {
        "k": jnp.zeros((batch, max_len, kv, dh), dt),
        "v": jnp.zeros((batch, max_len, kv, dh), dt),
    }
    return {"layers": [layer() for _ in range(n)],
            "pos": jnp.zeros((), jnp.int32)}


def decode_attention(p, x, cfg: ModelConfig, layer_cache, pos
                     ) -> Tuple[jnp.ndarray, dict]:
    """Single-token decode. x: (B, 1, D); cache k/v: (B, S, KV, Dh);
    pos: scalar int32 — the index being written.

    The score/value contractions reduce over the cache's S axis, so under a
    sequence-sharded cache GSPMD emits the flash-decoding partial-softmax
    all-reduce automatically.
    """
    b, t, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    with pscope("attn"):
        positions = jnp.full((t,), pos, jnp.int32)
        q, k, v = _project_qkv(p, x, cfg, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["k"], k.astype(layer_cache["k"].dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["v"], v.astype(layer_cache["v"].dtype), pos, axis=1)
        group = h // kv
        qh = q.reshape(b, kv, group, dh)              # t == 1
        with pscope("sdpa"):
            scores = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                                ck.astype(jnp.float32)) / jnp.sqrt(
                                    jnp.float32(dh))
            scores = quantize_here(scores, "dot")
            s_idx = jnp.arange(ck.shape[1])
            valid = s_idx <= pos
            if cfg.sliding_window is not None:
                valid &= s_idx > pos - cfg.sliding_window
            scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
            w = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bkgs,bskd->bkgd", w, cv.astype(jnp.float32))
            out = quantize_here(out, "dot").astype(x.dtype)
        out = out.reshape(b, 1, h * dh)
        with pscope("out_proj"):
            y = linear(p["wo"], out)
    return y, {"k": ck, "v": cv}


def cross_attention(p, x, memory, cfg: ModelConfig) -> jnp.ndarray:
    """Encoder-decoder cross attention. x: (B, Tq, D), memory: (B, Tk, D)."""
    b, tq, _ = x.shape
    tk = memory.shape[1]
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    with pscope("cross_attn"):
        with pscope("qkv"):
            q = linear(p["wq"], x).reshape(b, tq, h, dh)
            k = linear(p["wk"], memory).reshape(b, tk, kv, dh)
            v = linear(p["wv"], memory).reshape(b, tk, kv, dh)
        qh = q.transpose(0, 2, 1, 3)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        with pscope("sdpa"):
            out = _sdpa(qh, kh, vh, cfg, causal=False)
            out = quantize_here(out, "dot")
        out = out.transpose(0, 2, 1, 3).reshape(b, tq, -1)
        with pscope("out_proj"):
            return linear(p["wo"], out)
