"""Attention: GQA/MHA with RoPE, optional QKV bias, QK-norm, sliding
window; a training path (flash kernel or jnp reference) and a decode path
against a preallocated KV cache (flash-decoding style, shardable)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import census as _census
from repro.core.quantize import quantize_here
from repro.core.scope import pscope
from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.layers import init_linear, init_norm, linear, norm, rotary

NEG_INF = -1e30


def _noted(res, collect: bool):
    """Unpack a kernel result that may carry a fused census scalar and
    hand the scalar to the open census tape (``core.census``)."""
    if collect:
        out, count = res
        _census.note_count(count)
        return out
    return res


def _note_host_census(out) -> None:
    """Census fallback for paths with no kernel epilogue (the jnp scan,
    the decode einsum): the host oracle over the same stored output —
    identical contract, ``bit_census_ref(<returned tensor>)``."""
    if _census.census_active():
        from repro.kernels.ref import bit_census_ref
        _census.note_count(bit_census_ref(out))


def _sdpa_scan(q, k, v, *, causal: bool, window, block_q: int, kv_len=None,
               q_start=None, qk_bits: int = 24, pv_bits: int = 24,
               mode: str = "rne"):
    """Memory-efficient attention: lax.scan over q blocks with an
    in-scan remat body — peak temp is one (B, H, bq, Tk) logits block and
    the backward recomputes it per block (flash semantics in pure jnp;
    the Pallas kernel replaces this on real TPUs).

    q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D); queries right-aligned.
    """
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    bq = min(block_q, tq)
    pad = (-tq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nq = (tq + pad) // bq
    qb = q.reshape(b, hq, nq, bq, d).transpose(2, 0, 1, 3, 4)
    starts = jnp.arange(nq) * bq
    kg = k.reshape(b, hkv, 1, tk, d)
    vg = v.reshape(b, hkv, 1, tk, d)

    # one mask path for both layouts: right alignment == per-row offset
    # tk - tq (q_start rows carry their own cache positions). The offset
    # ignores the query padding — padded rows sit at the END of the
    # array (positions >= tk, garbage, sliced off), so real query i
    # keeps its unpadded position tk - tq + i. (The previous
    # tk - (tq + pad) offset shifted every real query left by the pad,
    # silently tightening the causal mask whenever block_q ∤ tq.)
    qs = (jnp.full((b,), tk - tq, jnp.int32) if q_start is None
          else q_start.astype(jnp.int32))

    def body(carry, xs):
        qblk, start = xs                       # (B,Hq,bq,D), scalar
        qr = qblk.reshape(b, hkv, group, bq, d)
        s = jnp.einsum("bhgqd,bhukd->bhgqk", qr.astype(jnp.float32),
                       kg.astype(jnp.float32)) * scale
        if qk_bits < 24:            # fused NEAT truncation (kernel parity)
            from repro.utils.numerics import truncate_mantissa
            s = truncate_mantissa(s, qk_bits, mode)
        qpos = qs[:, None, None] + start + jnp.arange(bq)[None, :, None]
        kpos = jnp.arange(tk)[None, None, :]
        bmask = jnp.ones((b, bq, tk), bool)
        if causal:
            bmask &= kpos <= qpos
        if window is not None:
            bmask &= kpos > qpos - window
        if kv_len is not None:      # per-row valid-KV prefix (ragged slots)
            bmask &= kpos < kv_len[:, None, None]
        s = jnp.where(bmask[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # rows with no admissible key: 0, not a uniform average (matches
        # the kernel's zero-denominator guard and the jnp oracle)
        p = jnp.where(jnp.any(bmask, -1, keepdims=True)[:, None, None],
                      p, 0.0)
        o = jnp.einsum("bhgqk,bhukd->bhgqd", p, vg.astype(jnp.float32))
        if pv_bits < 24:
            from repro.utils.numerics import truncate_mantissa
            o = truncate_mantissa(o, pv_bits, mode)
        return carry, o.reshape(b, hq, bq, d).astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(body), 0, (qb, starts))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, tq + pad, d)
    return out[:, :, :tq]


def _sdpa(q, k, v, cfg: ModelConfig, *, causal: bool, kv_len=None,
          q_start=None, qk_bits: int = 24, pv_bits: int = 24,
          mode: str = "rne"):
    backend = cfg.kernel_backend
    bits = dict(qk_bits=qk_bits, pv_bits=pv_bits, mode=mode)
    collect = _census.census_active()
    if backend in ("pallas", "interpret"):
        return _noted(kops.flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window,
            kv_len=kv_len, q_start=q_start, backend=backend,
            collect_census=collect, **bits), collect)
    tq, tk = q.shape[2], k.shape[2]
    if max(tq, tk) <= 2 * cfg.attn_block_q:
        return _noted(kops.flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window,
            kv_len=kv_len, q_start=q_start, backend="ref",
            collect_census=collect, **bits), collect)
    out = _sdpa_scan(q, k, v, causal=causal, window=cfg.sliding_window,
                     block_q=cfg.attn_block_q, kv_len=kv_len,
                     q_start=q_start, **bits)
    _note_host_census(out)
    return out


def init_attention(key, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, h * dh, dtype, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, kv * dh, dtype, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, kv * dh, dtype, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = init_norm(dh, dtype)
        p["knorm"] = init_norm(dh, dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    from repro.sharding.specs import shard_hint
    b, t, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    with pscope("qkv"):
        q = shard_hint(linear(p["wq"], x).reshape(b, t, h, dh), "heads")
        k = shard_hint(linear(p["wk"], x).reshape(b, t, kv, dh), "heads")
        v = shard_hint(linear(p["wv"], x).reshape(b, t, kv, dh), "heads")
    if cfg.qk_norm:
        q = norm(p["qnorm"], q)
        k = norm(p["knorm"], k)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    return q, k, v


def attention(p, x, cfg: ModelConfig, *, causal: bool = True,
              positions: Optional[jnp.ndarray] = None,
              kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill). x: (B, T, D).

    ``kv_len`` ((B,) int32) optionally limits each row's attention to its
    first ``kv_len[b]`` keys — the ragged-slot mask used when prompts of
    different lengths are prefilled left-aligned in one batch. Rows must
    not query beyond their own valid prefix.
    """
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t)
    with pscope("attn"):
        q, k, v = _project_qkv(p, x, cfg, positions)
        qh = q.transpose(0, 2, 1, 3)   # (B, H, T, Dh)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        with pscope("sdpa"):
            out = _sdpa(qh, kh, vh, cfg, causal=causal, kv_len=kv_len)
            out = quantize_here(out, "dot")
        out = out.transpose(0, 2, 1, 3).reshape(b, t, -1)
        with pscope("out_proj"):
            return linear(p["wo"], out)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None, dtype=None):
    """Preallocated cache: one (B, S, KV, Dh) K/V pair per layer, plus a
    per-slot position vector (B,) — each slot advances at its own pace so
    a finished slot can be reset and refilled mid-flight."""
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    dt = dtype or cfg.compute_dtype
    n = n_layers if n_layers is not None else cfg.n_layers
    layer = lambda: {
        "k": jnp.zeros((batch, max_len, kv, dh), dt),
        "v": jnp.zeros((batch, max_len, kv, dh), dt),
    }
    return {"layers": [layer() for _ in range(n)],
            "pos": jnp.zeros((batch,), jnp.int32)}


def max_pages_for(max_len: int, page_size: int) -> int:
    """Block-table width: logical pages covering one slot's ``max_len``
    ceiling. Callers should pick ``page_size | max_len`` so the logical
    capacity equals the contiguous layout's S axis exactly (keeps
    paged-vs-contiguous attention reductions over the same masked
    length)."""
    return -(-max_len // page_size)


def init_paged_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                        page_size: int, num_pages: int,
                        n_layers: Optional[int] = None, dtype=None):
    """Paged cache: one shared ``(num_pages, page_size, KV, Dh)`` K/V
    *pool* per layer plus a ``(B, max_pages)`` block table mapping each
    slot's logical prefix onto physical pages (one table serves every
    layer — page ids index each layer's pool identically, the vLLM
    layout). Unallocated table entries hold the sentinel ``num_pages``:
    any write routed through them lands out of bounds and is dropped,
    and reads are clamped+masked, so a slot without pages can never
    touch pool memory. Total resident KV is ``num_pages * page_size``
    tokens — set by the *pool*, not ``B * max_len``."""
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    dt = dtype or cfg.compute_dtype
    n = n_layers if n_layers is not None else cfg.n_layers
    layer = lambda: {
        "k": jnp.zeros((num_pages, page_size, kv, dh), dt),
        "v": jnp.zeros((num_pages, page_size, kv, dh), dt),
    }
    return {"layers": [layer() for _ in range(n)],
            "block_tables": jnp.full(
                (batch, max_pages_for(max_len, page_size)), num_pages,
                jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32)}


def is_paged(cache) -> bool:
    """A cache dict is paged iff it carries a block table."""
    return isinstance(cache, dict) and "block_tables" in cache


def paged_write(pool: jnp.ndarray, tables: jnp.ndarray,
                positions: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Scatter ``rows[i]`` into ``pool`` at logical position
    ``positions[i]`` of the slot whose block-table row is
    ``tables[i]``. pool: (P, ps, ...); tables: (N, max_pages) int32;
    positions: (N,) int32; rows: (N, ...). Writes through sentinel
    table entries (or positions past the table) index out of bounds and
    are dropped — never clamped onto live entries."""
    num_pages, ps = pool.shape[0], pool.shape[1]
    n, max_pages = tables.shape
    slot_pages = jnp.clip(positions // ps, 0, max_pages - 1)
    page = jnp.take_along_axis(tables, slot_pages[:, None], axis=1)[:, 0]
    # sentinel pages (>= num_pages) push the flat index past the pool
    flat = page * ps + positions % ps
    flat = jnp.where(positions // ps < max_pages, flat,
                     num_pages * ps)
    pooled = pool.reshape((num_pages * ps,) + pool.shape[2:])
    pooled = pooled.at[flat].set(rows.astype(pool.dtype), mode="drop")
    return pooled.reshape(pool.shape)


def slot_mask(mask: jnp.ndarray, ndim: int, axis: int = 0) -> jnp.ndarray:
    """Reshape a (B,) bool mask for broadcasting against a leaf whose
    batch axis sits at ``axis`` of an ``ndim``-rank array."""
    shape = [1] * ndim
    shape[axis] = mask.shape[0]
    return mask.reshape(shape)


def reset_kv_cache(cache, mask: jnp.ndarray):
    """Zero the KV entries and position of the slots selected by the (B,)
    bool ``mask``; other slots are untouched. Per-slot masking already
    hides entries beyond ``pos``, so this is defense in depth — a recycled
    slot can never attend to its predecessor's keys even if the zeroing
    were skipped.

    Paged caches reset the slot's *block-table row* to the sentinel and
    its position to zero instead: the pool is shared, so page contents
    are left for the allocator to recycle — a slot whose table is
    sentinel-filled can neither read nor write any page, which is the
    same isolation guarantee by construction."""
    if is_paged(cache):
        num_pages = cache["layers"][0]["k"].shape[0]
        bt = jnp.where(mask[:, None], num_pages, cache["block_tables"])
        return {"layers": cache["layers"], "block_tables": bt,
                "pos": jnp.where(mask, 0, cache["pos"])}
    layers = [{"k": jnp.where(slot_mask(mask, lc["k"].ndim), 0, lc["k"]),
               "v": jnp.where(slot_mask(mask, lc["v"].ndim), 0, lc["v"])}
              for lc in cache["layers"]]
    return {"layers": layers, "pos": jnp.where(mask, 0, cache["pos"])}


def snapshot_kv_slot(cache, s: int, live: int, pages):
    """Gather slot ``s``'s KV to a host-side pytree (preemption swap-out).

    Paged layouts gather the slot's content ``pages`` out of every layer
    pool (block-table-resolved page ids → ``(n, ps, KV, Dh)`` per
    layer). Contiguous layouts copy the slot's full cache row — entries
    past ``live`` are junk the per-slot ``kv_len``/causal masks already
    hide, so restoring them verbatim is harmless and needs no slicing
    bookkeeping. Handles both layer layouts: list of per-layer dicts and
    the ``scan_layers`` stacked dict (leading L axis)."""
    lyr = cache["layers"]
    if is_paged(cache):
        idx = jnp.asarray(list(pages), jnp.int32)
        if isinstance(lyr, dict):       # stacked: (L, P, ps, KV, Dh)
            snap = {k: v[:, idx] for k, v in lyr.items()}
        else:                           # list of (P, ps, KV, Dh) pools
            snap = [{k: v[idx] for k, v in lc.items()} for lc in lyr]
    else:
        if isinstance(lyr, dict):       # stacked: (L, B, S, KV, Dh)
            snap = {k: v[:, s] for k, v in lyr.items()}
        else:                           # list of (B, S, KV, Dh)
            snap = [{k: v[s] for k, v in lc.items()} for lc in lyr]
    return jax.device_get(snap)


def restore_kv_slot(cache, s: int, live: int, pages, snap):
    """Write a :func:`snapshot_kv_slot` payload back (preemption
    swap-in): paged layouts scatter into the slot's *new* page ids
    (``pages`` — same count, possibly different physical pages),
    contiguous ones overwrite the slot's row; either way the slot's
    position is set to ``live``. Eager (un-jitted) ops — swaps are rare
    and off the steady-state step path."""
    cache = dict(cache)
    lyr = cache["layers"]
    if is_paged(cache):
        idx = jnp.asarray(list(pages), jnp.int32)
        if isinstance(lyr, dict):
            lyr = {k: v.at[:, idx].set(jnp.asarray(snap[k], v.dtype))
                   for k, v in lyr.items()}
        else:
            lyr = [{k: v.at[idx].set(jnp.asarray(sl[k], v.dtype))
                    for k, v in lc.items()}
                   for lc, sl in zip(lyr, snap)]
    else:
        if isinstance(lyr, dict):
            lyr = {k: v.at[:, s].set(jnp.asarray(snap[k], v.dtype))
                   for k, v in lyr.items()}
        else:
            lyr = [{k: v.at[s].set(jnp.asarray(sl[k], v.dtype))
                    for k, v in lc.items()}
                   for lc, sl in zip(lyr, snap)]
    cache["layers"] = lyr
    cache["pos"] = cache["pos"].at[s].set(live)
    return cache


def _broadcast_pos(pos, batch: int) -> jnp.ndarray:
    """Accept scalar (lockstep) or (B,) per-slot positions."""
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(jnp.atleast_1d(pos), (batch,))


def decode_attention(p, x, cfg: ModelConfig, layer_cache, pos,
                     block_tables=None) -> Tuple[jnp.ndarray, dict]:
    """Single-token decode. x: (B, 1, D); cache k/v: (B, S, KV, Dh)
    contiguous strips, or — when ``block_tables`` ((B, max_pages) int32)
    is given — (num_pages, page_size, KV, Dh) shared pools; pos: (B,)
    int32 per-slot write positions (a scalar broadcasts, which advances
    every slot in lockstep — the legacy wave behavior).

    Each slot writes its K/V at its own position and is masked causally
    against its own length, so slots at different phases (prefill vs.
    decode vs. freshly reset) coexist in one compiled step. The paged
    path scatters through the block table (sentinel rows drop the
    write) and, on kernel backends, streams pages through the paged
    flash kernel (no gather materialization); the CPU path gathers the
    logical prefix and runs the same einsum as the contiguous layout —
    when ``max_pages * page_size`` equals the contiguous S the masked
    reduction runs over the same length, so both layouts agree. The
    score/value contractions reduce over the cache's S axis, so under a
    sequence-sharded cache GSPMD emits the flash-decoding partial-softmax
    all-reduce automatically.
    """
    b, t, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    with pscope("attn"):
        pos = _broadcast_pos(pos, b)
        positions = pos[:, None]                      # (B, 1) RoPE phases
        q, k, v = _project_qkv(p, x, cfg, positions)
        if block_tables is not None:
            ck = paged_write(layer_cache["k"], block_tables, pos, k[:, 0])
            cv = paged_write(layer_cache["v"], block_tables, pos, v[:, 0])
            if cfg.kernel_backend in ("pallas", "interpret"):
                # page-streaming decode: one (B, H, 1, D) query against
                # the slot's prefix, causal mask == s_idx <= pos
                qh4 = q.transpose(0, 2, 1, 3)
                with pscope("sdpa"):
                    qk_bits, pv_bits, mode = _ambient_dot_bits()
                    out = _sdpa_paged(qh4, ck, cv, block_tables, cfg,
                                      kv_len=pos + 1, q_start=pos,
                                      qk_bits=qk_bits, pv_bits=pv_bits,
                                      mode=mode)
                    out = quantize_here(out, "dot")
                out = out.transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
                with pscope("out_proj"):
                    y = linear(p["wo"], out)
                return y, {"k": ck, "v": cv}
            from repro.kernels.ref import gather_pages
            kk = gather_pages(ck, block_tables)       # (B, S_log, KV, Dh)
            vv = gather_pages(cv, block_tables)
        else:
            upd = lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
                c, u, i, axis=0)
            ck = jax.vmap(upd)(layer_cache["k"],
                               k.astype(layer_cache["k"].dtype), pos)
            cv = jax.vmap(upd)(layer_cache["v"],
                               v.astype(layer_cache["v"].dtype), pos)
            kk, vv = ck, cv
        group = h // kv
        qh = q.reshape(b, kv, group, dh)              # t == 1
        with pscope("sdpa"):
            scores = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                                kk.astype(jnp.float32)) / jnp.sqrt(
                                    jnp.float32(dh))
            scores = quantize_here(scores, "dot")
            s_idx = jnp.arange(kk.shape[1])
            valid = s_idx[None, :] <= pos[:, None]    # (B, S) per-slot causal
            if cfg.sliding_window is not None:
                valid &= s_idx[None, :] > pos[:, None] - cfg.sliding_window
            scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
            w = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bkgs,bskd->bkgd", w, vv.astype(jnp.float32))
            out = quantize_here(out, "dot").astype(x.dtype)
            _note_host_census(out)
        out = out.reshape(b, 1, h * dh)
        with pscope("out_proj"):
            y = linear(p["wo"], out)
    return y, {"k": ck, "v": cv}


def _ambient_dot_bits() -> Tuple[int, int, str]:
    """Resolve the active NEAT rule at the current scope stack to the
    flash kernel's fused ``(qk_bits, pv_bits, mode)``. The decode path
    enforces the rule with an explicit ``quantize_here(scores, "dot")``
    before its softmax; the chunked path fuses its softmax inside the
    kernel, so the same truncation must ride the kernel's NEAT hooks —
    otherwise chunked prefill and streaming decode diverge under a
    reduced-precision serving rule. Identity (24 bits) with no rule.

    The speculative drafter (``serve.engine``) resolves here too: it
    traces ``decode_step`` under ``use_rule(WholeProgram(MantissaTrunc))``
    so its qk/pv truncation lands in this hook, while verification traces
    with no ambient rule and stays exact — one code path, two
    precisions."""
    from repro.core.quantize import active_rule
    from repro.core.scope import current_stack
    rule = active_rule()
    if rule is None:
        return 24, 24, "rne"
    fpi = rule.select(current_stack(), "dot", jnp.dtype(jnp.float32))
    bits = min(int(fpi.mantissa_bits(jnp.dtype(jnp.float32))), 24)
    return bits, bits, getattr(fpi, "mode", "rne")


def prefill_attention(p, x, cfg: ModelConfig, layer_cache, pos, n_new
                      ) -> Tuple[jnp.ndarray, dict]:
    """Chunked prefill: ingest a multi-token chunk per slot. x: (B, C, D);
    cache k/v: (B, S, KV, Dh); pos: (B,) int32 per-slot write starts;
    n_new: (B,) int32 valid tokens per slot (1 <= n_new <= C).

    Writes each slot's first ``n_new[b]`` K/V rows at positions
    ``pos[b] .. pos[b]+n_new[b]-1`` (columns beyond ``n_new`` scatter out
    of bounds and are dropped, so the cache only ever holds ingested
    tokens and a near-``max_len`` write cannot clamp onto earlier
    entries), gives column i the RoPE phase ``pos[b]+i``, and attends the
    whole chunk causally against the slot's cache prefix through the
    flash kernel's ``q_start``/``kv_len`` path. Output columns at or
    beyond ``n_new[b]`` are garbage (their K/V never lands in the cache,
    so the garbage stays column-local); callers read column
    ``n_new[b]-1``. The single-token decode path is unchanged —
    ``prefill_attention(..., n_new=1)`` matches ``decode_attention`` up
    to kernel-vs-einsum float reordering.
    """
    b, c, _ = x.shape
    with pscope("attn"):
        pos = _broadcast_pos(pos, b)
        n_new = _broadcast_pos(n_new, b)
        offs = jnp.arange(c, dtype=jnp.int32)
        positions = pos[:, None] + offs[None, :]          # (B, C) phases
        q, k, v = _project_qkv(p, x, cfg, positions)
        s_len = layer_cache["k"].shape[1]
        idx = jnp.where(offs[None, :] < n_new[:, None],
                        pos[:, None] + offs[None, :], s_len)
        write = lambda cb, u, i: cb.at[i].set(u, mode="drop")
        ck = jax.vmap(write)(layer_cache["k"],
                             k.astype(layer_cache["k"].dtype), idx)
        cv = jax.vmap(write)(layer_cache["v"],
                             v.astype(layer_cache["v"].dtype), idx)
        qh = q.transpose(0, 2, 1, 3)                      # (B, H, C, Dh)
        kh = ck.transpose(0, 2, 1, 3)                     # (B, KV, S, Dh)
        vh = cv.transpose(0, 2, 1, 3)
        with pscope("sdpa"):
            qk_bits, pv_bits, mode = _ambient_dot_bits()
            out = _sdpa(qh, kh, vh, cfg, causal=True,
                        kv_len=pos + n_new, q_start=pos,
                        qk_bits=qk_bits, pv_bits=pv_bits, mode=mode)
            out = quantize_here(out, "dot")
        out = out.transpose(0, 2, 1, 3).reshape(b, c, -1)
        with pscope("out_proj"):
            y = linear(p["wo"], out)
    return y, {"k": ck, "v": cv}


def _sdpa_paged(q, k_pool, v_pool, tables, cfg: ModelConfig, *, kv_len,
                q_start, qk_bits: int = 24, pv_bits: int = 24,
                mode: str = "rne"):
    """Backend dispatch for paged attention. q: (N, Hq, Tq, D);
    pools: (num_pages, page_size, KV, Dh); tables: (N, max_pages).
    Kernel backends stream pages through the block-table scalar-prefetch
    path; the CPU fallbacks gather each row's logical prefix
    (``kernels.ref.gather_pages``) and reuse the contiguous
    oracle / ``_sdpa_scan`` with the same ``kv_len``/``q_start``
    contract."""
    backend = cfg.kernel_backend
    bits = dict(qk_bits=qk_bits, pv_bits=pv_bits, mode=mode)
    if backend in ("pallas", "interpret"):
        collect = _census.census_active()
        return _noted(kops.paged_flash_attention(
            q, k_pool, v_pool, tables, causal=True,
            window=cfg.sliding_window, kv_len=kv_len, q_start=q_start,
            pages_per_block=cfg.pages_per_block, backend=backend,
            collect_census=collect, **bits), collect)
    # the gather fallback delegates to _sdpa, which notes the census
    from repro.kernels.ref import gather_pages
    kk = gather_pages(k_pool, tables).transpose(0, 2, 1, 3)
    vv = gather_pages(v_pool, tables).transpose(0, 2, 1, 3)
    return _sdpa(q, kk, vv, cfg, causal=True, kv_len=kv_len,
                 q_start=q_start, **bits)


def packed_attention(p, x, cfg: ModelConfig, layer_cache, block_tables,
                     slot, qpos) -> Tuple[jnp.ndarray, dict]:
    """Ragged packed prefill: one (ΣC,) token stream instead of a
    (B, C) rectangle. x: (1, T, D) packed hidden states; cache k/v:
    (num_pages, page_size, KV, Dh) pools; block_tables: (B, max_pages);
    slot: (T,) int32 owning slot per packed row (== B marks a padding
    row); qpos: (T,) int32 absolute cache position per row.

    Row i gets the RoPE phase ``qpos[i]``, writes its K/V through slot
    ``slot[i]``'s block table at logical position ``qpos[i]`` (padding
    rows and sentinel pages index out of bounds and are dropped), and
    attends causally over its own slot's logical prefix — each packed
    row is a batch row of the paged kernel with ``q_start = qpos`` and
    ``kv_len = qpos + 1`` (0 for padding rows, which therefore return
    zeros). Because the whole chunk's K/V is scattered before the
    attention call, later rows of a slot see earlier rows of the same
    step, exactly like the rectangle path. Padding rows' outputs are
    garbage but row-local; callers gather per-slot last-row logits.
    """
    _, t, _ = x.shape
    b = block_tables.shape[0]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    page_size = layer_cache["k"].shape[1]
    max_pages = block_tables.shape[1]
    with pscope("attn"):
        slot = slot.astype(jnp.int32)
        qpos = qpos.astype(jnp.int32)
        valid = slot < b
        positions = qpos[None, :]                     # (1, T) RoPE phases
        q, k, v = _project_qkv(p, x, cfg, positions)  # (1, T, H/KV, Dh)
        rows_tbl = block_tables[jnp.clip(slot, 0, b - 1)]  # (T, max_pages)
        wpos = jnp.where(valid, qpos, max_pages * page_size)  # pad -> OOB
        ck = paged_write(layer_cache["k"], rows_tbl, wpos, k[0])
        cv = paged_write(layer_cache["v"], rows_tbl, wpos, v[0])
        qh = q[0][:, :, None, :]                      # (T, H, 1, Dh)
        kv_len = jnp.where(valid, qpos + 1, 0)
        with pscope("sdpa"):
            qk_bits, pv_bits, mode = _ambient_dot_bits()
            out = _sdpa_paged(qh, ck, cv, rows_tbl, cfg, kv_len=kv_len,
                              q_start=qpos, qk_bits=qk_bits,
                              pv_bits=pv_bits, mode=mode)
            out = quantize_here(out, "dot")
        out = out[:, :, 0, :].reshape(1, t, h * dh)
        with pscope("out_proj"):
            y = linear(p["wo"], out)
    return y, {"k": ck, "v": cv}


def cross_attention(p, x, memory, cfg: ModelConfig) -> jnp.ndarray:
    """Encoder-decoder cross attention. x: (B, Tq, D), memory: (B, Tk, D)."""
    b, tq, _ = x.shape
    tk = memory.shape[1]
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    with pscope("cross_attn"):
        with pscope("qkv"):
            q = linear(p["wq"], x).reshape(b, tq, h, dh)
            k = linear(p["wk"], memory).reshape(b, tk, kv, dh)
            v = linear(p["wv"], memory).reshape(b, tk, kv, dh)
        qh = q.transpose(0, 2, 1, 3)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        with pscope("sdpa"):
            out = _sdpa(qh, kh, vh, cfg, causal=False)
            out = quantize_here(out, "dot")
        out = out.transpose(0, 2, 1, 3).reshape(b, tq, -1)
        with pscope("out_proj"):
            return linear(p["wo"], out)
