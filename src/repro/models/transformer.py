"""Decoder-only transformer LM — covers the dense, MoE and early-fusion
VLM (Chameleon-style: image tokens are ordinary vocabulary entries)
architectures. Pure-function params; every block under a ``pscope`` so
NEAT placement rules address layers exactly like the paper addresses
functions."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import census as _census
from repro.core.scope import pscope, tag_phase
from repro.sharding.specs import shard_activations
from repro.models import attention as attn_mod
from repro.models.config import ModelConfig
from repro.models.layers import (cross_entropy, embedding, init_embedding,
                                 init_linear, init_mlp, init_norm, mlp, norm,
                                 unembed, maybe_remat)
from repro.models.moe import init_moe, moe_ffn, load_balance_loss


def _init_layer(lk, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(lk, 2)
    layer = {
        "attn_norm": init_norm(cfg.d_model, dtype, cfg.norm),
        "attn": attn_mod.init_attention(ks[0], cfg),
        "ffn_norm": init_norm(cfg.d_model, dtype, cfg.norm),
    }
    if cfg.family == "moe":
        layer["moe"] = init_moe(ks[1], cfg)
    else:
        layer["mlp"] = init_mlp(ks[1], cfg)
    return layer


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    params = {"embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                      dtype)}
    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    if cfg.scan_layers:
        # stacked leaves (L, ...) — the lax.scan layout
        params["layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg))(layer_keys)
    else:
        params["layers"] = [_init_layer(k, cfg) for k in layer_keys]
    params["final_norm"] = init_norm(cfg.d_model, dtype, cfg.norm)
    if not cfg.tie_embeddings:
        params["head"] = init_linear(ks[-1], cfg.d_model, cfg.vocab_size,
                                     dtype)
    return params


def _block(layer, x, cfg: ModelConfig, i: int, *, moe_impl: str):
    with pscope(f"layer{i:02d}"):
        h = norm(layer["attn_norm"], x, cfg.norm)
        x = x + attn_mod.attention(layer["attn"], h, cfg)
        x = shard_activations(x)
        h = norm(layer["ffn_norm"], x, cfg.norm)
        if cfg.family == "moe":
            x = x + moe_ffn(layer["moe"], h, cfg, impl=moe_impl)
        else:
            x = x + mlp(layer["mlp"], h, cfg)
        x = shard_activations(x)
    return x


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            *, moe_impl: str | None = None) -> jnp.ndarray:
    """tokens: (B, T) int32 -> logits (B, T, V) fp32."""
    moe_impl = moe_impl or cfg.moe_impl
    with pscope("model"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        x = shard_activations(x)
        if cfg.scan_layers:
            def body(y, layer):
                fn = maybe_remat(
                    lambda l, yy: _block(l, yy, cfg, 0, moe_impl=moe_impl),
                    cfg)
                return fn(layer, y), None
            x, _ = jax.lax.scan(body, x, params["layers"])
        else:
            for i, layer in enumerate(params["layers"]):
                fn = maybe_remat(
                    lambda l, y, _i=i: _block(l, y, cfg, _i,
                                              moe_impl=moe_impl), cfg)
                x = fn(layer, x)
        x = norm(params["final_norm"], x, cfg.norm)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        return unembed(head, x, cfg.tie_embeddings)


def loss_fn(params, batch, cfg: ModelConfig, *,
            moe_impl: str | None = None,
            aux_weight: float = 0.01) -> Tuple[jnp.ndarray, dict]:
    moe_impl = moe_impl or cfg.moe_impl
    logits = forward(params, batch["tokens"], cfg, moe_impl=moe_impl)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    metrics = {"ce": loss}
    if cfg.family == "moe" and aux_weight:
        x = embedding(params["embed"], batch["tokens"], cfg.compute_dtype)
        layer0 = (jax.tree.map(lambda v: v[0], params["layers"])
                  if cfg.scan_layers else params["layers"][0])
        aux = load_balance_loss(layer0["moe"], x, cfg)
        loss = loss + aux_weight * aux
        metrics["aux"] = aux
    return loss, metrics


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.scan_layers:
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        dt = cfg.compute_dtype
        return {"layers": {
                    "k": jnp.zeros((cfg.n_layers, batch, max_len, kv, dh),
                                   dt),
                    "v": jnp.zeros((cfg.n_layers, batch, max_len, kv, dh),
                                   dt)},
                "pos": jnp.zeros((batch,), jnp.int32)}
    return attn_mod.init_kv_cache(cfg, batch, max_len)


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     page_size: int, num_pages: int):
    """Paged cache: per-layer (num_pages, page_size, KV, Dh) pools +
    one (B, max_pages) block table shared by every layer."""
    if cfg.scan_layers:
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        dt = cfg.compute_dtype
        mp = attn_mod.max_pages_for(max_len, page_size)
        return {"layers": {
                    "k": jnp.zeros((cfg.n_layers, num_pages, page_size,
                                    kv, dh), dt),
                    "v": jnp.zeros((cfg.n_layers, num_pages, page_size,
                                    kv, dh), dt)},
                "block_tables": jnp.full((batch, mp), num_pages,
                                         jnp.int32),
                "pos": jnp.zeros((batch,), jnp.int32)}
    return attn_mod.init_paged_kv_cache(cfg, batch, max_len, page_size,
                                        num_pages)


def reset_slots(cfg: ModelConfig, cache, mask):
    """Zero the KV entries + position of the (B,) bool-masked slots so a
    retired slot can be refilled with a new request mid-flight. Paged
    caches point the masked slots' block-table rows at the sentinel
    instead — the shared pool is never touched (isolation holds because
    a sentinel table can neither read nor write any page)."""
    if attn_mod.is_paged(cache):
        layers = cache["layers"]
        num_pages = (layers["k"].shape[1] if cfg.scan_layers
                     else layers[0]["k"].shape[0])
        bt = jnp.where(mask[:, None], num_pages, cache["block_tables"])
        return {"layers": layers, "block_tables": bt,
                "pos": jnp.where(mask, 0, cache["pos"])}
    if cfg.scan_layers:   # stacked leaves (L, B, S, KV, Dh): batch axis 1
        layers = {n: jnp.where(attn_mod.slot_mask(mask, x.ndim, axis=1),
                               0, x)
                  for n, x in cache["layers"].items()}
        return {"layers": layers, "pos": jnp.where(mask, 0, cache["pos"])}
    return attn_mod.reset_kv_cache(cache, mask)


def snapshot_slot(cfg: ModelConfig, cache, s: int, live: int, pages):
    """Preemption swap-out: gather slot ``s``'s KV to host (the generic
    helper handles list / scan-stacked and paged / contiguous forms)."""
    return attn_mod.snapshot_kv_slot(cache, s, live, pages)


def restore_slot(cfg: ModelConfig, cache, s: int, live: int, pages, snap):
    """Preemption swap-in: write the snapshot back into the slot's new
    pages (or cache row) and set its position to ``live``."""
    return attn_mod.restore_kv_slot(cache, s, live, pages, snap)


def _decode_block(layer, lc, x, pos, cfg: ModelConfig, i: int,
                  moe_impl: str, block_tables=None):
    with pscope(f"layer{i:02d}" if not cfg.scan_layers else "layer"):
        h = norm(layer["attn_norm"], x, cfg.norm)
        y, new_lc = attn_mod.decode_attention(layer["attn"], h, cfg, lc,
                                              pos,
                                              block_tables=block_tables)
        x = x + y
        h = norm(layer["ffn_norm"], x, cfg.norm)
        if cfg.family == "moe":
            x = x + moe_ffn(layer["moe"], h, cfg, impl=moe_impl)
        else:
            x = x + mlp(layer["mlp"], h, cfg)
    return x, new_lc


def _prefill_block(layer, lc, x, pos, n_new, cfg: ModelConfig, i: int,
                   moe_impl: str):
    with pscope(f"layer{i:02d}" if not cfg.scan_layers else "layer"):
        h = norm(layer["attn_norm"], x, cfg.norm)
        y, new_lc = attn_mod.prefill_attention(layer["attn"], h, cfg, lc,
                                               pos, n_new)
        x = x + y
        h = norm(layer["ffn_norm"], x, cfg.norm)
        if cfg.family == "moe":
            x = x + moe_ffn(layer["moe"], h, cfg, impl=moe_impl)
        else:
            x = x + mlp(layer["mlp"], h, cfg)
    return x, new_lc


def _scan_blocks(block, x, layers, caches):
    """``lax.scan`` over stacked layers, shielding the census tape:
    notes inside a scan body are inner tracers, so each iteration
    collects locally and threads its count out as a scan output; the
    fold is re-noted on the caller's tape (see ``core.census``).
    ``block(layer, lc, y) -> (y, new_lc)``."""
    active = _census.census_active()

    def body(y, xs):
        layer, lc = xs
        if active:
            (y2, new_lc), cnt = _census.collect(lambda: block(layer, lc, y))
            return y2, (new_lc, cnt)
        return block(layer, lc, y)

    x, ys = jax.lax.scan(body, x, (layers, caches))
    if active:
        ys, counts = ys
        _census.note_count(jnp.sum(counts, dtype=jnp.int32))
    return x, ys


def _chunk_logits(params, cache, tokens, n_new, cfg: ModelConfig,
                  moe_impl: str):
    """Shared (B, C)-chunk trunk: run the chunk through every layer's
    ``q_start`` prefill attention and return the **full per-column**
    logits (B, C, V) plus the written layer caches — the chunked-prefill
    entry gathers one column, the speculative verify entry reads every
    column (each draft token's greedy successor)."""
    pos = cache["pos"]
    with pscope("model"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        if cfg.scan_layers:
            x, new_layers = _scan_blocks(
                lambda layer, lc, y: _prefill_block(
                    layer, lc, y, pos, n_new, cfg, 0, moe_impl),
                x, params["layers"], cache["layers"])
        else:
            new_layers = []
            for i, layer in enumerate(params["layers"]):
                x, lc = _prefill_block(layer, cache["layers"][i], x, pos,
                                       n_new, cfg, i, moe_impl)
                new_layers.append(lc)
        x = norm(params["final_norm"], x, cfg.norm)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = unembed(head, x, cfg.tie_embeddings)
    return logits, new_layers


@tag_phase("prefill")
def prefill_chunk(params, cache, tokens: jnp.ndarray, n_new: jnp.ndarray,
                  cfg: ModelConfig, *, moe_impl: str | None = None
                  ) -> Tuple[jnp.ndarray, dict]:
    """Chunked prefill: ingest a (B, C) token chunk, each slot writing its
    first ``n_new[b]`` tokens' K/V at its own position and attending the
    chunk causally against its cache prefix (the flash kernel's
    ``q_start`` path). Returns the (B, 1, V) logits of each slot's last
    valid column and the cache advanced by ``n_new`` per slot."""
    from repro.models.prefill import broadcast_n_new, gather_last_logits
    moe_impl = moe_impl or cfg.moe_impl
    b, c = tokens.shape
    n_new = broadcast_n_new(n_new, b)
    logits, new_layers = _chunk_logits(params, cache, tokens, n_new, cfg,
                                       moe_impl)
    return (gather_last_logits(logits, n_new),
            {"layers": new_layers, "pos": cache["pos"] + n_new})


@tag_phase("verify")
def spec_verify(params, cache, tokens: jnp.ndarray, n_new: jnp.ndarray,
                draft: jnp.ndarray, spec: jnp.ndarray, cfg: ModelConfig,
                *, moe_impl: str | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """Speculative verify on a (B, C) rectangle: the target model runs
    the window's rows (current token + drafts for spec slots, ordinary
    prompt chunks for everyone else) through the same trunk as
    :func:`prefill_chunk` — no new kernel math — then accepts the
    leading greedy matches and **commits the position vector by the
    accepted advance only** (:func:`repro.models.prefill.
    spec_acceptance`). Rejected rows' K/V stays in the cache beyond the
    committed position, where the per-slot ``kv_len``/causal masks hide
    it and the next genuine ingest overwrites it verbatim — the same
    stale-but-masked self-heal the packed pool writes rely on, which is
    the entire rollback contract for attention families. Returns
    ``(greedy (B, C), n_acc (B,), cache)``."""
    from repro.models.prefill import broadcast_n_new, spec_acceptance
    moe_impl = moe_impl or cfg.moe_impl
    b, c = tokens.shape
    n_new = broadcast_n_new(n_new, b)
    logits, new_layers = _chunk_logits(params, cache, tokens, n_new, cfg,
                                       moe_impl)
    greedy, n_acc, adv = spec_acceptance(logits, draft, n_new, spec)
    return greedy, n_acc, {"layers": new_layers,
                           "pos": cache["pos"] + adv}


def _packed_block(layer, lc, x, bt, slot, qpos, cfg: ModelConfig, i: int,
                  moe_impl: str):
    with pscope(f"layer{i:02d}" if not cfg.scan_layers else "layer"):
        h = norm(layer["attn_norm"], x, cfg.norm)
        y, new_lc = attn_mod.packed_attention(layer["attn"], h, cfg, lc,
                                              bt, slot, qpos)
        x = x + y
        h = norm(layer["ffn_norm"], x, cfg.norm)
        if cfg.family == "moe":
            x = x + moe_ffn(layer["moe"], h, cfg, impl=moe_impl)
        else:
            x = x + mlp(layer["mlp"], h, cfg)
    return x, new_lc


def _packed_logits(params, cache, tokens, slot, qpos, cfg: ModelConfig,
                   moe_impl: str):
    """Shared packed-stream trunk: run the (T,) stream through every
    layer's ``packed_attention`` and return the (1, T, V) per-row logits
    plus written layer caches. The packed-prefill entry gathers each
    slot's last row; the speculative verify entry gathers each slot's
    whole window."""
    bt = cache["block_tables"]
    with pscope("model"):
        x = embedding(params["embed"], tokens[None], cfg.compute_dtype)
        if cfg.scan_layers:
            x, new_layers = _scan_blocks(
                lambda layer, lc, y: _packed_block(
                    layer, lc, y, bt, slot, qpos, cfg, 0, moe_impl),
                x, params["layers"], cache["layers"])
        else:
            new_layers = []
            for i, layer in enumerate(params["layers"]):
                x, lc = _packed_block(layer, cache["layers"][i], x, bt,
                                      slot, qpos, cfg, i, moe_impl)
                new_layers.append(lc)
        x = norm(params["final_norm"], x, cfg.norm)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = unembed(head, x, cfg.tie_embeddings)    # (1, T, V)
    return logits, new_layers


@tag_phase("prefill")
def prefill_packed(params, cache, tokens: jnp.ndarray, slot: jnp.ndarray,
                   qpos: jnp.ndarray, last: jnp.ndarray,
                   cfg: ModelConfig, *, cap: int = 0,
                   moe_impl: str | None = None
                   ) -> Tuple[jnp.ndarray, dict]:
    """Ragged packed prefill: one (ΣC,) token stream instead of a (B, C)
    rectangle. ``tokens``/``slot``/``qpos``: (T,) packed rows — row i is
    slot ``slot[i]``'s token at absolute cache position ``qpos[i]``
    (``slot == B`` marks padding rows); ``last``: (B,) index of each
    slot's final packed row this step (anything for inactive slots —
    their logits are garbage the engine ignores). The cache must be
    paged; each row writes K/V through its slot's block table and
    attends over that slot's logical prefix (``models/attention.py::
    packed_attention``). Returns the (B, 1, V) logits of each slot's
    ``last`` row and the cache with ``pos`` advanced by each slot's
    packed row count."""
    del cap                    # batched path has no per-slot rectangle
    moe_impl = moe_impl or cfg.moe_impl
    bt = cache["block_tables"]
    b = bt.shape[0]
    slot = slot.astype(jnp.int32)
    qpos = qpos.astype(jnp.int32)
    counts = jnp.zeros((b,), jnp.int32).at[slot].add(1, mode="drop")
    logits, new_layers = _packed_logits(params, cache, tokens, slot,
                                        qpos, cfg, moe_impl)
    t = tokens.shape[0]
    per_slot = logits[0][jnp.clip(last.astype(jnp.int32), 0, t - 1)]
    return (per_slot[:, None, :],
            {"layers": new_layers, "block_tables": bt,
             "pos": cache["pos"] + counts})


@tag_phase("verify")
def spec_verify_packed(params, cache, tokens: jnp.ndarray,
                       slot: jnp.ndarray, qpos: jnp.ndarray,
                       rowidx: jnp.ndarray, n_new: jnp.ndarray,
                       draft: jnp.ndarray, spec: jnp.ndarray,
                       cfg: ModelConfig, *, cap: int = 0,
                       moe_impl: str | None = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """Packed-stream speculative verify: each speculating slot's window
    (``[cur, d_1 .. d_k]``) packs as ordinary ragged rows next to the
    prefilling slots' chunks — the mixed step the engine runs while
    prompts are still streaming in. ``rowidx``: (B, C) stream index of
    each slot's window row j (``>= T`` / anything for unused columns —
    the gather clamps and acceptance masks them via ``n_new``). Drafter
    writes rode the same block tables; this call overwrites the window's
    positions with the *target's* K/V, commits ``pos`` by the accepted
    advance, and leaves the rejected tail stale-but-masked in the pool
    (the packed self-heal property — see
    ``repro.models.prefill.merge_slotwise``). Returns ``(greedy (B, C),
    n_acc (B,), cache)``."""
    del cap
    from repro.models.prefill import spec_acceptance
    moe_impl = moe_impl or cfg.moe_impl
    bt = cache["block_tables"]
    slot = slot.astype(jnp.int32)
    qpos = qpos.astype(jnp.int32)
    logits, new_layers = _packed_logits(params, cache, tokens, slot,
                                        qpos, cfg, moe_impl)
    t = tokens.shape[0]
    per = logits[0][jnp.clip(rowidx.astype(jnp.int32), 0, t - 1)]
    greedy, n_acc, adv = spec_acceptance(per, draft, n_new, spec)
    return greedy, n_acc, {"layers": new_layers, "block_tables": bt,
                           "pos": cache["pos"] + adv}


@tag_phase("decode")
def decode_step(params, cache, tokens: jnp.ndarray, cfg: ModelConfig,
                *, moe_impl: str | None = None) -> Tuple[jnp.ndarray, dict]:
    """One decode step. tokens: (B, 1) -> (logits (B, 1, V), new cache).
    ``cache["pos"]`` is the (B,) per-slot position vector; every slot
    advances by one each step. Works on contiguous and paged caches
    alike — a paged cache routes its block table into the attention."""
    moe_impl = moe_impl or cfg.moe_impl
    pos = cache["pos"]
    bt = cache.get("block_tables")
    with pscope("model"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        if cfg.scan_layers:
            x, new_layers = _scan_blocks(
                lambda layer, lc, y: _decode_block(
                    layer, lc, y, pos, cfg, 0, moe_impl, block_tables=bt),
                x, params["layers"], cache["layers"])
        else:
            new_layers = []
            for i, layer in enumerate(params["layers"]):
                x, lc = _decode_block(layer, cache["layers"][i], x, pos,
                                      cfg, i, moe_impl, block_tables=bt)
                new_layers.append(lc)
        x = norm(params["final_norm"], x, cfg.norm)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = unembed(head, x, cfg.tie_embeddings)
    out = {"layers": new_layers, "pos": pos + 1}
    if bt is not None:
        out["block_tables"] = bt
    return logits, out


def decode_loop(params, cache, cur, pos, left, done, key, flush,
                cfg: ModelConfig, *, n_steps: int, temperature: float,
                eos_token, max_len: int):
    """Megastep: up to ``n_steps`` fused decode steps on device.

    Contiguous and paged caches alike — the block table rides the cache
    pytree through the while carry unchanged."""
    from repro.models.decode_loop import fused_decode_loop
    return fused_decode_loop(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, cur,
        pos, left, done, key, flush, n_steps=n_steps,
        temperature=temperature, eos_token=eos_token, max_len=max_len)
