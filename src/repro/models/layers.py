"""Shared layers (pure-function style: params are dict pytrees).

Every layer runs inside a ``pscope`` and routes its outputs through
``quantize_here`` — the NEAT scope-mode enforcement points. With no active
placement rule these are identities and compile away.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import quantize_here
from repro.core.scope import pscope
from repro.models.config import ModelConfig


def maybe_remat(fn, cfg: "ModelConfig"):
    """Apply the config's activation-checkpoint policy to a block fn."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    return jax.checkpoint(fn, prevent_cse=False)


def _init_dense(key, d_in, d_out, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def init_linear(key, d_in, d_out, dtype, bias: bool = False,
                scale: Optional[float] = None):
    p = {"w": _init_dense(key, d_in, d_out, dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x, *, op_class: str = "dot"):
    y = jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return quantize_here(y, op_class)


def init_norm(d, dtype, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm(p, x, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rotary(x: jnp.ndarray, positions: jnp.ndarray,
           theta: float) -> jnp.ndarray:
    """RoPE. x: (..., T, H, Dh); positions: (..., T) or (T,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]      # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


def init_embedding(key, vocab, d, dtype):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embedding(p, tokens, compute_dtype):
    with pscope("embed"):
        out = jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)
        return quantize_here(out, "dot")


def unembed(p_embed_or_head, x, tied: bool):
    with pscope("lm_head"):
        w = (p_embed_or_head["table"].T if tied
             else p_embed_or_head["w"])
        logits = jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                            w.astype(jnp.float32))
        return quantize_here(logits, "dot")


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"gate": init_linear(ks[0], d, f, dtype),
                "up": init_linear(ks[1], d, f, dtype),
                "down": init_linear(ks[2], f, d, dtype)}
    return {"up": init_linear(ks[0], d, f, dtype),
            "down": init_linear(ks[1], f, d, dtype)}


def mlp(p, x, cfg: ModelConfig):
    from repro.sharding.specs import shard_hint
    with pscope("mlp"):
        if cfg.act == "swiglu":
            g = linear(p["gate"], x)
            u = linear(p["up"], x)
            h = quantize_here(jax.nn.silu(g) * u, "mul")
        else:
            u = linear(p["up"], x)
            act = jax.nn.gelu if cfg.act == "gelu" else jax.nn.relu
            h = quantize_here(act(u), "transcendental")
        h = shard_hint(h, "hidden")     # keep the FFN tensor-parallel
        return linear(p["down"], h)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy in fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
