"""Zamba2-style hybrid: a Mamba2 backbone with ONE shared transformer
block (attention + MLP) invoked periodically — the weights are shared
across invocations (arXiv:2411.15242; we omit the per-invocation LoRA
adapters, recorded in DESIGN.md).

NEAT significance: the shared block is the paper's radar/FFT pattern at LM
scale — the same function called from many call sites. CIP must give every
invocation one FPI; FCS can assign caller-specific precision because each
invocation happens under a distinct ``pscope`` depth frame.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.scope import pscope, tag_phase
from repro.sharding.specs import shard_activations
from repro.models import attention as attn_mod
from repro.models.config import ModelConfig
from repro.models.layers import (cross_entropy, embedding, init_embedding,
                                 init_linear, init_mlp, init_norm, linear,
                                 maybe_remat, mlp, norm, unembed)
from repro.models.ssm import (init_mamba2, mamba2_forward, mamba2_init_cache,
                              mamba2_step)


def _n_shared_calls(cfg: ModelConfig) -> int:
    period = max(cfg.attn_period, 1)
    return max(1, cfg.n_layers // period)


def _init_block(k, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    return {"norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "mamba": init_mamba2(k, cfg)}


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.n_layers + 4)
    params = {"embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                      dtype)}
    if cfg.scan_layers:
        period = max(cfg.attn_period, 1)
        groups = cfg.n_layers // period
        tail = cfg.n_layers - groups * period
        gkeys = jax.random.split(ks[1], (groups, period))
        params["blocks_stacked"] = jax.vmap(jax.vmap(
            lambda k: _init_block(k, cfg)))(gkeys)
        tkeys = jax.random.split(ks[2], max(tail, 1))
        params["tail"] = [_init_block(tkeys[i], cfg) for i in range(tail)]
    else:
        params["blocks"] = [_init_block(ks[i + 1], cfg)
                            for i in range(cfg.n_layers)]
    # the single shared attention+MLP block; input is concat(hidden, embed)
    sk = jax.random.split(ks[-2], 4)
    params["shared"] = {
        "in_proj": init_linear(sk[0], 2 * cfg.d_model, cfg.d_model, dtype),
        "attn_norm": init_norm(cfg.d_model, dtype, cfg.norm),
        "attn": attn_mod.init_attention(sk[1], cfg),
        "ffn_norm": init_norm(cfg.d_model, dtype, cfg.norm),
        "mlp": init_mlp(sk[2], cfg),
    }
    params["final_norm"] = init_norm(cfg.d_model, dtype, cfg.norm)
    params["head"] = init_linear(ks[-1], cfg.d_model, cfg.vocab_size, dtype)
    return params


def _shared_block(p, x, x0, cfg: ModelConfig):
    """The weight-shared attn+MLP block (call under a caller pscope)."""
    with pscope("shared_attn"):
        h = linear(p["in_proj"], jnp.concatenate([x, x0], axis=-1))
        a = norm(p["attn_norm"], h, cfg.norm)
        h = h + attn_mod.attention(p["attn"], a, cfg)
        m = norm(p["ffn_norm"], h, cfg.norm)
        return h + mlp(p["mlp"], m, cfg)


def _layer(block, shared, x, x0, cfg: ModelConfig, i: int):
    period = max(cfg.attn_period, 1)
    with pscope(f"layer{i:02d}"):
        h = norm(block["norm"], x, cfg.norm)
        x = x + mamba2_forward(block["mamba"], h, cfg,
                               chunk=cfg.ssd_chunk)
        x = shard_activations(x)
        if (i + 1) % period == 0:
            # distinct caller frame -> FCS can specialize this call
            x = x + _shared_block(shared, x, x0, cfg)
            x = shard_activations(x)
    return x


def _mamba_block(block, x, cfg: ModelConfig):
    h = norm(block["norm"], x, cfg.norm)
    x = x + mamba2_forward(block["mamba"], h, cfg, chunk=cfg.ssd_chunk)
    return shard_activations(x)


def forward(params, tokens, cfg: ModelConfig) -> jnp.ndarray:
    with pscope("model"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        x = shard_activations(x)
        x0 = x
        if cfg.scan_layers:
            shared = params["shared"]

            def inner(y, block):
                fn = maybe_remat(lambda b, yy: _mamba_block(b, yy, cfg),
                                 cfg)
                return fn(block, y), None

            def group(carry, gblocks):
                y, y0 = carry
                y, _ = jax.lax.scan(inner, y, gblocks)
                gfn = maybe_remat(
                    lambda s, yy, yy0: _shared_block(s, yy, yy0, cfg), cfg)
                y = shard_activations(y + gfn(shared, y, y0))
                return (y, y0), None

            (x, _), _ = jax.lax.scan(group, (x, x0),
                                     params["blocks_stacked"])
            for block in params["tail"]:
                x = _mamba_block(block, x, cfg)
        else:
            for i, block in enumerate(params["blocks"]):
                fn = maybe_remat(
                    lambda b, s, y, y0, _i=i: _layer(b, s, y, y0, cfg, _i),
                    cfg)
                x = fn(block, params["shared"], x, x0)
        x = norm(params["final_norm"], x, cfg.norm)
        return unembed(params["head"], x, tied=False)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"ce": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    period = max(cfg.attn_period, 1)
    n_attn = _n_shared_calls(cfg)
    return {
        "mamba": [mamba2_init_cache(cfg, batch) for _ in range(cfg.n_layers)],
        "attn": attn_mod.init_kv_cache(cfg, batch, max_len,
                                       n_layers=n_attn),
        "pos": jnp.zeros((batch,), jnp.int32),
        "x0": jnp.zeros((batch, 1, cfg.d_model), cfg.compute_dtype),
    }


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     page_size: int, num_pages: int):
    """The Mamba backbone keeps its dense per-slot state (a recurrent
    state has no length axis to page); only the shared attention
    block's KV moves to pools + block table."""
    n_attn = _n_shared_calls(cfg)
    return {
        "mamba": [mamba2_init_cache(cfg, batch) for _ in range(cfg.n_layers)],
        "attn": attn_mod.init_paged_kv_cache(cfg, batch, max_len,
                                             page_size, num_pages,
                                             n_layers=n_attn),
        "pos": jnp.zeros((batch,), jnp.int32),
        "x0": jnp.zeros((batch, 1, cfg.d_model), cfg.compute_dtype),
    }


def reset_slots(cfg: ModelConfig, cache, mask):
    """Zero the (B,) bool-masked slots' Mamba states, attention KV and
    positions so a retired slot can serve a fresh request mid-flight."""
    batch = mask.shape[0]
    zero = lambda x: jnp.where(
        mask.reshape((batch,) + (1,) * (x.ndim - 1)), 0, x)
    return {
        "mamba": [jax.tree.map(zero, mc) for mc in cache["mamba"]],
        "attn": attn_mod.reset_kv_cache(cache["attn"], mask),
        "pos": jnp.where(mask, 0, cache["pos"]),
        "x0": zero(cache["x0"]),
    }


def snapshot_slot(cfg: ModelConfig, cache, s: int, live: int, pages):
    """Preemption swap-out: dense Mamba state + residual carry by batch
    slice, attention KV via the generic paged/contiguous gather."""
    return {
        "mamba": jax.device_get(
            jax.tree.map(lambda v: v[s], cache["mamba"])),
        "x0": jax.device_get(cache["x0"][s]),
        "attn": attn_mod.snapshot_kv_slot(cache["attn"], s, live, pages),
    }


def restore_slot(cfg: ModelConfig, cache, s: int, live: int, pages, snap):
    """Preemption swap-in: writes both the outer and the nested
    attention-core position (the attn core tracks its own ``pos``)."""
    cache = dict(cache)
    cache["mamba"] = jax.tree.map(
        lambda v, sl: v.at[s].set(jnp.asarray(sl, v.dtype)),
        cache["mamba"], snap["mamba"])
    cache["x0"] = cache["x0"].at[s].set(
        jnp.asarray(snap["x0"], cache["x0"].dtype))
    cache["attn"] = attn_mod.restore_kv_slot(cache["attn"], s, live,
                                             pages, snap["attn"])
    cache["pos"] = cache["pos"].at[s].set(live)
    return cache


@tag_phase("prefill")
def prefill_chunk(params, cache, tokens, n_new, cfg: ModelConfig):
    """Chunked prefill: the Mamba backbone is stateful per token, so the
    chunk is scanned on-device (one compiled ``lax.scan`` of the decode
    cell, per-slot ``n_new`` state masking) — the shared attention
    block's KV cache advances inside the same scan."""
    from repro.models.prefill import masked_scan_prefill
    return masked_scan_prefill(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        n_new)


@tag_phase("prefill")
def prefill_packed(params, cache, tokens, slot, qpos, last,
                   cfg: ModelConfig, *, cap: int):
    """Packed-stream prefill: the stream is unpacked into a (B, cap)
    rectangle and scanned through the decode cell (the Mamba state is
    dense; the shared attention block's paged KV advances inside the
    scan — its pool writes self-heal, see ``prefill.merge_slotwise``).
    ``qpos``/``last`` are implied by the cache's own positions and the
    per-slot counts."""
    del qpos, last
    from repro.models.prefill import packed_scan_prefill
    batch = cache["pos"].shape[0]
    return packed_scan_prefill(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        slot, batch, cap)


@tag_phase("verify")
def spec_verify(params, cache, tokens, n_new, draft, spec,
                cfg: ModelConfig):
    """Speculative verify for the hybrid stack: the decode cell scanned
    with commit-as-you-accept masking — the Mamba backbone's dense state
    is merged per accepted column (a recurrent state cannot be
    position-rewound) while the shared attention block's paged KV
    self-heals through the pool-leaf rule of ``prefill.merge_slotwise``
    exactly as in packed prefill."""
    from repro.models.prefill import spec_scan_verify
    return spec_scan_verify(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        n_new, draft, spec)


@tag_phase("verify")
def spec_verify_packed(params, cache, tokens, slot, qpos, rowidx, n_new,
                       draft, spec, cfg: ModelConfig, *, cap: int):
    """Packed-stream speculative verify: unpack into the (B, cap)
    rectangle and ride the commit-as-you-accept scan (state is dense,
    the attention block's pool writes self-heal)."""
    del qpos, rowidx
    from repro.models.prefill import packed_spec_scan_verify
    batch = cache["pos"].shape[0]
    return packed_spec_scan_verify(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        slot, batch, cap, n_new, draft, spec)


@tag_phase("decode")
def decode_step(params, cache, tokens, cfg: ModelConfig):
    period = max(cfg.attn_period, 1)
    pos = cache["pos"]
    bt = cache["attn"].get("block_tables")
    with pscope("model"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        x0 = x
        new_mamba, new_attn = [], []
        attn_i = 0
        for i, block in enumerate(params["blocks"]):
            with pscope(f"layer{i:02d}"):
                h = norm(block["norm"], x, cfg.norm)
                y, mc = mamba2_step(block["mamba"], h, cfg,
                                    cache["mamba"][i])
                x = x + y
                new_mamba.append(mc)
                if (i + 1) % period == 0:
                    with pscope("shared_attn"):
                        sp = params["shared"]
                        h2 = linear(sp["in_proj"],
                                    jnp.concatenate([x, x0], axis=-1))
                        a = norm(sp["attn_norm"], h2, cfg.norm)
                        ya, lc = attn_mod.decode_attention(
                            sp["attn"], a, cfg,
                            cache["attn"]["layers"][attn_i], pos,
                            block_tables=bt)
                        h2 = h2 + ya
                        m = norm(sp["ffn_norm"], h2, cfg.norm)
                        x = x + h2 + mlp(sp["mlp"], m, cfg)
                        new_attn.append(lc)
                        attn_i += 1
        x = norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["head"], x, tied=False)
    attn_cache = {"layers": new_attn, "pos": pos + 1}
    if bt is not None:
        attn_cache["block_tables"] = bt
    return logits, {"mamba": new_mamba, "attn": attn_cache,
                    "pos": pos + 1, "x0": cache["x0"]}


def decode_loop(params, cache, cur, pos, left, done, key, flush,
                cfg: ModelConfig, *, n_steps: int, temperature: float,
                eos_token, max_len: int):
    """Megastep: up to ``n_steps`` fused decode steps on device (both
    the mamba states and the shared-attention KV ride the carry)."""
    from repro.models.decode_loop import fused_decode_loop
    return fused_decode_loop(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, cur,
        pos, left, done, key, flush, n_steps=n_steps,
        temperature=temperature, eos_token=eos_token, max_len=max_len)
