"""LeNet-5 (paper §V-H, Table IV) in pure JAX with NEAT scopes matching
Table V's columns: Conv1, AvgPool1, Conv2, AvgPool2, Conv3, FC, Tanh,
Internal Func. Tanh activations run under their own scope (the paper
treats tanh as a separate instrumented function)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import quantize_here
from repro.core.scope import pscope


def _tanh(x):
    with pscope("tanh"):
        return quantize_here(jnp.tanh(x), "transcendental")


def _conv(p, x, stride: int = 1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return quantize_here(y + p["b"], "conv")


def _avg_pool(x, k: int = 2):
    y = jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, k, k, 1),
                              (1, k, k, 1), "VALID") / (k * k)
    return quantize_here(y, "add")


def init_lenet5(key, n_classes: int = 10):
    ks = jax.random.split(key, 5)

    def conv_p(k, kh, kw, cin, cout):
        scale = 1.0 / (kh * kw * cin) ** 0.5
        return {"w": jax.random.normal(k, (kh, kw, cin, cout)) * scale,
                "b": jnp.zeros((cout,))}

    def fc_p(k, din, dout):
        return {"w": jax.random.normal(k, (din, dout)) / din ** 0.5,
                "b": jnp.zeros((dout,))}

    return {
        "conv1": conv_p(ks[0], 5, 5, 1, 6),
        "conv2": conv_p(ks[1], 5, 5, 6, 16),
        "conv3": conv_p(ks[2], 5, 5, 16, 120),
        "fc1": fc_p(ks[3], 120, 84),
        "fc2": fc_p(ks[4], 84, n_classes),
    }


def lenet5_forward(params, images: jnp.ndarray) -> jnp.ndarray:
    """images: (B, 32, 32, 1) -> logits (B, 10). Table IV architecture."""
    x = images
    with pscope("conv1"):
        x = _conv(params["conv1"], x)          # (B,28,28,6)
    x = _tanh(x)
    with pscope("avgpool1"):
        x = _avg_pool(x)                       # (B,14,14,6)
    x = _tanh(x)
    with pscope("conv2"):
        x = _conv(params["conv2"], x)          # (B,10,10,16)
    x = _tanh(x)
    with pscope("avgpool2"):
        x = _avg_pool(x)                       # (B,5,5,16)
    x = _tanh(x)
    with pscope("conv3"):
        x = _conv(params["conv3"], x)          # (B,1,1,120)
    x = _tanh(x)
    x = x.reshape(x.shape[0], -1)
    with pscope("fc"):
        x = quantize_here(x @ params["fc1"]["w"] + params["fc1"]["b"], "dot")
    x = _tanh(x)
    with pscope("internal"):
        logits = quantize_here(
            x @ params["fc2"]["w"] + params["fc2"]["b"], "dot")
    return logits


def lenet5_loss(params, images, labels) -> jnp.ndarray:
    logits = lenet5_forward(params, images)
    with pscope("internal"):
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)


def accuracy(params, images, labels) -> jnp.ndarray:
    logits = lenet5_forward(params, images)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
