"""State-space blocks: a generic chunked linear recurrence (the Mamba-2
SSD block-decomposition algorithm) plus the Mamba2 layer built on it.

The recurrence  S_t = a_t * S_{t-1} + k_t (x) v_t,  y_t = q_t . S_t
is evaluated chunk-parallel: quadratic attention-like matmuls within
chunks (MXU-friendly), an associative scan across chunk states (log-depth,
collective-free along time when the sequence is replicated; GSPMD inserts
ppermutes when time is sharded). This is the TPU-idiomatic adaptation —
no sequential T-step scan appears in the HLO hot path.

``mlstm`` (xlstm.py) reuses the same engine: its matrix memory
C_t = f_t C_{t-1} + i_t v_t k_t^T is the identical algebra with
a = forget gate and v pre-scaled by the input gate.

Recurrent state has no position axis to mask, so speculative rollback
cannot use the attention trick of freezing ``kv_len``: the recurrent
families verify via the masked commit-as-you-accept scan in
``models.prefill.spec_scan_verify``, which folds a draft row's state
update into the carry only while the row is still accepted.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import quantize_here
from repro.core.scope import pscope
from repro.models.config import ModelConfig
from repro.models.layers import init_linear, init_norm, linear, norm


def chunked_linear_recurrence(a, k, v, q, *, chunk: int = 128):
    """y_t = q_t . S_t with S_t = a_t S_{t-1} + k_t (x) v_t.

    a: (B, T, H) decay in (0, 1]; k, q: (B, T, H, N); v: (B, T, H, P).
    Returns y: (B, T, H, P) and the final state (B, H, N, P).
    """
    b, t, h = a.shape
    n, p = k.shape[-1], v.shape[-1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad
    nc = tt // chunk
    a = a.reshape(b, nc, chunk, h)
    k = k.reshape(b, nc, chunk, h, n)
    v = v.reshape(b, nc, chunk, h, p)
    q = q.reshape(b, nc, chunk, h, n)

    la = jnp.log(jnp.maximum(a.astype(jnp.float32), 1e-20))
    cum = jnp.cumsum(la, axis=2)                       # (B,nc,Q,H)

    # --- intra-chunk (quadratic within the chunk) ---
    # L[i,j] = exp(cum_i - cum_j) for i >= j (decay from j+1..i)
    li = cum[:, :, :, None, :]                          # i
    lj = cum[:, :, None, :, :]                          # j
    tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    L = jnp.exp(li - lj) * tri[None, None, :, :, None]  # (B,nc,Q,Q,H)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * L,
                         v.astype(jnp.float32))

    # --- chunk states ---
    last = cum[:, :, -1:, :]                            # total chunk decay
    w = jnp.exp(last - cum)                             # decay j+1..end
    s_chunk = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", w,
                         k.astype(jnp.float32), v.astype(jnp.float32))
    d_chunk = jnp.exp(last[:, :, 0, :])                 # (B,nc,H)

    # --- associative scan over chunks: S'_c = d_c S'_{c-1} + S_c ---
    def combine(x, y):
        dx, sx = x
        dy, sy = y
        return dx * dy, sy + dy[..., None, None] * sx

    d_run, s_run = jax.lax.associative_scan(
        combine, (d_chunk, s_chunk), axis=1)
    # state entering chunk c = S'_{c-1}
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_run[:, :1]), s_run[:, :-1]], axis=1)

    # --- inter-chunk contribution ---
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         q.astype(jnp.float32) * jnp.exp(cum)[..., None],
                         s_prev)

    y = (y_intra + y_inter).reshape(b, tt, h, p)[:, :t]
    final_state = s_run[:, -1]                          # (B,H,N,P)
    return y, final_state


def recurrence_step(state, a_t, k_t, v_t, q_t):
    """Single decode step of the same recurrence.
    state: (B,H,N,P); a_t: (B,H); k_t,q_t: (B,H,N); v_t: (B,H,P)."""
    state = (a_t[..., None, None] * state
             + k_t[..., :, None] * v_t[..., None, :])
    y = jnp.einsum("bhn,bhnp->bhp", q_t, state)
    return y.astype(v_t.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 layer
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    heads = cfg.ssm_heads or max(1, di // 64)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    # in_proj emits [z (di), x (di), B (n), C (n), dt (heads)]
    d_in_proj = 2 * di + 2 * n + heads
    p = {
        "in_proj": init_linear(ks[0], d, d_in_proj, dtype),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * n),
                                   jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((heads,), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "out_norm": init_norm(di, dtype),
        "out_proj": init_linear(ks[2], di, d, dtype),
    }
    return p


def _causal_conv(x, w, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B,T,C), w: (K,C). With `state`
    ((B,K-1,C)) runs in streaming mode and returns the new state."""
    ksz = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (ksz - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(ksz))
    new_state = xp[:, -(ksz - 1):] if ksz > 1 else None
    return jax.nn.silu(out), new_state


def mamba2_forward(p, x, cfg: ModelConfig, *, chunk: int = 128):
    """x: (B,T,D) -> (B,T,D)."""
    b, t, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    heads = cfg.ssm_heads or max(1, di // 64)
    hp = di // heads
    with pscope("mamba"):
        with pscope("in_proj"):
            zxbcdt = linear(p["in_proj"], x)
        z, xs, bmat, cmat, dt = jnp.split(
            zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
        conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
        with pscope("conv"):
            conv_out, _ = _causal_conv(conv_in, p["conv"].astype(x.dtype))
        xs, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32)
                             + p["dt_bias"][None, None, :])   # (B,T,H)
        a = jnp.exp(-jnp.exp(p["A_log"])[None, None, :] * dt)  # decay
        xh = xs.reshape(b, t, heads, hp)
        k = jnp.broadcast_to(bmat[:, :, None, :], (b, t, heads, n))
        q = jnp.broadcast_to(cmat[:, :, None, :], (b, t, heads, n))
        v = xh.astype(jnp.float32) * dt[..., None]
        with pscope("ssd"):
            y, _ = chunked_linear_recurrence(a, k, v.astype(x.dtype), q,
                                             chunk=chunk)
            y = quantize_here(y, "dot")
        y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, t, di).astype(x.dtype)
        y = norm(p["out_norm"], y * jax.nn.silu(z))
        with pscope("out_proj"):
            return linear(p["out_proj"], y)


def mamba2_init_cache(cfg: ModelConfig, batch: int):
    di, n = cfg.d_inner, cfg.ssm_state
    heads = cfg.ssm_heads or max(1, di // 64)
    hp = di // heads
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n),
                          cfg.compute_dtype),
        "state": jnp.zeros((batch, heads, n, hp), jnp.float32),
    }


def mamba2_step(p, x, cfg: ModelConfig, cache):
    """x: (B,1,D) -> (B,1,D), new cache."""
    b, _, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    heads = cfg.ssm_heads or max(1, di // 64)
    hp = di // heads
    with pscope("mamba"):
        with pscope("in_proj"):
            zxbcdt = linear(p["in_proj"], x)
        z, xs, bmat, cmat, dt = jnp.split(
            zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
        conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
        with pscope("conv"):
            conv_out, conv_state = _causal_conv(
                conv_in, p["conv"].astype(x.dtype), cache["conv"])
        xs, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                             + p["dt_bias"][None, :])          # (B,H)
        a = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt)
        xh = xs[:, 0].reshape(b, heads, hp)
        k = jnp.broadcast_to(bmat[:, 0, None, :], (b, heads, n))
        q = jnp.broadcast_to(cmat[:, 0, None, :], (b, heads, n))
        v = xh.astype(jnp.float32) * dt[..., None]
        with pscope("ssd"):
            y, state = recurrence_step(cache["state"], a, k, v, q)
            y = quantize_here(y, "dot")
        y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, 1, di).astype(x.dtype)
        y = norm(p["out_norm"], y * jax.nn.silu(z))
        with pscope("out_proj"):
            out = linear(p["out_proj"], y)
    return out, {"conv": conv_state, "state": state}
