"""Encoder-decoder transformer (Seamless-M4T medium backbone).

Per the assignment spec the modality frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, T_src, D) — the speech conv
frontend never executes here. The transformer backbone (encoder self-attn,
decoder self+cross attn) is fully implemented.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.scope import pscope, tag_phase
from repro.sharding.specs import shard_activations
from repro.models import attention as attn_mod
from repro.models.config import ModelConfig
from repro.models.layers import (cross_entropy, embedding, init_embedding,
                                 init_linear, init_mlp, init_norm,
                                 maybe_remat, mlp, norm, unembed)


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    n_enc, n_dec = cfg.n_enc_layers, cfg.n_dec_layers
    ks = jax.random.split(key, n_enc + n_dec + 3)
    params = {"embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                      dtype)}
    params["encoder"] = []
    for i in range(n_enc):
        lk = jax.random.split(ks[1 + i], 2)
        params["encoder"].append({
            "attn_norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "attn": attn_mod.init_attention(lk[0], cfg),
            "ffn_norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "mlp": init_mlp(lk[1], cfg),
        })
    params["decoder"] = []
    for i in range(n_dec):
        lk = jax.random.split(ks[1 + n_enc + i], 3)
        params["decoder"].append({
            "attn_norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "attn": attn_mod.init_attention(lk[0], cfg),
            "cross_norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "cross": attn_mod.init_attention(lk[1], cfg),
            "ffn_norm": init_norm(cfg.d_model, dtype, cfg.norm),
            "mlp": init_mlp(lk[2], cfg),
        })
    params["final_norm"] = init_norm(cfg.d_model, dtype, cfg.norm)
    params["head"] = init_linear(ks[-1], cfg.d_model, cfg.vocab_size, dtype)
    return params


def encode(params, src_embeds: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """src_embeds: (B, T_src, D) — precomputed frontend features."""
    x = src_embeds.astype(cfg.compute_dtype)

    def _layer(layer, y, i):
        with pscope(f"enc{i:02d}"):
            h = norm(layer["attn_norm"], y, cfg.norm)
            y = y + attn_mod.attention(layer["attn"], h, cfg,
                                       causal=False)
            y = shard_activations(y)
            h = norm(layer["ffn_norm"], y, cfg.norm)
            y = y + mlp(layer["mlp"], h, cfg)
            return shard_activations(y)

    with pscope("encoder"):
        x = shard_activations(x)
        for i, layer in enumerate(params["encoder"]):
            fn = maybe_remat(lambda l, y, _i=i: _layer(l, y, _i), cfg)
            x = fn(layer, x)
    return x


def decode(params, tokens: jnp.ndarray, memory: jnp.ndarray,
           cfg: ModelConfig) -> jnp.ndarray:
    def _layer(layer, y, mem, i):
        with pscope(f"dec{i:02d}"):
            h = norm(layer["attn_norm"], y, cfg.norm)
            y = y + attn_mod.attention(layer["attn"], h, cfg)
            y = shard_activations(y)
            h = norm(layer["cross_norm"], y, cfg.norm)
            y = y + attn_mod.cross_attention(layer["cross"], h, mem, cfg)
            h = norm(layer["ffn_norm"], y, cfg.norm)
            y = y + mlp(layer["mlp"], h, cfg)
            return shard_activations(y)

    with pscope("decoder"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        x = shard_activations(x)
        for i, layer in enumerate(params["decoder"]):
            fn = maybe_remat(lambda l, y, m, _i=i: _layer(l, y, m, _i), cfg)
            x = fn(layer, x, memory)
        x = norm(params["final_norm"], x, cfg.norm)
        return unembed(params["head"], x, tied=False)


def forward(params, batch_or_tokens, cfg: ModelConfig,
            src_embeds: jnp.ndarray | None = None) -> jnp.ndarray:
    if src_embeds is None:   # batch dict
        src_embeds = batch_or_tokens["src_embeds"]
        tokens = batch_or_tokens["tokens"]
    else:
        tokens = batch_or_tokens
    with pscope("model"):
        memory = encode(params, src_embeds, cfg)
        return decode(params, tokens, memory, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"ce": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    cache = attn_mod.init_kv_cache(cfg, batch, max_len,
                                   n_layers=cfg.n_dec_layers)
    cache["memory"] = jnp.zeros((batch, 1, cfg.d_model), cfg.compute_dtype)
    return cache


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     page_size: int, num_pages: int):
    """Paged decoder self-attn KV (shared pools + per-slot block table);
    the cached encoder memory stays a per-slot dense strip."""
    cache = attn_mod.init_paged_kv_cache(cfg, batch, max_len, page_size,
                                         num_pages,
                                         n_layers=cfg.n_dec_layers)
    cache["memory"] = jnp.zeros((batch, 1, cfg.d_model), cfg.compute_dtype)
    return cache


def reset_slots(cfg: ModelConfig, cache, mask):
    """Zero the (B,) bool-masked slots' self-attn KV, position and cached
    encoder memory so a retired slot can serve a fresh request. Paged
    caches sentinel the slot's block-table row instead of zeroing KV."""
    core = {"layers": cache["layers"], "pos": cache["pos"]}
    if attn_mod.is_paged(cache):
        core["block_tables"] = cache["block_tables"]
    new = attn_mod.reset_kv_cache(core, mask)
    new["memory"] = jnp.where(
        attn_mod.slot_mask(mask, cache["memory"].ndim), 0, cache["memory"])
    return new


def snapshot_slot(cfg: ModelConfig, cache, s: int, live: int, pages):
    """Preemption swap-out: decoder self-attn KV via the generic gather
    plus the slot's cached encoder memory."""
    return {
        "core": attn_mod.snapshot_kv_slot(cache, s, live, pages),
        "memory": jax.device_get(cache["memory"][s]),
    }


def restore_slot(cfg: ModelConfig, cache, s: int, live: int, pages, snap):
    """Preemption swap-in: the generic helper rebuilds the KV/pos half
    (and preserves extra keys), then the encoder memory is re-attached."""
    cache = attn_mod.restore_kv_slot(cache, s, live, pages, snap["core"])
    cache["memory"] = cache["memory"].at[s].set(
        jnp.asarray(snap["memory"], cache["memory"].dtype))
    return cache


def _chunk_logits(params, cache, tokens, n_new, memory,
                  cfg: ModelConfig):
    """Shared (B, C)-chunk decoder trunk (self-attn via the ``q_start``
    path + cross-attn over the cached memory) returning full per-column
    logits (B, C, V) and the written layer caches."""
    pos = cache["pos"]
    with pscope("model"), pscope("decoder"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        new_layers = []
        for i, layer in enumerate(params["decoder"]):
            with pscope(f"dec{i:02d}"):
                h = norm(layer["attn_norm"], x, cfg.norm)
                y, lc = attn_mod.prefill_attention(
                    layer["attn"], h, cfg, cache["layers"][i], pos, n_new)
                x = x + y
                new_layers.append(lc)
                h = norm(layer["cross_norm"], x, cfg.norm)
                x = x + attn_mod.cross_attention(layer["cross"], h, memory,
                                                 cfg)
                h = norm(layer["ffn_norm"], x, cfg.norm)
                x = x + mlp(layer["mlp"], h, cfg)
        x = norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["head"], x, tied=False)
    return logits, new_layers


@tag_phase("prefill")
def prefill_chunk(params, cache, tokens, n_new, cfg: ModelConfig,
                  memory: jnp.ndarray | None = None):
    """Chunked decoder prefill: the (B, C) chunk runs batched through
    each decoder layer — self-attention against the slot's KV prefix via
    the flash kernel's ``q_start`` path, cross-attention over the cached
    encoder memory. Returns each slot's last-valid-column logits and the
    cache advanced by ``n_new`` per slot."""
    from repro.models.prefill import broadcast_n_new, gather_last_logits
    memory = cache["memory"] if memory is None else memory
    b, c = tokens.shape
    n_new = broadcast_n_new(n_new, b)
    logits, new_layers = _chunk_logits(params, cache, tokens, n_new,
                                       memory, cfg)
    return (gather_last_logits(logits, n_new),
            {"layers": new_layers, "pos": cache["pos"] + n_new,
             "memory": memory})


@tag_phase("verify")
def spec_verify(params, cache, tokens, n_new, draft, spec,
                cfg: ModelConfig):
    """Speculative verify on the decoder rectangle — the transformer
    contract (see ``transformer.spec_verify``) with the cached encoder
    memory carried through: position commit by accepted advance, the
    rejected tail's self-attn KV left stale-but-masked."""
    from repro.models.prefill import broadcast_n_new, spec_acceptance
    memory = cache["memory"]
    b, c = tokens.shape
    n_new = broadcast_n_new(n_new, b)
    logits, new_layers = _chunk_logits(params, cache, tokens, n_new,
                                       memory, cfg)
    greedy, n_acc, adv = spec_acceptance(logits, draft, n_new, spec)
    return greedy, n_acc, {"layers": new_layers,
                           "pos": cache["pos"] + adv, "memory": memory}


@tag_phase("prefill")
def prefill_packed(params, cache, tokens, slot, qpos, last,
                   cfg: ModelConfig, *, cap: int = 0,
                   memory: jnp.ndarray | None = None):
    """Ragged packed decoder prefill: (T,) packed rows, each attending
    its own slot's paged self-attn prefix (``packed_attention``) and
    cross-attending its slot's cached encoder memory (gathered per
    row). See ``transformer.prefill_packed`` for the row contract."""
    del cap
    memory = cache["memory"] if memory is None else memory
    bt = cache["block_tables"]
    b = bt.shape[0]
    slot = slot.astype(jnp.int32)
    qpos = qpos.astype(jnp.int32)
    counts = jnp.zeros((b,), jnp.int32).at[slot].add(1, mode="drop")
    logits, new_layers = _packed_logits(params, cache, tokens, slot,
                                        qpos, memory, cfg)
    t = tokens.shape[0]
    per_slot = logits[0][jnp.clip(last.astype(jnp.int32), 0, t - 1)]
    return (per_slot[:, None, :],
            {"layers": new_layers, "block_tables": bt,
             "pos": cache["pos"] + counts, "memory": memory})


def _packed_logits(params, cache, tokens, slot, qpos, memory,
                   cfg: ModelConfig):
    """Shared packed-stream decoder trunk: paged self-attn per row plus
    per-row cross-attn over each row's own slot's cached memory;
    returns (1, T, V) per-row logits and the written layer caches."""
    bt = cache["block_tables"]
    b = bt.shape[0]
    mem_rows = memory[jnp.clip(slot, 0, b - 1)]      # (T, Tm, D)
    with pscope("model"), pscope("decoder"):
        x = embedding(params["embed"], tokens[None], cfg.compute_dtype)
        new_layers = []
        for i, layer in enumerate(params["decoder"]):
            with pscope(f"dec{i:02d}"):
                h = norm(layer["attn_norm"], x, cfg.norm)
                y, lc = attn_mod.packed_attention(
                    layer["attn"], h, cfg, cache["layers"][i], bt, slot,
                    qpos)
                x = x + y
                new_layers.append(lc)
                h = norm(layer["cross_norm"], x, cfg.norm)
                # per-row cross attention: each packed row queries its
                # own slot's memory (batch axis = packed rows, Tq = 1)
                xc = attn_mod.cross_attention(
                    layer["cross"], h[0][:, None, :], mem_rows, cfg)
                x = x + xc[:, 0][None]
                h = norm(layer["ffn_norm"], x, cfg.norm)
                x = x + mlp(layer["mlp"], h, cfg)
        x = norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["head"], x, tied=False)   # (1, T, V)
    return logits, new_layers


@tag_phase("verify")
def spec_verify_packed(params, cache, tokens, slot, qpos, rowidx, n_new,
                       draft, spec, cfg: ModelConfig, *, cap: int = 0):
    """Packed-stream speculative verify for the encoder-decoder: the
    transformer contract (``transformer.spec_verify_packed``) with the
    cached encoder memory cross-attended per packed row and carried
    through the committed cache."""
    del cap
    from repro.models.prefill import spec_acceptance
    memory = cache["memory"]
    bt = cache["block_tables"]
    slot = slot.astype(jnp.int32)
    qpos = qpos.astype(jnp.int32)
    logits, new_layers = _packed_logits(params, cache, tokens, slot,
                                        qpos, memory, cfg)
    t = tokens.shape[0]
    per = logits[0][jnp.clip(rowidx.astype(jnp.int32), 0, t - 1)]
    greedy, n_acc, adv = spec_acceptance(per, draft, n_new, spec)
    return greedy, n_acc, {"layers": new_layers, "block_tables": bt,
                           "pos": cache["pos"] + adv, "memory": memory}


@tag_phase("decode")
def decode_step(params, cache, tokens, cfg: ModelConfig,
                memory: jnp.ndarray | None = None):
    """Single-token decode against cached self-attn KV + encoder memory
    (contiguous strips or paged pools alike)."""
    memory = cache["memory"] if memory is None else memory
    pos = cache["pos"]
    bt = cache.get("block_tables")
    with pscope("model"), pscope("decoder"):
        x = embedding(params["embed"], tokens, cfg.compute_dtype)
        new_layers = []
        for i, layer in enumerate(params["decoder"]):
            with pscope(f"dec{i:02d}"):
                h = norm(layer["attn_norm"], x, cfg.norm)
                y, lc = attn_mod.decode_attention(
                    layer["attn"], h, cfg, cache["layers"][i], pos,
                    block_tables=bt)
                x = x + y
                new_layers.append(lc)
                h = norm(layer["cross_norm"], x, cfg.norm)
                x = x + attn_mod.cross_attention(layer["cross"], h, memory,
                                                 cfg)
                h = norm(layer["ffn_norm"], x, cfg.norm)
                x = x + mlp(layer["mlp"], h, cfg)
        x = norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["head"], x, tied=False)
    out = {"layers": new_layers, "pos": pos + 1, "memory": memory}
    if bt is not None:
        out["block_tables"] = bt
    return logits, out


def decode_loop(params, cache, cur, pos, left, done, key, flush,
                cfg: ModelConfig, *, n_steps: int, temperature: float,
                eos_token, max_len: int):
    """Megastep: up to ``n_steps`` fused decoder steps on device; the
    frozen encoder memory rides the cache pytree through the carry."""
    from repro.models.decode_loop import fused_decode_loop
    return fused_decode_loop(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, cur,
        pos, left, done, key, flush, n_steps=n_steps,
        temperature=temperature, eos_token=eos_token, max_len=max_len)
