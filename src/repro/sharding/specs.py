"""Partition-rule engine: path-pattern -> PartitionSpec, with divisibility
checking and graceful fallback (an axis that does not divide a dim is
dropped from that dim's spec rather than failing the lowering).

Layout strategy (Megatron TP x FSDP x DP, EP for MoE, SP for long
contexts):

* batch dims      -> dp axes ("pod","data")
* TP dims         -> "model": attention heads / FFN hidden / vocab / experts
* FSDP dim        -> the non-TP weight dim shards over dp axes
* KV caches       -> batch on dp when divisible; sequence on "model"
                     (flash-decoding reduction), plus dp when batch == 1
* optimizer state -> same as params (ZeRO-1 comes from FSDP dims)
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    dp_axes: Tuple[str, ...]          # ("pod","data") or ("data",)
    tp_axis: str = "model"
    fsdp: bool = True                 # shard weights over dp too

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    # -- spec builders --------------------------------------------------------
    def _fit(self, dim: int, axes) -> Optional[Any]:
        """Return axes if they evenly divide dim, else try prefixes, else
        None (replicated)."""
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        for end in range(len(axes), 0, -1):
            cand = axes[:end]
            if dim % self.axis_size(cand) == 0:
                return cand if len(cand) > 1 else cand[0]
        return None

    def spec(self, shape: Sequence[int], *dim_axes) -> P:
        """PartitionSpec with per-dim candidate axes, divisibility-checked."""
        assert len(shape) == len(dim_axes), (shape, dim_axes)
        return P(*[self._fit(s, a) for s, a in zip(shape, dim_axes)])

    def named(self, shape, *dim_axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, *dim_axes))


def make_rules(mesh: Mesh, *, fsdp: bool = True) -> ShardingRules:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return ShardingRules(mesh=mesh, dp_axes=dp, fsdp=fsdp)


# ---------------------------------------------------------------------------
# Activation-sharding context (Megatron-style sequence parallelism).
#
# Model code calls ``shard_activations(x)`` at residual boundaries; with an
# active context this pins (B, T, D) activations to (dp, tp, None) — the
# sequence dim shards over "model" between blocks, so the per-layer remat
# stash is 1/TP of the naive size. GSPMD inserts the all-gather before
# attention and the reduce-scatter after, exactly the Megatron-SP schedule.
# Without a context it is the identity (CPU smoke tests).
# ---------------------------------------------------------------------------

_act_tls = threading.local()


@contextlib.contextmanager
def use_activation_sharding(rules: Optional[ShardingRules],
                            *, sequence_parallel: bool = True,
                            tp_intermediates=True):
    # tp_intermediates: True -> ("hidden", "heads"); False -> ();
    # or an explicit tuple/str of hint kinds to enable.
    if tp_intermediates is True:
        kinds = ("hidden", "heads")
    elif tp_intermediates is False:
        kinds = ()
    elif isinstance(tp_intermediates, str):
        kinds = (tp_intermediates,)
    else:
        kinds = tuple(tp_intermediates)
    prev = getattr(_act_tls, "ctx", None)
    _act_tls.ctx = ((rules, sequence_parallel, kinds)
                    if rules is not None else None)
    try:
        yield
    finally:
        _act_tls.ctx = prev


def activation_rules() -> Optional[ShardingRules]:
    ctx = getattr(_act_tls, "ctx", None)
    return ctx[0] if ctx else None


def shard_activations(x, kind: str = "residual"):
    """Pin a (B, T, D) activation's sharding at a block boundary."""
    ctx = getattr(_act_tls, "ctx", None)
    if ctx is None or x.ndim != 3:
        return x
    rules, sp, _ = ctx
    t_axis = rules.tp_axis if (sp and kind == "residual") else None
    spec = rules.spec(x.shape, rules.dp_axes, t_axis, None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def shard_hint(x, kind: str):
    """Pin Megatron-TP *intermediate* activations so GSPMD keeps the
    matmuls tensor-parallel (all-reduce activations) instead of gathering
    full weights per layer:
      "hidden" — (B, T, F) FFN hidden, F on the model axis
      "heads"  — (B, T, H, Dh) attention heads, H on the model axis
    Identity without an active context or when tp_intermediates is off.
    """
    ctx = getattr(_act_tls, "ctx", None)
    if ctx is None or kind not in ctx[2]:
        return x
    rules, _, _ = ctx
    tp = rules.tp_axis
    if kind == "hidden" and x.ndim == 3:
        spec = rules.spec(x.shape, rules.dp_axes, None, tp)
    elif kind == "heads" and x.ndim == 4:
        spec = rules.spec(x.shape, rules.dp_axes, None, tp, None)
    else:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter rules by path pattern
# ---------------------------------------------------------------------------

def _param_spec(rules: ShardingRules, path: str, shape) -> P:
    """Assign a spec from the parameter's path + rank.

    Rules are written against the *trailing* dims of each pattern; any
    extra leading dims (the scan-over-layers stack, grouped stacks) are
    padded with None (replicated layer axis).
    """
    r = rules
    dp = r.dp_axes if r.fsdp else None
    tp = r.tp_axis
    ndim = len(shape)

    def trailing(*base):
        """Spec matching the last len(base) dims, None-padded in front."""
        if ndim < len(base):
            return None
        axes = [None] * (ndim - len(base)) + list(base)
        return r.spec(shape, *axes)

    # MoE expert stacks (E, D, F) / (E, F, D): experts on model (EP)
    if re.search(r"moe/(gate|up)$", path):
        s = trailing(tp, dp, None)
        if s is not None:
            return s
    if re.search(r"moe/down$", path):
        s = trailing(tp, None, dp)
        if s is not None:
            return s
    if re.search(r"router/w$", path):
        s = trailing(dp, None)
        if s is not None:
            return s

    # embeddings / lm head
    if re.search(r"embed/table$", path):
        return trailing(tp, dp) or P(*([None] * ndim))
    if re.search(r"head/w$", path):
        return trailing(dp, tp) or P(*([None] * ndim))

    # column-parallel: d_model -> expanded dim on model
    if re.search(r"(wq|wk|wv|gate|up|in_proj|wi|wf|wo_gate|wx|cross/w[qkv])"
                 r"/w$", path):
        s = trailing(dp, tp)
        if s is not None:
            return s
    # row-parallel: contracted dim on model
    if re.search(r"(wo|down|out_proj)/w$", path):
        s = trailing(tp, dp)
        if s is not None:
            return s
    # TP-expanded bias vectors
    if re.search(r"(wq|wk|wv|gate|up|in_proj|wi|wf|wo_gate|wx)/b$", path):
        return trailing(tp) or P(*([None] * ndim))

    # mamba conv (K, C): channels follow d_inner (model)
    if re.search(r"mamba.*conv$", path) or re.search(r"/conv$", path):
        s = trailing(None, tp)
        if s is not None:
            return s
    # slstm recurrent (h, dh, 4dh): heads on model
    if re.search(r"/r$", path):
        s = trailing(tp, None, None)
        if s is not None:
            return s
    # lenet-style conv kernels (KH, KW, Cin, Cout)
    if re.search(r"conv\d*/w$", path) and ndim == 4:
        return r.spec(shape, None, None, None, tp)

    if ndim <= 1:
        return P(*([None] * ndim))
    # fallback: FSDP the largest dim
    axes: list = [None] * ndim
    big = int(np.argmax(shape))
    axes[big] = dp
    return r.spec(shape, *axes)


def _tree_paths(tree):
    from repro.utils.jax_compat import tree_flatten_with_path
    flat, treedef = tree_flatten_with_path(tree)
    keys = []
    for path, leaf in flat:
        keys.append(("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path), leaf))
    return keys, treedef


def params_shardings(rules: ShardingRules, params_shape) -> Any:
    """NamedShardings mirroring an (abstract) param tree."""
    flat, treedef = _tree_paths(params_shape)
    out = []
    for path, leaf in flat:
        spec = _param_spec(rules, path, leaf.shape)
        out.append(NamedSharding(rules.mesh, spec))
    return jax.tree.unflatten(treedef, out)


def opt_state_shardings(rules: ShardingRules, opt_shape, params_shape) -> Any:
    """Adam moments mirror the param shardings; count is replicated."""
    pshard = params_shardings(rules, params_shape)
    return {
        "mu": pshard,
        "nu": pshard,
        "count": NamedSharding(rules.mesh, P()),
    }


def batch_shardings(rules: ShardingRules, batch_shape) -> Any:
    """Token batches: batch dim over dp; model-dim activations on model."""
    def spec_for(leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(rules.mesh, P())
        axes = [None] * len(shape)
        axes[0] = rules.dp_axes
        # (B, T, D) activations: leave T/D replicated (sequence stays local)
        return NamedSharding(rules.mesh,
                             rules.spec(shape, *axes))
    return jax.tree.map(spec_for, batch_shape)


def cache_shardings(rules: ShardingRules, cache_shape, batch: int) -> Any:
    """KV caches (B, S, KV, Dh) and SSM states.

    batch divisible by dp  -> B on dp, S on model (flash-decode reduce)
    batch == 1 (long ctx)  -> S over (data, model) jointly
    """
    r = rules
    dp_ok = batch % r.axis_size(r.dp_axes) == 0

    def spec_for(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return NamedSharding(r.mesh, P())
        if path.endswith("pos"):
            return NamedSharding(r.mesh, P())
        if nd == 5:   # stacked (L, B, S, KV, Dh) scan-layers cache
            if dp_ok:
                return r.named(shape, None, r.dp_axes, r.tp_axis, None,
                               None)
            return r.named(shape, None, None, r.dp_axes + (r.tp_axis,),
                           None, None)
        if nd == 4 and ("k" in path.split("/")[-1:] or
                        "v" in path.split("/")[-1:]):
            if dp_ok:
                return r.named(shape, r.dp_axes, r.tp_axis, None, None)
            return r.named(shape, None, r.dp_axes + (r.tp_axis,), None, None)
        if nd == 4:   # ssm state (B, H, N, P) / mlstm C (B, H, dh, dh)
            tp_n = r.axis_size(r.tp_axis)
            # shard heads on model when divisible, else the state row dim
            if shape[1] % tp_n == 0:
                axes = (r.tp_axis, None, None)
            elif shape[2] % tp_n == 0:
                axes = (None, r.tp_axis, None)
            else:
                axes = (None, None, r.tp_axis)
            if dp_ok:
                return r.named(shape, r.dp_axes, *axes)
            return r.named(shape, None, *axes)
        if nd == 3:   # conv state (B, K-1, C) or memory (B, 1, D)
            if dp_ok:
                return r.named(shape, r.dp_axes, None, r.tp_axis)
            return r.named(shape, None, None, r.tp_axis)
        if nd == 2:   # slstm scalar states (B, D)
            if dp_ok:
                return r.named(shape, r.dp_axes, r.tp_axis)
            return r.named(shape, None, r.tp_axis)
        axes = [None] * nd
        if dp_ok:
            axes[0] = r.dp_axes
        return r.named(shape, *axes)

    flat, treedef = _tree_paths(cache_shape)
    return jax.tree.unflatten(treedef,
                              [spec_for(p, l) for p, l in flat])
