from repro.sharding.specs import (
    ShardingRules, make_rules, params_shardings, batch_shardings,
    cache_shardings, opt_state_shardings,
)
