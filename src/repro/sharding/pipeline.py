"""GPipe-style pipeline parallelism over a mesh axis (default "pod").

At 1000+ nodes the cross-pod DCI links are far slower than in-pod ICI, so
pure DP across pods pays a full gradient all-reduce over the slow links.
The pipeline option instead places contiguous layer groups on successive
pod stages and streams microbatches with ``shard_map`` +
``lax.ppermute``: cross-pod traffic becomes one activation tensor per
microbatch boundary (B_micro x T x D) instead of the whole gradient.

This module implements the schedule generically over a user-provided
per-stage step function; it is exercised by tests and available to the
launcher via ``--pipeline``, while the default dry-run keeps pod-as-DP.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn: Callable, mesh: Mesh, *, axis: str = "pod",
                     n_microbatches: int = 4):
    """Build fn(stage_params, x) running a GPipe forward.

    ``stage_fn(stage_params, x_micro) -> y_micro`` is the per-stage
    computation; ``stage_params`` has a leading stage axis sharded over
    ``axis``; x: (B, ...) with B divisible by n_microbatches.

    Schedule: n_stages + n_micro - 1 ticks; each tick every stage
    processes one microbatch (bubble at the edges), activations hop
    stage->stage+1 via ppermute.
    """
    n_stages = mesh.shape[axis]

    def run(stage_params, x):
        def body(params_local, x_local):
            # params_local: this stage's params — shard_map keeps the
            # sharded leading axis at size 1, strip it; x_local: the full
            # local batch (only stage 0's content matters; later stages
            # receive activations via ppermute)
            params_local = jax.tree.map(lambda a: a[0], params_local)
            stage = jax.lax.axis_index(axis)
            micro = jnp.split(x_local, n_microbatches, axis=0)
            n_ticks = n_stages + n_microbatches - 1
            outs = [None] * n_microbatches
            carry = jnp.zeros_like(micro[0])
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            for t in range(n_ticks):
                mb = t  # microbatch entering stage 0 at tick t
                inject = micro[mb] if mb < n_microbatches else carry
                xin = jnp.where(stage == 0, inject, carry)
                y = stage_fn(params_local, xin)
                # last stage emits microbatch t - (n_stages - 1)
                out_idx = t - (n_stages - 1)
                if 0 <= out_idx < n_microbatches:
                    outs[out_idx] = y
                carry = jax.lax.ppermute(y, axis, perm)
            # only the last stage's outs are real; broadcast them
            # (mask + psum — ppermute cannot fan out one source)
            out = jnp.concatenate(outs, axis=0)
            out = jnp.where(stage == n_stages - 1, out,
                            jnp.zeros_like(out))
            return jax.lax.psum(out, axis)

        in_specs = (P(axis), P())          # params staged; batch replicated
        out_specs = P()
        fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        return fn(stage_params, x)

    return run
