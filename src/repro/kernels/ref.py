"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.numerics import manipulated_bits, truncate_mantissa


def mantissa_trunc_ref(x: jnp.ndarray, bits: int,
                       mode: str = "rne") -> jnp.ndarray:
    """Oracle for kernels.mantissa_trunc."""
    return truncate_mantissa(x, bits, mode)


def bit_census_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.bit_census: total manipulated mantissa bits
    (trailing-zero counting, paper §III-C) as a scalar int32."""
    if x.size == 0:
        return jnp.zeros((), jnp.int32)
    return jnp.sum(manipulated_bits(x)).astype(jnp.int32)


def quant_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, a_bits: int,
                     b_bits: int, out_bits: int,
                     mode: str = "rne") -> jnp.ndarray:
    """Oracle for kernels.quant_matmul: truncate operands, fp32-accumulate
    matmul, truncate the result."""
    aq = truncate_mantissa(a, min(a_bits, _mant(a)), mode)
    bq = truncate_mantissa(b, min(b_bits, _mant(b)), mode)
    out = jnp.dot(aq.astype(jnp.float32), bq.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return truncate_mantissa(out, min(out_bits, 24), mode)


def _mant(x) -> int:
    from repro.utils.numerics import float_spec
    return float_spec(x.dtype).mantissa_bits


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int | None = None,
                        kv_len: jnp.ndarray | None = None,
                        q_start: jnp.ndarray | None = None,
                        qk_bits: int = 24, pv_bits: int = 24,
                        mode: str = "rne") -> jnp.ndarray:
    """Oracle for kernels.flash_attention.

    q: (B, Hq, Tq, D), k/v: (B, Hkv, Tk, D) with Hq % Hkv == 0 (GQA).
    ``kv_len`` ((B,) int32) optionally limits row b to its first
    ``kv_len[b]`` keys (ragged-slot prefix mask). ``q_start`` ((B,)
    int32) optionally places row b's queries at absolute key positions
    ``q_start[b] + i`` (the chunked-prefill layout) instead of right
    alignment. Query rows whose mask admits no key return zeros,
    matching the kernel's zero-denominator guard. Optional NEAT
    truncation of the QK^T logits and the PV product.
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if qk_bits < 24:
        logits = truncate_mantissa(logits, qk_bits, mode)
    # one mask path for both layouts: right alignment is q_start=tk-tq
    tk = k.shape[2]
    qs = (jnp.full((b,), tk - tq, jnp.int32) if q_start is None
          else q_start.astype(jnp.int32))
    qpos = qs[:, None, None] + jnp.arange(tq)[None, :, None]
    kpos = jnp.arange(tk)[None, None, :]
    bmask = jnp.ones((b, tq, tk), bool)
    if causal:
        bmask &= kpos <= qpos
    if window is not None:
        bmask &= kpos > qpos - window
    if kv_len is not None:
        bmask &= kpos < kv_len[:, None, None]
    logits = jnp.where(bmask[:, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    # rows with no admissible key: 0, not NaN (kernel's l==0 guard)
    p = jnp.where(jnp.any(bmask, -1, keepdims=True)[:, None], p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    if pv_bits < 24:
        out = truncate_mantissa(out, pv_bits, mode)
    return out.astype(q.dtype)
