"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.numerics import manipulated_bits, truncate_mantissa


def mantissa_trunc_ref(x: jnp.ndarray, bits: int,
                       mode: str = "rne") -> jnp.ndarray:
    """Oracle for kernels.mantissa_trunc."""
    return truncate_mantissa(x, bits, mode)


def bit_census_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.bit_census: total manipulated mantissa bits
    (trailing-zero counting, paper §III-C) as a scalar int32."""
    if x.size == 0:
        return jnp.zeros((), jnp.int32)
    return jnp.sum(manipulated_bits(x)).astype(jnp.int32)


def quant_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, a_bits: int,
                     b_bits: int, out_bits: int,
                     mode: str = "rne") -> jnp.ndarray:
    """Oracle for kernels.quant_matmul: truncate operands, fp32-accumulate
    matmul, truncate the result."""
    aq = truncate_mantissa(a, min(a_bits, _mant(a)), mode)
    bq = truncate_mantissa(b, min(b_bits, _mant(b)), mode)
    out = jnp.dot(aq.astype(jnp.float32), bq.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return truncate_mantissa(out, min(out_bits, 24), mode)


def _mant(x) -> int:
    from repro.utils.numerics import float_spec
    return float_spec(x.dtype).mantissa_bits


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int | None = None,
                        kv_len: jnp.ndarray | None = None,
                        q_start: jnp.ndarray | None = None,
                        qk_bits: int = 24, pv_bits: int = 24,
                        mode: str = "rne") -> jnp.ndarray:
    """Oracle for kernels.flash_attention.

    q: (B, Hq, Tq, D), k/v: (B, Hkv, Tk, D) with Hq % Hkv == 0 (GQA).
    ``kv_len`` ((B,) int32) optionally limits row b to its first
    ``kv_len[b]`` keys (ragged-slot prefix mask). ``q_start`` ((B,)
    int32) optionally places row b's queries at absolute key positions
    ``q_start[b] + i`` (the chunked-prefill layout) instead of right
    alignment. Query rows whose mask admits no key return zeros,
    matching the kernel's zero-denominator guard. Optional NEAT
    truncation of the QK^T logits and the PV product.
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if qk_bits < 24:
        logits = truncate_mantissa(logits, qk_bits, mode)
    # one mask path for both layouts: right alignment is q_start=tk-tq
    tk = k.shape[2]
    qs = (jnp.full((b,), tk - tq, jnp.int32) if q_start is None
          else q_start.astype(jnp.int32))
    qpos = qs[:, None, None] + jnp.arange(tq)[None, :, None]
    kpos = jnp.arange(tk)[None, None, :]
    bmask = jnp.ones((b, tq, tk), bool)
    if causal:
        bmask &= kpos <= qpos
    if window is not None:
        bmask &= kpos > qpos - window
    if kv_len is not None:
        bmask &= kpos < kv_len[:, None, None]
    logits = jnp.where(bmask[:, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    # rows with no admissible key: 0, not NaN (kernel's l==0 guard)
    p = jnp.where(jnp.any(bmask, -1, keepdims=True)[:, None], p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    if pv_bits < 24:
        out = truncate_mantissa(out, pv_bits, mode)
    return out.astype(q.dtype)


def gather_pages(pool: jnp.ndarray, block_tables: jnp.ndarray
                 ) -> jnp.ndarray:
    """Materialize each row's logical K/V prefix from a paged pool.

    pool: (num_pages, page_size, ...); block_tables: (B, max_pages)
    int32. Returns (B, max_pages * page_size, ...) — logical position
    ``p * page_size + j`` reads pool page ``block_tables[b, p]``, row
    ``j``. Sentinel/stale table entries are clamped onto a valid page;
    callers mask the result with their ``kv_len`` prefix, exactly like
    the paged kernel does. This is the oracle-side (and CPU fallback)
    form of the kernel's scalar-prefetch page streaming.
    """
    num_pages, page_size = pool.shape[0], pool.shape[1]
    tbl = jnp.clip(block_tables.astype(jnp.int32), 0, num_pages - 1)
    b, max_pages = tbl.shape
    gathered = pool[tbl]                 # (B, max_pages, page_size, ...)
    return gathered.reshape((b, max_pages * page_size) + pool.shape[2:])


def paged_flash_attention_ref(q, k_pool, v_pool, block_tables, *,
                              causal: bool = True,
                              window: int | None = None,
                              kv_len: jnp.ndarray | None = None,
                              q_start: jnp.ndarray | None = None,
                              qk_bits: int = 24, pv_bits: int = 24,
                              mode: str = "rne",
                              pages_per_block: int = 1) -> jnp.ndarray:
    """Oracle for kernels.paged_flash_attention: gather the logical
    K/V prefix per row, then run the contiguous oracle with the same
    ``kv_len``/``q_start`` mask contract.

    q: (B, Hq, Tq, D); k_pool/v_pool: (num_pages, page_size, Hkv, D);
    block_tables: (B, max_pages) int32. ``pages_per_block`` is the
    kernel's KV-block grouping knob; the gathered oracle is blocking-
    agnostic (attention in logical coordinates does not depend on how
    physical pages are tiled), so it is validated and otherwise inert —
    which is exactly the invariant the kernel sweep tests pin down."""
    if int(pages_per_block) < 1:
        raise ValueError(
            f"pages_per_block must be >= 1, got {pages_per_block}")
    kk = gather_pages(k_pool, block_tables)   # (B, S_log, Hkv, D)
    vv = gather_pages(v_pool, block_tables)
    return flash_attention_ref(q, kk.transpose(0, 2, 1, 3),
                               vv.transpose(0, 2, 1, 3), causal=causal,
                               window=window, kv_len=kv_len,
                               q_start=q_start, qk_bits=qk_bits,
                               pv_bits=pv_bits, mode=mode)
