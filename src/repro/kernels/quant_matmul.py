"""Pallas TPU kernel: fused mantissa-truncated matmul.

The TPU-native NEAT enforcement point. On x86/Pin, replacing a FLOP is
free — the instruction itself is swapped. On TPU a *separate* truncation
pass would re-stream every operand through HBM (pure overhead for a
bandwidth-bound elementwise op). This kernel truncates the A and B tiles
*in VMEM*, immediately before they enter the MXU, and truncates the fp32
accumulator once on the final K step — NEAT enforcement at zero extra HBM
traffic.

Tiling: (block_m x block_k) @ (block_k x block_n) with a K-innermost grid
and an fp32 VMEM accumulator; MXU-aligned blocks (multiples of 128).

``collect_census=True`` reuses the final K step — the output tile is
already in VMEM — to run the §III-C trailing-zero bit census on the
tile as stored (padding rows/cols masked) and accumulate it into a
(1, 1) SMEM scalar across the grid, exactly
``bit_census_ref(<the returned M x N output>)`` at zero extra
dispatches. The grid goes all-"arbitrary" when census is on (the SMEM
cell is cross-program state).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bit_census import _census_block
from repro.kernels.mantissa_trunc import _trunc_block
from repro.kernels.runtime import default_interpret
from repro.utils.jax_compat import CompilerParams as _CompilerParams


def _kernel(a_ref, b_ref, o_ref, *rest, a_bits, b_bits, out_bits,
            mode, k_steps, block_m, block_n, m_valid, n_valid,
            collect_census):
    if collect_census:
        c_ref, acc_ref = rest
    else:
        c_ref, (acc_ref,) = None, rest

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if c_ref is not None:
        first = ((pl.program_id(0) == 0) & (pl.program_id(1) == 0)
                 & (pl.program_id(2) == 0))
        # hoisted: program_id is unavailable inside a pl.when body under
        # the interpret-mode evaluator
        row = pl.program_id(0) * block_m + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, 1), 0)
        col = pl.program_id(1) * block_n + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_n), 1)
        census_mask = (row < m_valid) & (col < n_valid)

        @pl.when(first)
        def _census_init():
            c_ref[0, 0] = jnp.int32(0)

    a = _trunc_block(a_ref[...], a_bits, mode)   # VMEM-resident truncation
    b = _trunc_block(b_ref[...], b_bits, mode)
    acc_ref[...] += jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        out = _trunc_block(acc_ref[...], out_bits, mode)
        stored = out.astype(o_ref.dtype)
        o_ref[...] = stored
        if c_ref is not None:
            # census the stored tile; rows/cols past the unpadded (M, N)
            # are sliced off by the caller and masked here, so the
            # scalar equals bit_census_ref(<returned output>)
            bits = _census_block(stored)
            bits = jnp.where(census_mask, bits, 0)
            c_ref[0, 0] += jnp.sum(bits, dtype=jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("a_bits", "b_bits", "out_bits", "mode",
                                    "block_m", "block_n", "block_k",
                                    "collect_census", "interpret"))
def quant_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                        a_bits: int = 24, b_bits: int = 24,
                        out_bits: int = 24, mode: str = "rne",
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128,
                        collect_census: bool = False,
                        interpret: bool | None = None):
    """(M, K) @ (K, N) with NEAT truncation fused into the MXU pipeline.
    ``collect_census=True`` additionally returns the fused bit census of
    the output (scalar int32). ``interpret=None`` resolves from the
    backend (compiled on TPU)."""
    interpret = default_interpret(interpret)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)

    def pad(x, bm, bn):
        pm = (-x.shape[0]) % bm
        pn = (-x.shape[1]) % bn
        if pm or pn:
            x = jnp.pad(x, ((0, pm), (0, pn)))
        return x

    ap = pad(a, block_m, block_k)
    bp = pad(b, block_k, block_n)
    mp, kp = ap.shape
    _, np_ = bp.shape
    k_steps = kp // block_k
    grid = (mp // block_m, np_ // block_n, k_steps)

    out_specs = [pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j))]
    out_shape = [jax.ShapeDtypeStruct((mp, np_), a.dtype)]
    if collect_census:
        out_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0),
                                      memory_space=pltpu.SMEM))
        out_shape.append(jax.ShapeDtypeStruct((1, 1), jnp.int32))
        semantics = ("arbitrary", "arbitrary", "arbitrary")
    else:
        semantics = ("parallel", "parallel", "arbitrary")
    res = pl.pallas_call(
        functools.partial(_kernel, a_bits=a_bits, b_bits=b_bits,
                          out_bits=out_bits, mode=mode, k_steps=k_steps,
                          block_m=block_m, block_n=block_n, m_valid=m,
                          n_valid=n, collect_census=collect_census),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=out_specs if collect_census else out_specs[0],
        out_shape=out_shape if collect_census else out_shape[0],
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(ap, bp)
    out, census = res if collect_census else (res, None)
    if collect_census:
        return out[:m, :n], census[0, 0]
    return out[:m, :n]
