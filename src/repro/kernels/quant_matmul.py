"""Pallas TPU kernel: fused mantissa-truncated matmul.

The TPU-native NEAT enforcement point. On x86/Pin, replacing a FLOP is
free — the instruction itself is swapped. On TPU a *separate* truncation
pass would re-stream every operand through HBM (pure overhead for a
bandwidth-bound elementwise op). This kernel truncates the A and B tiles
*in VMEM*, immediately before they enter the MXU, and truncates the fp32
accumulator once on the final K step — NEAT enforcement at zero extra HBM
traffic.

Tiling: (block_m x block_k) @ (block_k x block_n) with a K-innermost grid
and an fp32 VMEM accumulator; MXU-aligned blocks (multiples of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mantissa_trunc import _trunc_block
from repro.kernels.runtime import default_interpret
from repro.utils.jax_compat import CompilerParams as _CompilerParams


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, a_bits, b_bits, out_bits,
            mode, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _trunc_block(a_ref[...], a_bits, mode)   # VMEM-resident truncation
    b = _trunc_block(b_ref[...], b_bits, mode)
    acc_ref[...] += jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        out = _trunc_block(acc_ref[...], out_bits, mode)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("a_bits", "b_bits", "out_bits", "mode",
                                    "block_m", "block_n", "block_k",
                                    "interpret"))
def quant_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                        a_bits: int = 24, b_bits: int = 24,
                        out_bits: int = 24, mode: str = "rne",
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128,
                        interpret: bool | None = None) -> jnp.ndarray:
    """(M, K) @ (K, N) with NEAT truncation fused into the MXU pipeline.
    ``interpret=None`` resolves from the backend (compiled on TPU)."""
    interpret = default_interpret(interpret)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)

    def pad(x, bm, bn):
        pm = (-x.shape[0]) % bm
        pn = (-x.shape[1]) % bn
        if pm or pn:
            x = jnp.pad(x, ((0, pm), (0, pn)))
        return x

    ap = pad(a, block_m, block_k)
    bp = pad(b, block_k, block_n)
    mp, kp = ap.shape
    _, np_ = bp.shape
    k_steps = kp // block_k
    grid = (mp // block_m, np_ // block_n, k_steps)

    out = pl.pallas_call(
        functools.partial(_kernel, a_bits=a_bits, b_bits=b_bits,
                          out_bits=out_bits, mode=mode, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
