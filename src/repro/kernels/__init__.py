"""Pallas TPU kernels for NEAT's compute hot spots.

Each kernel ships three layers:
  <name>.py  — the pl.pallas_call with explicit BlockSpec VMEM tiling
  ops.py     — jit'd public wrappers with interpret/TPU dispatch
  ref.py     — pure-jnp oracles the tests assert against
"""
from repro.kernels.ops import (
    mantissa_trunc,
    quant_matmul,
    flash_attention,
    paged_flash_attention,
    bit_census,
)
