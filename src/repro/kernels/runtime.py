"""Shared runtime policy for the Pallas kernels.

Every kernel wrapper takes ``interpret: bool | None``. ``None`` (the
default) resolves from the active JAX backend: compiled Mosaic on TPU,
interpreter emulation everywhere else — so callers never hardcode
``interpret=True`` and the same call site runs compiled on real
hardware and emulated in CPU CI.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel's ``interpret`` argument (None = auto off-TPU)."""
    if interpret is None:
        return not on_tpu()
    return interpret
