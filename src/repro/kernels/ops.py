"""Public jit'd wrappers for the Pallas kernels.

``backend`` selects the implementation:
  "auto"    — Pallas on TPU, jnp reference elsewhere (this container: jnp)
  "pallas"  — pl.pallas_call compiled for TPU
  "interpret" — Pallas with interpret=True (CPU emulation; tests use this)
  "ref"     — the pure-jnp oracle
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mantissa_trunc import mantissa_trunc_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


def mantissa_trunc(x: jnp.ndarray, bits: int, mode: str = "rne",
                   *, backend: str = "auto") -> jnp.ndarray:
    b = _resolve(backend)
    if b == "ref":
        return _ref.mantissa_trunc_ref(x, bits, mode)
    return mantissa_trunc_pallas(x, bits, mode, interpret=(b == "interpret"))


def quant_matmul(a: jnp.ndarray, b: jnp.ndarray, *, a_bits: int = 24,
                 b_bits: int = 24, out_bits: int = 24, mode: str = "rne",
                 backend: str = "auto") -> jnp.ndarray:
    be = _resolve(backend)
    if be == "ref":
        return _ref.quant_matmul_ref(a, b, a_bits, b_bits, out_bits, mode)
    return quant_matmul_pallas(a, b, a_bits=a_bits, b_bits=b_bits,
                               out_bits=out_bits, mode=mode,
                               interpret=(be == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    kv_len: jnp.ndarray | None = None, qk_bits: int = 24,
                    pv_bits: int = 24, mode: str = "rne",
                    backend: str = "auto"):
    """``kv_len`` ((B,) int32, optional) masks each batch row to its first
    ``kv_len[b]`` keys — the ragged-slot prefix mask for continuous
    batching (rows must not query beyond their own valid prefix)."""
    be = _resolve(backend)
    if be == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window, kv_len=kv_len,
                                        qk_bits=qk_bits,
                                        pv_bits=pv_bits, mode=mode)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  kv_len=kv_len,
                                  qk_bits=qk_bits, pv_bits=pv_bits,
                                  mode=mode, interpret=(be == "interpret"))
