"""Public jit'd wrappers for the Pallas kernels.

``backend`` selects the implementation:
  "auto"    — Pallas on TPU, jnp reference elsewhere (this container: jnp)
  "pallas"  — pl.pallas_call (compiled on TPU, interpreter emulation off-TPU)
  "interpret" — Pallas with interpret=True forced (CPU emulation; tests)
  "ref"     — the pure-jnp oracle

The Pallas paths leave ``interpret`` unset (None) so the kernels resolve
it from ``jax.default_backend()`` themselves (``kernels.runtime``);
callers never hardcode emulation.

These wrappers are precision-agnostic plumbing: the serving engine's
reduced-precision drafter does not add kernel variants — it reaches the
same ``flash_attention``/``paged_flash_attention`` entry points with
smaller fused ``qk_bits``/``out_bits`` resolved from the ambient NEAT
rule, and ``mantissa_trunc`` is what builds its truncated weight views.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.bit_census import bit_census_pallas
from repro.kernels.flash_attention import (flash_attention_pallas,
                                           paged_flash_attention_pallas)
from repro.kernels.mantissa_trunc import mantissa_trunc_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.runtime import on_tpu as _on_tpu


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


def _interp(resolved: str) -> bool | None:
    # "interpret" forces emulation; "pallas" defers to the backend default
    return True if resolved == "interpret" else None


def mantissa_trunc(x: jnp.ndarray, bits: int, mode: str = "rne",
                   *, backend: str = "auto") -> jnp.ndarray:
    b = _resolve(backend)
    if b == "ref":
        return _ref.mantissa_trunc_ref(x, bits, mode)
    return mantissa_trunc_pallas(x, bits, mode, interpret=_interp(b))


def quant_matmul(a: jnp.ndarray, b: jnp.ndarray, *, a_bits: int = 24,
                 b_bits: int = 24, out_bits: int = 24, mode: str = "rne",
                 collect_census: bool = False, backend: str = "auto"):
    """``collect_census=True`` returns ``(out, census)`` where ``census``
    is the fused §III-C bit census of ``out`` (scalar int32, exactly
    ``ref.bit_census_ref(out)`` on every backend)."""
    be = _resolve(backend)
    if be == "ref":
        out = _ref.quant_matmul_ref(a, b, a_bits, b_bits, out_bits, mode)
        if collect_census:
            return out, _ref.bit_census_ref(out)
        return out
    return quant_matmul_pallas(a, b, a_bits=a_bits, b_bits=b_bits,
                               out_bits=out_bits, mode=mode,
                               collect_census=collect_census,
                               interpret=_interp(be))


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    kv_len: jnp.ndarray | None = None,
                    q_start: jnp.ndarray | None = None, qk_bits: int = 24,
                    pv_bits: int = 24, mode: str = "rne",
                    collect_census: bool = False, backend: str = "auto"):
    """``kv_len`` ((B,) int32, optional) masks each batch row to its first
    ``kv_len[b]`` keys — the ragged-slot prefix mask for continuous
    batching (rows must not query beyond their own valid prefix).
    ``q_start`` ((B,) int32, optional) places row b's queries at absolute
    key positions ``q_start[b] + i`` — the chunked-prefill layout where a
    (B, C, D) query chunk attends causally against each slot's KV-cache
    prefix (pair it with ``kv_len = q_start + n_new``).
    ``collect_census=True`` returns ``(out, census)`` with the fused bit
    census of ``out`` (== ``ref.bit_census_ref(out)`` exactly)."""
    be = _resolve(backend)
    if be == "ref":
        out = _ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window, kv_len=kv_len,
                                       q_start=q_start, qk_bits=qk_bits,
                                       pv_bits=pv_bits, mode=mode)
        if collect_census:
            return out, _ref.bit_census_ref(out)
        return out
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  kv_len=kv_len, q_start=q_start,
                                  qk_bits=qk_bits, pv_bits=pv_bits,
                                  mode=mode, collect_census=collect_census,
                                  interpret=_interp(be))


def paged_flash_attention(q, k_pool, v_pool, block_tables, *,
                          causal: bool = True, window: int | None = None,
                          kv_len: jnp.ndarray | None = None,
                          q_start: jnp.ndarray | None = None,
                          qk_bits: int = 24, pv_bits: int = 24,
                          mode: str = "rne", pages_per_block: int = 1,
                          block_k: int | None = None,
                          collect_census: bool = False,
                          backend: str = "auto"):
    """Flash attention over a paged KV pool: ``k_pool``/``v_pool`` are
    ``(num_pages, page_size, Hkv, D)`` and ``block_tables`` ((B,
    max_pages) int32) maps each row's logical prefix onto physical
    pages. ``kv_len``/``q_start`` keep the contiguous entry's contract
    in logical coordinates. On the Pallas path the table rides as a
    scalar-prefetch argument and one KV grid step streams
    ``pages_per_block`` pages as a single ``pages_per_block * page_size``
    KV block; the ref path gathers the logical prefix and reuses the
    contiguous oracle. ``block_k``, if given, must be an exact page
    multiple consistent with ``pages_per_block`` — mismatches are a hard
    error (the old path silently clamped to one page), and a lone
    ``block_k`` is routed to ``pages_per_block = block_k / page_size``.
    ``collect_census=True`` returns ``(out, census)`` with the fused bit
    census of ``out``."""
    page_size = k_pool.shape[1]
    if pages_per_block < 1:
        raise ValueError(
            f"pages_per_block must be >= 1, got {pages_per_block}")
    if block_k is not None:
        if block_k < page_size or block_k % page_size:
            raise ValueError(
                f"block_k={block_k} is not a positive multiple of "
                f"page_size={page_size}: the paged kernel streams whole "
                f"pool pages, so block_k must equal pages_per_block * "
                f"page_size (e.g. pages_per_block="
                f"{max(1, block_k // page_size)})")
        if pages_per_block != 1 and block_k != pages_per_block * page_size:
            raise ValueError(
                f"block_k={block_k} conflicts with pages_per_block="
                f"{pages_per_block} at page_size={page_size}: block_k "
                f"must equal pages_per_block * page_size = "
                f"{pages_per_block * page_size}. Pass only one of the "
                f"two knobs, or make them agree")
        pages_per_block = block_k // page_size
    be = _resolve(backend)
    if be == "ref":
        out = _ref.paged_flash_attention_ref(
            q, k_pool, v_pool, block_tables, causal=causal, window=window,
            kv_len=kv_len, q_start=q_start, qk_bits=qk_bits,
            pv_bits=pv_bits, mode=mode, pages_per_block=pages_per_block)
        if collect_census:
            return out, _ref.bit_census_ref(out)
        return out
    return paged_flash_attention_pallas(
        q, k_pool, v_pool, block_tables, causal=causal, window=window,
        kv_len=kv_len, q_start=q_start, qk_bits=qk_bits, pv_bits=pv_bits,
        mode=mode, pages_per_block=pages_per_block,
        collect_census=collect_census, interpret=_interp(be))


def bit_census(x: jnp.ndarray, *, backend: str = "auto") -> jnp.ndarray:
    """Total manipulated mantissa bits of `x` (scalar int32) — the fused
    trailing-zero census the dynamic energy estimator accumulates per
    placement site. Exact; bit-identical across backends."""
    b = _resolve(backend)
    if b == "ref":
        return _ref.bit_census_ref(x)
    return bit_census_pallas(x, interpret=_interp(b))
