"""Pallas TPU kernel: mantissa truncation (the NEAT FPI hot path).

Elementwise bit-level rounding executed entirely in VMEM: bitcast to the
integer lane type, round-to-nearest-even (or truncate) at the dropped-bit
boundary, mask, bitcast back, preserving NaN/Inf. Tiled (block_m, block_n)
with the lane dim a multiple of 128 so the VPU operates on full registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.runtime import default_interpret
from repro.utils.numerics import float_spec


def _trunc_block(x: jnp.ndarray, bits: int, mode: str) -> jnp.ndarray:
    """The in-register truncation — same math as the jnp oracle but written
    against lax.bitcast so it lowers to pure VPU bit ops."""
    spec = float_spec(x.dtype)
    if bits >= spec.mantissa_bits:
        return x
    drop = spec.mantissa_bits - bits
    u = lax.bitcast_convert_type(x, spec.uint_dtype)
    one = jnp.array(1, spec.uint_dtype)
    mask = ~((one << drop) - one)
    if mode == "rne":
        lsb = (u >> drop) & one
        q = (u + (((one << (drop - 1)) - one) + lsb)) & mask
    else:
        q = u & mask
    exp_mask = jnp.array(spec.exp_mask, spec.uint_dtype)
    special = (u & exp_mask) == exp_mask
    q = jnp.where(special, u, q)
    return lax.bitcast_convert_type(q, x.dtype)


def _kernel(x_ref, o_ref, *, bits: int, mode: str):
    o_ref[...] = _trunc_block(x_ref[...], bits, mode)


@functools.partial(jax.jit,
                   static_argnames=("bits", "mode", "block_m", "block_n",
                                    "interpret"))
def mantissa_trunc_pallas(x: jnp.ndarray, bits: int, mode: str = "rne",
                          *, block_m: int = 256, block_n: int = 512,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Truncate `x` to `bits` effective mantissa bits via the Pallas kernel.

    `x` may be any shape; it is viewed as (M, N) with N the trailing dim.
    Pure elementwise — bandwidth-bound — so blocks are sized to stream
    ~1 MB VMEM tiles (256x512 fp32 = 512 KB in + 512 KB out).
    ``interpret=None`` resolves from the backend (compiled on TPU).
    """
    interpret = default_interpret(interpret)
    spec = float_spec(x.dtype)
    if bits >= spec.mantissa_bits:
        return x
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    # pad to a (block_m * block_n) multiple, run a 1-D grid of 2-D tiles
    tile = block_m * block_n
    padded = ((n + tile - 1) // tile) * tile
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    x2 = flat.reshape(padded // block_n, block_n)
    grid = (x2.shape[0] // block_m,)
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, mode=mode),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(-1)[:n].reshape(orig_shape)
