"""Pallas TPU kernel: blocked flash attention with fused NEAT truncation.

Online-softmax attention tiled for VMEM (FlashAttention adapted to the TPU
memory hierarchy: HBM -> VMEM block streaming, MXU for QK^T and PV, VPU for
the softmax update). Supports GQA (grouped KV heads), causal masking and
sliding windows, and — the NEAT integration — optional mantissa truncation
of the QK logits and of the output, fused so enforcement costs no extra
HBM traffic.

Layout: q (BH, Tq, D), kv (BHkv, Tk, D); grid (BH, Tq/bq, Tk/bk) with the
KV dim innermost ("arbitrary") carrying running max / denominator /
accumulator scratch.

**Paged variant** (``paged_flash_attention_pallas``): K/V live in a
shared physical pool ``(num_pages, page_size, Hkv, D)`` and each batch
row owns a ``(max_pages,)`` block table mapping its logical prefix onto
pool pages. The table rides as a scalar-prefetch argument
(``pltpu.PrefetchScalarGridSpec``) so the KV BlockSpecs' index maps
resolve the *physical* pages per grid step. One KV grid step streams
``pages_per_block`` table entries — the kernel concatenates the
sub-page tiles into one ``(pages_per_block * page_size, D)`` KV block,
so small pool pages (8/16/32 rows) still fill the (8, 128) MXU tile.
The ``kv_len``/``q_start`` mask contract is unchanged in *logical*
coordinates (key position ``page_slot * page_size + offset``), which is
also what masks sentinel sub-pages mid-block: an unallocated table
entry is clamped to a valid page and its keys sit at logical positions
``>= kv_len``, so the existing prefix mask discards them. Tables whose
``max_pages`` is not a multiple of ``pages_per_block`` are padded with
sentinel columns; the padded tail is masked the same way.

**Census epilogue** (``collect_census=True``): the final KV step already
holds the output tile in VMEM, so the kernel runs the §III-C
trailing-zero bit census on the tile *as stored* (post-cast, padded
query rows masked) and accumulates it into a (1, 1) SMEM scalar across
the whole grid — the same accumulator channel as
``bit_census.bit_census_pallas``. The scalar is exactly
``bit_census_ref(<returned output>)``, which is what makes the
measured-vs-host parity gate exact. Census accumulation is cross-program
state, so the grid switches to all-"arbitrary" dimension semantics when
it is on.

Speculative verification (``serve.engine`` draft-and-verify) reuses this
same ``q_start``/``kv_len`` contract unmodified: the target model scores
a slot's k+1 candidate rows as a short chunked-prefill window starting
at ``q_start = committed_len``, and rejected drafts are "rolled back" by
simply not advancing ``kv_len`` past the accepted prefix — stale KV rows
beyond it are masked off here and overwritten by the next ingest, so the
kernel needs no erase path. The drafter's reduced-precision rule rides
the existing fused ``qk_bits``/``out_bits`` hooks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bit_census import _census_block
from repro.kernels.mantissa_trunc import _trunc_block
from repro.kernels.runtime import default_interpret
from repro.utils.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _attn_step(q, k, v, kvl_ref, qs_ref, o_ref, c_ref, m_ref, l_ref,
               acc_ref, *, scale, causal, window, kv_steps, block_q,
               block_k, pad_k, qk_bits, pv_bits, mode, q_rows):
    """One online-softmax KV step over an assembled (block_k, d) KV tile
    (the paged entry concatenates ``pages_per_block`` sub-page tiles
    before calling in here). ``c_ref`` is the optional census SMEM
    scalar; ``q_rows`` the valid (unpadded) query-row count it masks to.
    """
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if c_ref is not None:
        first = ((pl.program_id(0) == 0) & (pl.program_id(1) == 0)
                 & (kv_i == 0))
        # hoisted: program_id is unavailable inside a pl.when body under
        # the interpret-mode evaluator
        census_row = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, 1), 0)

        @pl.when(first)
        def _census_init():
            c_ref[0, 0] = jnp.int32(0)

    q = q.astype(jnp.float32)                   # (bq, d)
    k = k.astype(jnp.float32)                   # (bk, d)
    v = v.astype(jnp.float32)                   # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if qk_bits < 24:
        s = _trunc_block(s, qk_bits, mode)      # NEAT: truncated logits

    # causal / sliding-window mask. qs_ref carries the per-row query
    # offset in padded key coords: (tk - tq) + pad_k for the default
    # right-aligned layout, or q_start[b] + pad_k when the caller places
    # a query chunk at an explicit per-slot cache position. Either way
    # causal alignment survives query padding; key positions < pad_k are
    # the zero left-pad keys.
    q_pos = (pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)) + qs_ref[0, 0]
    k_pos = kv_i * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos >= pad_k
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    # per-row valid-KV prefix (continuous batching: ragged slot lengths)
    mask &= k_pos < kvl_ref[0, 0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                       # (bq, 1)
    l_prev = l_ref[:, :1]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)                      # NEG_INF rows -> exp(<=0)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(kv_i == kv_steps - 1)
    def _done():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        if pv_bits < 24:
            out = _trunc_block(out, pv_bits, mode)   # NEAT: truncated PV
        stored = out.astype(o_ref.dtype)
        o_ref[0] = stored
        if c_ref is not None:
            # census the tile exactly as stored; query rows the caller
            # slices off are masked, so the accumulated scalar equals
            # bit_census_ref(<returned output>) bit-for-bit
            bits = _census_block(stored)
            bits = jnp.where(census_row < q_rows, bits, 0)
            c_ref[0, 0] += jnp.sum(bits, dtype=jnp.int32)


def _kernel(q_ref, k_ref, v_ref, kvl_ref, qs_ref, o_ref, *rest,
            collect_census, **kw):
    if collect_census:
        c_ref, m_ref, l_ref, acc_ref = rest
    else:
        c_ref, (m_ref, l_ref, acc_ref) = None, rest
    _attn_step(q_ref[0], k_ref[0], v_ref[0], kvl_ref, qs_ref, o_ref,
               c_ref, m_ref, l_ref, acc_ref, **kw)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "qk_bits", "pv_bits",
                              "mode", "block_q", "block_k",
                              "collect_census", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           kv_len=None, q_start=None, qk_bits: int = 24,
                           pv_bits: int = 24, mode: str = "rne",
                           block_q: int = 128, block_k: int = 128,
                           collect_census: bool = False,
                           interpret: bool | None = None):
    """q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D). Returns (B, Hq, Tq, D).
    ``kv_len`` ((B,) int32) optionally limits row b's attention to its
    first ``kv_len[b]`` keys (ragged-slot prefix mask). ``q_start``
    ((B,) int32) optionally places row b's query chunk at absolute key
    position ``q_start[b]`` (query i sits at ``q_start[b] + i``) instead
    of the default right alignment — the chunked-prefill contract where a
    (B, C, D) chunk attends causally against each slot's KV-cache prefix.
    ``collect_census=True`` additionally returns the fused §III-C bit
    census of the output (scalar int32 ==
    ``bit_census_ref(<the returned tensor>)``) at zero extra dispatches.
    ``interpret=None`` resolves from the backend (compiled on TPU)."""
    interpret = default_interpret(interpret)
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    pq = (-tq) % block_q
    pk = (-tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    # pad keys on the LEFT so right-alignment (and causal masks) holds
    kp = jnp.pad(k, ((0, 0), (0, 0), (pk, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (pk, 0), (0, 0))) if pk else v
    tqp, tkp = tq + pq, tk + pk

    q3 = qp.reshape(b * hq, tqp, d)
    k3 = kp.reshape(b * hkv, tkp, d)
    v3 = vp.reshape(b * hkv, tkp, d)
    kv_steps = tkp // block_k
    grid = (b * hq, tqp // block_q, kv_steps)

    # per-row valid-KV prefix, shifted by the left key padding and spread
    # to one row per (batch, head) program; full length == no-op mask
    kvl = (jnp.full((b,), tk, jnp.int32) if kv_len is None
           else kv_len.astype(jnp.int32))
    kvl3 = jnp.repeat(kvl + pk, hq).reshape(b * hq, 1)
    # per-row query offset in padded key coords (right-aligned default)
    qs = (jnp.full((b,), tk - tq, jnp.int32) if q_start is None
          else q_start.astype(jnp.int32))
    qs3 = jnp.repeat(qs + pk, hq).reshape(b * hq, 1)

    out_specs = [pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0))]
    out_shape = [jax.ShapeDtypeStruct((b * hq, tqp, d), q.dtype)]
    if collect_census:
        # every program adds into the same SMEM cell -> sequential grid
        out_specs.append(pl.BlockSpec((1, 1), lambda h, qi, ki: (0, 0),
                                      memory_space=pltpu.SMEM))
        out_shape.append(jax.ShapeDtypeStruct((1, 1), jnp.int32))
        semantics = ("arbitrary", "arbitrary", "arbitrary")
    else:
        semantics = ("parallel", "parallel", "arbitrary")
    res = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            kv_steps=kv_steps, block_q=block_q, block_k=block_k,
            pad_k=pk, qk_bits=qk_bits, pv_bits=pv_bits, mode=mode,
            q_rows=tq, collect_census=collect_census),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, 1), lambda h, qi, ki: (h, 0)),
            pl.BlockSpec((1, 1), lambda h, qi, ki: (h, 0)),
        ],
        out_specs=out_specs if collect_census else out_specs[0],
        out_shape=out_shape if collect_census else out_shape[0],
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # denominator
            pltpu.VMEM((block_q, d), jnp.float32),     # accumulator
        ],
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(q3, k3, v3, kvl3, qs3)
    out, census = res if collect_census else (res, None)
    out = out.reshape(b, hq, tqp, d)[:, :, :tq]
    if collect_census:
        return out, census[0, 0]
    return out


def _paged_kernel(tbl_ref, q_ref, *refs, ppb, collect_census, **kw):
    # the block table only steers the KV BlockSpecs' index maps; the
    # body is the same online-softmax loop as the contiguous kernel,
    # over a KV tile assembled from ``ppb`` sub-page blocks
    k_refs, v_refs = refs[:ppb], refs[ppb:2 * ppb]
    kvl_ref, qs_ref, o_ref = refs[2 * ppb:2 * ppb + 3]
    rest = refs[2 * ppb + 3:]
    if collect_census:
        c_ref, m_ref, l_ref, acc_ref = rest
    else:
        c_ref, (m_ref, l_ref, acc_ref) = None, rest
    k = (k_refs[0][0] if ppb == 1
         else jnp.concatenate([r[0] for r in k_refs], axis=0))
    v = (v_refs[0][0] if ppb == 1
         else jnp.concatenate([r[0] for r in v_refs], axis=0))
    _attn_step(q_ref[0], k, v, kvl_ref, qs_ref, o_ref, c_ref, m_ref,
               l_ref, acc_ref, **kw)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "qk_bits", "pv_bits",
                              "mode", "block_q", "pages_per_block",
                              "collect_census", "interpret"))
def paged_flash_attention_pallas(q, k_pool, v_pool, block_tables, *,
                                 causal: bool = True,
                                 window: int | None = None,
                                 kv_len=None, q_start=None,
                                 qk_bits: int = 24, pv_bits: int = 24,
                                 mode: str = "rne", block_q: int = 128,
                                 pages_per_block: int = 1,
                                 collect_census: bool = False,
                                 interpret: bool | None = None):
    """Flash attention over a paged KV pool.

    q: (B, Hq, Tq, D); k_pool/v_pool: (num_pages, page_size, Hkv, D);
    block_tables: (B, max_pages) int32 mapping row b's logical key
    position ``p * page_size + j`` onto pool page
    ``block_tables[b, p]``, row ``j``. ``kv_len``/``q_start`` keep the
    contiguous kernel's contract in *logical* coordinates. Table entries
    past a row's allocation may hold any value (the canonical sentinel
    is ``num_pages``): the index map clamps them to a valid page and the
    ``kv_len`` mask discards whatever is read.

    One KV grid step streams ``pages_per_block`` table entries and
    concatenates their tiles into a ``block_k = pages_per_block *
    page_size`` KV block, so small pool pages still fill the MXU tile;
    the pool is never gathered into a contiguous (B, S, ...) buffer.
    Sentinel entries *inside* a block need no special casing — their
    keys land at logical positions ``>= kv_len`` and the prefix mask
    already discards them. ``collect_census=True`` additionally returns
    the fused bit census of the output (scalar int32 ==
    ``bit_census_ref(<the returned tensor>)``).
    """
    interpret = default_interpret(interpret)
    b, hq, tq, d = q.shape
    num_pages, page_size, hkv, _ = k_pool.shape
    max_pages = block_tables.shape[1]
    ppb = int(pages_per_block)
    assert ppb >= 1, pages_per_block
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, tq)
    pq = (-tq) % block_q
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    tqp = tq + pq
    q3 = qp.reshape(b * hq, tqp, d)
    # pool flattened page-major over KV heads: page p, head u -> p*Hkv+u
    k3 = k_pool.transpose(0, 2, 1, 3).reshape(num_pages * hkv,
                                              page_size, d)
    v3 = v_pool.transpose(0, 2, 1, 3).reshape(num_pages * hkv,
                                              page_size, d)
    # logical length keeps the ORIGINAL table width: sentinel columns
    # added below to round max_pages up to a pages_per_block multiple
    # sit at logical positions >= logical and are masked like any
    # unallocated entry
    logical = max_pages * page_size
    kvl = (jnp.full((b,), logical, jnp.int32) if kv_len is None
           else kv_len.astype(jnp.int32))
    kvl3 = jnp.repeat(kvl, hq).reshape(b * hq, 1)
    qs = (jnp.full((b,), logical - tq, jnp.int32) if q_start is None
          else q_start.astype(jnp.int32))
    qs3 = jnp.repeat(qs, hq).reshape(b * hq, 1)
    tbl = block_tables.astype(jnp.int32)
    pad_pages = (-max_pages) % ppb
    if pad_pages:
        tbl = jnp.pad(tbl, ((0, 0), (0, pad_pages)),
                      constant_values=num_pages)
    tbl = jnp.clip(tbl, 0, num_pages - 1)
    kv_steps = (max_pages + pad_pages) // ppb
    block_k = ppb * page_size

    grid = (b * hq, tqp // block_q, kv_steps)

    def kv_map(j):
        def m(h, qi, ki, tbl_ref, j=j, g=group, nh=hq, u=hkv, p=ppb):
            return (tbl_ref[h // nh, ki * p + j] * u + (h % nh) // g, 0, 0)
        return m

    in_specs = [pl.BlockSpec((1, block_q, d),
                             lambda h, qi, ki, tbl_ref: (h, qi, 0))]
    in_specs += [pl.BlockSpec((1, page_size, d), kv_map(j))
                 for j in range(ppb)]                            # K pages
    in_specs += [pl.BlockSpec((1, page_size, d), kv_map(j))
                 for j in range(ppb)]                            # V pages
    in_specs += [
        pl.BlockSpec((1, 1), lambda h, qi, ki, tbl_ref: (h, 0)),
        pl.BlockSpec((1, 1), lambda h, qi, ki, tbl_ref: (h, 0)),
    ]
    out_specs = [pl.BlockSpec((1, block_q, d),
                              lambda h, qi, ki, tbl_ref: (h, qi, 0))]
    out_shape = [jax.ShapeDtypeStruct((b * hq, tqp, d), q.dtype)]
    if collect_census:
        out_specs.append(
            pl.BlockSpec((1, 1), lambda h, qi, ki, tbl_ref: (0, 0),
                         memory_space=pltpu.SMEM))
        out_shape.append(jax.ShapeDtypeStruct((1, 1), jnp.int32))
        semantics = ("arbitrary", "arbitrary", "arbitrary")
    else:
        semantics = ("parallel", "parallel", "arbitrary")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if collect_census else out_specs[0],
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # denominator
            pltpu.VMEM((block_q, d), jnp.float32),     # accumulator
        ],
    )
    res = pl.pallas_call(
        functools.partial(
            _paged_kernel, ppb=ppb, collect_census=collect_census,
            scale=scale, causal=causal, window=window,
            kv_steps=kv_steps, block_q=block_q, block_k=block_k,
            pad_k=0, qk_bits=qk_bits, pv_bits=pv_bits, mode=mode,
            q_rows=tq),
        grid_spec=grid_spec,
        out_shape=out_shape if collect_census else out_shape[0],
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(tbl, q3, *([k3] * ppb), *([v3] * ppb), kvl3, qs3)
    out, census = res if collect_census else (res, None)
    out = out.reshape(b, hq, tqp, d)[:, :, :tq]
    if collect_census:
        return out, census[0, 0]
    return out
