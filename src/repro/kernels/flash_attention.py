"""Pallas TPU kernel: blocked flash attention with fused NEAT truncation.

Online-softmax attention tiled for VMEM (FlashAttention adapted to the TPU
memory hierarchy: HBM -> VMEM block streaming, MXU for QK^T and PV, VPU for
the softmax update). Supports GQA (grouped KV heads), causal masking and
sliding windows, and — the NEAT integration — optional mantissa truncation
of the QK logits and of the output, fused so enforcement costs no extra
HBM traffic.

Layout: q (BH, Tq, D), kv (BHkv, Tk, D); grid (BH, Tq/bq, Tk/bk) with the
KV dim innermost ("arbitrary") carrying running max / denominator /
accumulator scratch.

**Paged variant** (``paged_flash_attention_pallas``): K/V live in a
shared physical pool ``(num_pages, page_size, Hkv, D)`` and each batch
row owns a ``(max_pages,)`` block table mapping its logical prefix onto
pool pages. The table rides as a scalar-prefetch argument
(``pltpu.PrefetchScalarGridSpec``) so the KV BlockSpec's index map
resolves the *physical* page per grid step — the kernel body is the
same online-softmax loop, streaming one page per KV step, and the
``kv_len``/``q_start`` mask contract is unchanged (logical key position
``page_slot * page_size + offset``). Unallocated table entries are
clamped to a valid page and masked off by ``kv_len``.

Speculative verification (``serve.engine`` draft-and-verify) reuses this
same ``q_start``/``kv_len`` contract unmodified: the target model scores
a slot's k+1 candidate rows as a short chunked-prefill window starting
at ``q_start = committed_len``, and rejected drafts are "rolled back" by
simply not advancing ``kv_len`` past the accepted prefix — stale KV rows
beyond it are masked off here and overwritten by the next ingest, so the
kernel needs no erase path. The drafter's reduced-precision rule rides
the existing fused ``qk_bits``/``out_bits`` hooks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mantissa_trunc import _trunc_block
from repro.kernels.runtime import default_interpret
from repro.utils.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, kvl_ref, qs_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale, causal, window, kv_steps, block_q, block_k,
            pad_k, qk_bits, pv_bits, mode):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if qk_bits < 24:
        s = _trunc_block(s, qk_bits, mode)      # NEAT: truncated logits

    # causal / sliding-window mask. qs_ref carries the per-row query
    # offset in padded key coords: (tk - tq) + pad_k for the default
    # right-aligned layout, or q_start[b] + pad_k when the caller places
    # a query chunk at an explicit per-slot cache position. Either way
    # causal alignment survives query padding; key positions < pad_k are
    # the zero left-pad keys.
    q_pos = (pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)) + qs_ref[0, 0]
    k_pos = kv_i * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos >= pad_k
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    # per-row valid-KV prefix (continuous batching: ragged slot lengths)
    mask &= k_pos < kvl_ref[0, 0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                       # (bq, 1)
    l_prev = l_ref[:, :1]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)                      # NEG_INF rows -> exp(<=0)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(kv_i == kv_steps - 1)
    def _done():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        if pv_bits < 24:
            out = _trunc_block(out, pv_bits, mode)   # NEAT: truncated PV
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "qk_bits", "pv_bits",
                              "mode", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           kv_len=None, q_start=None, qk_bits: int = 24,
                           pv_bits: int = 24, mode: str = "rne",
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool | None = None):
    """q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D). Returns (B, Hq, Tq, D).
    ``kv_len`` ((B,) int32) optionally limits row b's attention to its
    first ``kv_len[b]`` keys (ragged-slot prefix mask). ``q_start``
    ((B,) int32) optionally places row b's query chunk at absolute key
    position ``q_start[b]`` (query i sits at ``q_start[b] + i``) instead
    of the default right alignment — the chunked-prefill contract where a
    (B, C, D) chunk attends causally against each slot's KV-cache prefix.
    ``interpret=None`` resolves from the backend (compiled on TPU)."""
    interpret = default_interpret(interpret)
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    pq = (-tq) % block_q
    pk = (-tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    # pad keys on the LEFT so right-alignment (and causal masks) holds
    kp = jnp.pad(k, ((0, 0), (0, 0), (pk, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (pk, 0), (0, 0))) if pk else v
    tqp, tkp = tq + pq, tk + pk

    q3 = qp.reshape(b * hq, tqp, d)
    k3 = kp.reshape(b * hkv, tkp, d)
    v3 = vp.reshape(b * hkv, tkp, d)
    kv_steps = tkp // block_k
    grid = (b * hq, tqp // block_q, kv_steps)

    # per-row valid-KV prefix, shifted by the left key padding and spread
    # to one row per (batch, head) program; full length == no-op mask
    kvl = (jnp.full((b,), tk, jnp.int32) if kv_len is None
           else kv_len.astype(jnp.int32))
    kvl3 = jnp.repeat(kvl + pk, hq).reshape(b * hq, 1)
    # per-row query offset in padded key coords (right-aligned default)
    qs = (jnp.full((b,), tk - tq, jnp.int32) if q_start is None
          else q_start.astype(jnp.int32))
    qs3 = jnp.repeat(qs + pk, hq).reshape(b * hq, 1)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            kv_steps=kv_steps, block_q=block_q, block_k=block_k,
            pad_k=pk, qk_bits=qk_bits, pv_bits=pv_bits, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, 1), lambda h, qi, ki: (h, 0)),
            pl.BlockSpec((1, 1), lambda h, qi, ki: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, tqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # denominator
            pltpu.VMEM((block_q, d), jnp.float32),     # accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q3, k3, v3, kvl3, qs3)
    out = out.reshape(b, hq, tqp, d)[:, :, :tq]
    return out


def _paged_kernel(tbl_ref, q_ref, k_ref, v_ref, kvl_ref, qs_ref, o_ref,
                  m_ref, l_ref, acc_ref, **kw):
    # the block table only steers the KV BlockSpec index maps; the body
    # is the same online-softmax loop as the contiguous kernel
    _kernel(q_ref, k_ref, v_ref, kvl_ref, qs_ref, o_ref, m_ref, l_ref,
            acc_ref, **kw)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "qk_bits", "pv_bits",
                              "mode", "block_q", "interpret"))
def paged_flash_attention_pallas(q, k_pool, v_pool, block_tables, *,
                                 causal: bool = True,
                                 window: int | None = None,
                                 kv_len=None, q_start=None,
                                 qk_bits: int = 24, pv_bits: int = 24,
                                 mode: str = "rne", block_q: int = 128,
                                 interpret: bool | None = None):
    """Flash attention over a paged KV pool.

    q: (B, Hq, Tq, D); k_pool/v_pool: (num_pages, page_size, Hkv, D);
    block_tables: (B, max_pages) int32 mapping row b's logical key
    position ``p * page_size + j`` onto pool page
    ``block_tables[b, p]``, row ``j``. ``kv_len``/``q_start`` keep the
    contiguous kernel's contract in *logical* coordinates. Table entries
    past a row's allocation may hold any value (the canonical sentinel
    is ``num_pages``): the index map clamps them to a valid page and the
    ``kv_len`` mask discards whatever is read. One KV grid step streams
    one page (``block_k == page_size``), so the pool is never gathered
    into a contiguous (B, S, ...) buffer.
    """
    interpret = default_interpret(interpret)
    b, hq, tq, d = q.shape
    num_pages, page_size, hkv, _ = k_pool.shape
    max_pages = block_tables.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, tq)
    pq = (-tq) % block_q
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    tqp = tq + pq
    q3 = qp.reshape(b * hq, tqp, d)
    # pool flattened page-major over KV heads: page p, head u -> p*Hkv+u
    k3 = k_pool.transpose(0, 2, 1, 3).reshape(num_pages * hkv,
                                              page_size, d)
    v3 = v_pool.transpose(0, 2, 1, 3).reshape(num_pages * hkv,
                                              page_size, d)
    logical = max_pages * page_size
    kvl = (jnp.full((b,), logical, jnp.int32) if kv_len is None
           else kv_len.astype(jnp.int32))
    kvl3 = jnp.repeat(kvl, hq).reshape(b * hq, 1)
    qs = (jnp.full((b,), logical - tq, jnp.int32) if q_start is None
          else q_start.astype(jnp.int32))
    qs3 = jnp.repeat(qs, hq).reshape(b * hq, 1)
    tbl = jnp.clip(block_tables.astype(jnp.int32), 0, num_pages - 1)

    grid = (b * hq, tqp // block_q, max_pages)

    def kv_map(h, qi, ki, tbl_ref, g=group, nh=hq, u=hkv):
        return (tbl_ref[h // nh, ki] * u + (h % nh) // g, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d),
                         lambda h, qi, ki, tbl_ref: (h, qi, 0)),
            pl.BlockSpec((1, page_size, d), kv_map),
            pl.BlockSpec((1, page_size, d), kv_map),
            pl.BlockSpec((1, 1), lambda h, qi, ki, tbl_ref: (h, 0)),
            pl.BlockSpec((1, 1), lambda h, qi, ki, tbl_ref: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda h, qi, ki, tbl_ref: (h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # denominator
            pltpu.VMEM((block_q, d), jnp.float32),     # accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_kernel, scale=scale, causal=causal, window=window,
            kv_steps=max_pages, block_q=block_q, block_k=page_size,
            pad_k=0, qk_bits=qk_bits, pv_bits=pv_bits, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, tqp, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tbl, q3, k3, v3, kvl3, qs3)
    return out.reshape(b, hq, tqp, d)[:, :, :tq]
