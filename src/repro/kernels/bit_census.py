"""Pallas TPU kernel: manipulated-mantissa-bit census (paper §III-C).

The dynamic energy model charges only the mantissa bits a FLOP actually
manipulates — counted by trailing zeros of the stored fraction. This
kernel fuses the whole census into one pass over the tensor: bitcast to
the integer lane type, trailing-zero count via popcount bit tricks
(``tz = popcount(~frac & (frac - 1))``), manipulated bits =
``mantissa_bits - tz``, and a tiled VMEM sum-reduction into a single
scalar accumulator (the TPU grid is sequential, so every tile adds into
the same SMEM cell). One scalar leaves the chip per tensor instead of a
per-element bit map, which is what lets the explorer thread the census
through its population-batched evaluator.

Counts are exact int32: the census saturates correctness at ~2^31 total
bits (~89M fp32 elements), far above any per-site tensor the explorer
evaluates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import default_interpret
from repro.utils.numerics import float_spec


def _census_block(x: jnp.ndarray) -> jnp.ndarray:
    """Per-element manipulated-bit count, pure VPU bit ops (int32 result).

    Matches ``utils.numerics.manipulated_bits`` bit-exactly: full-fraction
    values count ``mantissa_bits``; zero-fraction values (0.0, powers of
    two, Inf) count 1 (the implicit bit).
    """
    spec = float_spec(x.dtype)
    u = lax.bitcast_convert_type(x, spec.uint_dtype)
    if spec.total_bits < 32:       # widen sub-word lanes for the popcount
        u = u.astype(jnp.uint32)
    one = jnp.array(1, u.dtype)
    frac = u & ((one << spec.frac_bits) - one)
    # trailing zeros: popcount(~frac & (frac - 1)); frac == 0 wraps to the
    # full lane width and the min() clamps it back to frac_bits
    tz = lax.population_count(~frac & (frac - one)).astype(jnp.int32)
    tz = jnp.minimum(tz, spec.frac_bits)
    return spec.mantissa_bits - tz


def _kernel(x_ref, o_ref, *, n_valid: int, block_m: int, block_n: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        o_ref[0, 0] = jnp.int32(0)

    bits = _census_block(x_ref[...])
    # mask the flatten-padding tail (pads are 0.0 and would count 1 each)
    row = lax.broadcasted_iota(jnp.int32, bits.shape, 0)
    col = lax.broadcasted_iota(jnp.int32, bits.shape, 1)
    gidx = (pid * block_m + row) * block_n + col
    bits = jnp.where(gidx < n_valid, bits, 0)
    o_ref[0, 0] += jnp.sum(bits, dtype=jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "interpret"))
def bit_census_pallas(x: jnp.ndarray, *, block_m: int = 256,
                      block_n: int = 512,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Total manipulated mantissa bits of `x` as a scalar int32.

    `x` may be any shape; it is viewed as (M, N) tiles like the other
    elementwise kernels. Bandwidth-bound: one read per element, one
    scalar out.
    """
    interpret = default_interpret(interpret)
    float_spec(x.dtype)                      # validate supported dtype
    n = int(x.size)
    if n == 0:
        return jnp.zeros((), jnp.int32)
    flat = x.reshape(-1)
    rows = -(-n // block_n)
    # shrink the row-block for small inputs, staying sublane-aligned
    bm = min(block_m, -(-rows // 8) * 8)
    padded_rows = -(-rows // bm) * bm
    padded = padded_rows * block_n
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    x2 = flat.reshape(padded_rows, block_n)
    out = pl.pallas_call(
        functools.partial(_kernel, n_valid=n, block_m=bm, block_n=block_n),
        grid=(padded_rows // bm,),
        in_specs=[pl.BlockSpec((bm, block_n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0),
                               memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(x2)
    return out[0, 0]
