from repro.data.synthetic import (
    SyntheticLMDataset, synth_batch, synthetic_digits,
)
