"""Deterministic synthetic data pipelines.

LM stream: batch(step) is a pure function of (seed, step, shard), so
* every data-parallel shard computes its slice locally — zero input I/O
  or host broadcast at 1000-node scale,
* restart/elastic-resume is exact: a restarted worker reproduces any step
  (the trainer's straggler mitigation = deterministic skip-ahead),
* no host-device transfer bottleneck for the dry-run path.

The token process is a structured Markov-ish stream (not iid-uniform) so
cross-entropy actually decreases during the example runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> Dict:
        """Deterministic batch for `step`; shard slices the global batch."""
        local = self.global_batch // n_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.seed), step), shard)
        k1, k2 = jax.random.split(key)
        base = jax.random.randint(k1, (local, self.seq_len + 1), 0,
                                  self.vocab_size, jnp.int32)
        # structure: with p=0.75 repeat (prev_token + 1) mod V
        rep = jax.random.bernoulli(k2, 0.75, (local, self.seq_len + 1))
        toks = [base[:, 0]]
        # vectorized "copy previous + 1" chain via segment trick:
        # t_i = where(rep_i, (t_{i-1}+1) % V, base_i) — computed with scan
        def f(prev, xs):
            b, r = xs
            cur = jnp.where(r, (prev + 1) % self.vocab_size, b)
            return cur, cur
        _, rest = jax.lax.scan(
            f, base[:, 0], (base[:, 1:].T, rep[:, 1:].T))
        seq = jnp.concatenate([base[:, :1], rest.T], axis=1)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def synth_batch(vocab: int, batch: int, seq: int, step: int = 0,
                seed: int = 0) -> Dict:
    return SyntheticLMDataset(vocab, seq, batch, seed).batch(step)


def synthetic_digits(n: int, seed: int = 0, noise: float = 0.35,
                     image_hw: int = 32):
    """MNIST-like synthetic digits for the LeNet-5 case study: 10 template
    glyphs rendered on a 32x32 grid + Gaussian noise. Returns
    (images (N,32,32,1) fp32 in [0,1], labels (N,) int32)."""
    rng = np.random.default_rng(seed)
    # 7-segment style templates on an 8x8 grid, upscaled
    segs = {
        "top": [(0, c) for c in range(2, 6)],
        "mid": [(3, c) for c in range(2, 6)],
        "bot": [(7, c) for c in range(2, 6)],
        "tl": [(r, 2) for r in range(0, 4)],
        "tr": [(r, 5) for r in range(0, 4)],
        "bl": [(r, 2) for r in range(4, 8)],
        "br": [(r, 5) for r in range(4, 8)],
    }
    digit_segs = {
        0: ["top", "bot", "tl", "tr", "bl", "br"],
        1: ["tr", "br"],
        2: ["top", "tr", "mid", "bl", "bot"],
        3: ["top", "tr", "mid", "br", "bot"],
        4: ["tl", "tr", "mid", "br"],
        5: ["top", "tl", "mid", "br", "bot"],
        6: ["top", "tl", "mid", "bl", "br", "bot"],
        7: ["top", "tr", "br"],
        8: ["top", "mid", "bot", "tl", "tr", "bl", "br"],
        9: ["top", "mid", "bot", "tl", "tr", "br"],
    }
    templates = np.zeros((10, 8, 8), np.float32)
    for d, names in digit_segs.items():
        for nm in names:
            for (r, c) in segs[nm]:
                templates[d, r, c] = 1.0
    scale = image_hw // 8
    big = np.kron(templates, np.ones((scale, scale), np.float32))
    labels = rng.integers(0, 10, n).astype(np.int32)
    images = big[labels]
    # random shifts +- 2px and noise
    out = np.zeros((n, image_hw, image_hw), np.float32)
    for i in range(n):
        dy, dx = rng.integers(-2, 3, 2)
        out[i] = np.roll(np.roll(big[labels[i]], dy, 0), dx, 1)
    out += rng.normal(0.0, noise, out.shape).astype(np.float32)
    out = np.clip(out, 0.0, 1.0)
    return jnp.asarray(out[..., None]), jnp.asarray(labels)
