"""Bit-level float numerics shared by the FPI layer and the kernels.

All functions are pure jnp and shape-polymorphic; the Pallas kernels in
``repro.kernels`` re-implement the hot paths with explicit VMEM tiling and
are validated against these.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class FloatSpec(NamedTuple):
    """Bit layout of an IEEE-ish float type."""
    uint_dtype: object
    total_bits: int
    exp_bits: int
    frac_bits: int      # stored fraction bits (excl. implicit leading 1)

    @property
    def mantissa_bits(self) -> int:
        # Paper convention: mantissa bits *including* the implicit bit
        # (24 for fp32, 53 for fp64, 8 for bf16, 11 for fp16).
        return self.frac_bits + 1

    @property
    def exp_mask(self) -> int:
        return ((1 << self.exp_bits) - 1) << self.frac_bits


_SPECS = {
    jnp.dtype(jnp.float32): FloatSpec(jnp.uint32, 32, 8, 23),
    jnp.dtype(jnp.float64): FloatSpec(jnp.uint64, 64, 11, 52),
    jnp.dtype(jnp.bfloat16): FloatSpec(jnp.uint16, 16, 8, 7),
    jnp.dtype(jnp.float16): FloatSpec(jnp.uint16, 16, 5, 10),
}


def float_spec(dtype) -> FloatSpec:
    d = jnp.dtype(dtype)
    if d not in _SPECS:
        raise ValueError(f"unsupported float dtype {d}")
    return _SPECS[d]


def truncate_mantissa(x: jnp.ndarray, bits: int, mode: str = "rne") -> jnp.ndarray:
    """Reduce `x` to `bits` effective mantissa bits (incl. implicit bit).

    ``bits`` follows the paper's convention: fp32 supports 1..24, fp64
    1..53; ``bits == mantissa_bits`` is the identity. ``mode`` is ``"rne"``
    (round-to-nearest-even, the IEEE default) or ``"trunc"`` (the paper's
    bit truncation). NaN/Inf are preserved bit-exactly.
    """
    spec = float_spec(x.dtype)
    if bits < 1:
        raise ValueError(f"bits={bits} must be >= 1")
    if bits >= spec.mantissa_bits:   # clamp: wider-than-native is identity
        return x
    drop = spec.mantissa_bits - bits           # low fraction bits removed
    u = x.view(spec.uint_dtype)
    one = jnp.array(1, spec.uint_dtype)
    mask = ~((one << drop) - one)
    if mode == "rne":
        # round-half-to-even on the integer representation; a carry out of
        # the fraction correctly bumps the exponent.
        lsb = (u >> drop) & one
        rounded = u + (((one << (drop - 1)) - one) + lsb)
        q = rounded & mask
    elif mode == "trunc":
        q = u & mask
    else:
        raise ValueError(f"unknown rounding mode {mode!r}")
    # keep NaN/Inf (exponent all-ones) bit-exact
    exp_mask = jnp.array(spec.exp_mask, spec.uint_dtype)
    is_special = (u & exp_mask) == exp_mask
    q = jnp.where(is_special, u, q)
    return q.view(x.dtype)


def truncate_mantissa_dynamic(x: jnp.ndarray, bits: jnp.ndarray,
                              mode: str = "rne") -> jnp.ndarray:
    """``truncate_mantissa`` with a *traced* integer ``bits`` argument.

    Lets a single compiled function serve every mantissa width — the NEAT
    explorer jits one evaluator per placement family and feeds genome bit
    vectors as runtime arguments. ``bits >= mantissa_bits`` is the identity.
    """
    spec = float_spec(x.dtype)
    u = x.view(spec.uint_dtype)
    one = jnp.array(1, spec.uint_dtype)
    bits = jnp.asarray(bits, jnp.int32)
    drop_i = jnp.clip(spec.mantissa_bits - bits, 0, spec.frac_bits)
    drop = drop_i.astype(spec.uint_dtype)
    dropc = jnp.maximum(drop, one)           # avoid UB shifts at drop == 0
    mask = ~((one << dropc) - one)
    if mode == "rne":
        lsb = (u >> dropc) & one
        q = (u + (((one << (dropc - one)) - one) + lsb)) & mask
    elif mode == "trunc":
        q = u & mask
    else:
        raise ValueError(f"unknown rounding mode {mode!r}")
    exp_mask = jnp.array(spec.exp_mask, spec.uint_dtype)
    is_special = (u & exp_mask) == exp_mask
    q = jnp.where((drop_i == 0) | is_special, u, q)
    return q.view(x.dtype)


def manipulated_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Per-element count of manipulated mantissa bits, paper §III-C.

    Counts trailing zero bits of the stored fraction and subtracts from the
    available mantissa bits (incl. implicit bit): fp32 full precision -> 24,
    value with zero fraction -> 1. Returns int32 array of x's shape.
    """
    spec = float_spec(x.dtype)
    u = x.view(spec.uint_dtype)
    frac = u & ((jnp.array(1, spec.uint_dtype) << spec.frac_bits)
                - jnp.array(1, spec.uint_dtype))
    # lowest set bit; frac==0 handled separately
    lowest = frac & (~frac + jnp.array(1, spec.uint_dtype))
    # exact for 2**k up to frac_bits<=52: use float64 when needed
    f = lowest.astype(jnp.float64 if spec.frac_bits > 23 else jnp.float32)
    tz = jnp.where(frac == 0, spec.frac_bits,
                   jnp.round(jnp.log2(jnp.maximum(f, 1.0))).astype(jnp.int32))
    return (spec.mantissa_bits - tz).astype(jnp.int32)


def bits_for_storage(bits: int, dtype) -> int:
    """Bits moved to memory for an element at `bits` mantissa precision:
    sign + exponent + stored-fraction bits actually carrying information."""
    spec = float_spec(dtype)
    return 1 + spec.exp_bits + max(bits - 1, 0)
