"""Pytree utilities shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count_params(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return int(sum(np.prod(x.shape) if hasattr(x, "shape") else 1
                   for x in jax.tree.leaves(tree)))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_cast(tree, dtype):
    """Cast every inexact-float leaf of a pytree to `dtype`."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, tree)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_global_norm(tree):
    """Global L2 norm across all leaves (for grad clipping)."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)
