"""A minimal name->object registry used for archs, FPIs and selectors."""
from __future__ import annotations

from typing import Callable, Dict, Generic, Iterator, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, T] = {}

    def register(self, name: str, item: T | None = None):
        """Register an item, usable directly or as a decorator."""
        if item is not None:
            if name in self._items:
                raise KeyError(f"{self.kind} {name!r} already registered")
            self._items[name] = item
            return item

        def deco(fn: T) -> T:
            self.register(name, fn)
            return fn

        return deco

    def get(self, name: str) -> T:
        if name not in self._items:
            known = ", ".join(sorted(self._items))
            raise KeyError(f"unknown {self.kind} {name!r}; known: {known}")
        return self._items[name]

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def names(self) -> list[str]:
        return sorted(self._items)

    def items(self) -> Iterator[tuple[str, T]]:
        return iter(sorted(self._items.items()))
