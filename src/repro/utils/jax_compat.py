"""Small shims over JAX API renames, so version drift is absorbed in one
place instead of at every call site."""
from __future__ import annotations

import jax

try:  # pallas TPU params: TPUCompilerParams was renamed CompilerParams
    from jax.experimental.pallas import tpu as _pltpu
    CompilerParams = getattr(_pltpu, "CompilerParams", None) \
        or getattr(_pltpu, "TPUCompilerParams")
except ImportError:  # pragma: no cover - pallas not available
    CompilerParams = None

# jax.tree.flatten_with_path only exists in newer JAX; the jax.tree_util
# spelling is long-stable.
tree_flatten_with_path = getattr(jax.tree, "flatten_with_path", None) \
    or jax.tree_util.tree_flatten_with_path
