from repro.utils.tree import (
    tree_bytes,
    tree_count_params,
    tree_cast,
    tree_zeros_like,
    tree_global_norm,
)
from repro.utils.registry import Registry
