"""chameleon-34b [vlm] — early-fusion multimodal LM (arXiv:2405.09818).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text + VQ image
codes in one table). Backbone only; the VQ-VAE image tokenizer is a stub —
image patches arrive as ordinary token ids (early fusion means exactly
this). Chameleon uses qk-norm for stability; swiglu; untied embeddings.
"""
from repro.configs.registry import arch_registry
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, act="swiglu", norm="rmsnorm",
)

arch_registry.register("chameleon-34b", CONFIG)
