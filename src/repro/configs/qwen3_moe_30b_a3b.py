"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B.

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936,
MoE 128 experts top-8. Qwen3 uses qk-norm, no QKV bias, head_dim=128.
"""
from repro.configs.registry import arch_registry
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128,
    n_experts=128, top_k=8,
    qk_norm=True, act="swiglu", norm="rmsnorm", rope_theta=1e6,
)

arch_registry.register("qwen3-moe-30b-a3b", CONFIG)
