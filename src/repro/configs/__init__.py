"""Assigned-architecture configs (one module per arch) + shape sets."""
from repro.configs.registry import arch_registry, get_arch, list_archs
from repro.configs.shapes import SHAPES, InputShape, shape_cells

# importing registers every arch
from repro.configs import (  # noqa: F401
    chameleon_34b, qwen3_moe_30b_a3b, granite_moe_1b_a400m, qwen2_5_32b,
    qwen2_72b, h2o_danube3_4b, codeqwen1_5_7b, xlstm_1_3b,
    seamless_m4t_medium, zamba2_7b,
)
