"""The four assigned input-shape sets (same for every LM arch).

``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers a full-sequence
forward; ``decode_32k`` / ``long_500k`` lower ``serve_step`` (one token
against a KV cache of ``seq_len``). ``long_500k`` requires sub-quadratic
attention — ``applies`` encodes the skip rule from the assignment.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"

    def applies(self, cfg: ModelConfig) -> bool:
        if self.name == "long_500k":
            return cfg.is_subquadratic
        return True

    def skip_reason(self, cfg: ModelConfig) -> str:
        if self.name == "long_500k" and not cfg.is_subquadratic:
            return ("pure full-attention arch: 524k-token KV/O(T^2) "
                    "attention exceeds the assignment's sub-quadratic "
                    "requirement (skip noted in DESIGN.md)")
        return ""


SHAPES = {
    "train_4k": InputShape("train_4k", seq_len=4_096, global_batch=256,
                           kind="train"),
    "prefill_32k": InputShape("prefill_32k", seq_len=32_768, global_batch=32,
                              kind="prefill"),
    "decode_32k": InputShape("decode_32k", seq_len=32_768, global_batch=128,
                             kind="decode"),
    "long_500k": InputShape("long_500k", seq_len=524_288, global_batch=1,
                            kind="decode"),
}


def shape_cells(cfg: ModelConfig):
    """All (shape, applies?) cells for an arch, in canonical order."""
    return [(s, s.applies(cfg)) for s in SHAPES.values()]
