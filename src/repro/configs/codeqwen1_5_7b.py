"""codeqwen1.5-7b [dense] — hf:Qwen/CodeQwen1.5-7B (qwen1.5 arch).

32L d_model=4096 32H (kv=32 -> full MHA) d_ff=13440 vocab=92416; QKV bias.
"""
from repro.configs.registry import arch_registry
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, act="swiglu", norm="rmsnorm", rope_theta=1e6,
)

arch_registry.register("codeqwen1.5-7b", CONFIG)
