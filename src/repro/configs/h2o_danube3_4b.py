"""h2o-danube-3-4b [dense] — arXiv:2401.16818 (danube line).

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000; llama+mistral mix
with sliding-window attention -> sub-quadratic, runs long_500k.
"""
from repro.configs.registry import arch_registry
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000,
    sliding_window=4096, act="swiglu", norm="rmsnorm",
)

arch_registry.register("h2o-danube-3-4b", CONFIG)
