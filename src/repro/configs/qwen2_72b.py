"""qwen2-72b [dense] — arXiv:2407.10671.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064; QKV bias on.
"""
from repro.configs.registry import arch_registry
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, act="swiglu", norm="rmsnorm", rope_theta=1e6,
)

arch_registry.register("qwen2-72b", CONFIG)
