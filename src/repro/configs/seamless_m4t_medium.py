"""seamless-m4t-medium [audio] — arXiv:2308.11596.

Enc-dec backbone: 12L encoder + 12L decoder, d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=256206. Speech frontend is a STUB per the assignment:
input_specs supplies precomputed frame embeddings.
"""
from repro.configs.registry import arch_registry
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=24, n_enc_layers=12, n_dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    norm="layernorm", act="gelu",
)

arch_registry.register("seamless-m4t-medium", CONFIG)
