"""xlstm-1.3b [ssm] — arXiv:2405.04517 (xLSTM[7:1]).

48 blocks d_model=2048 4 heads vocab=50304, d_ff=0 (blocks carry their own
projections); every 8th block sLSTM, rest mLSTM. Constant-state recurrence
-> sub-quadratic, runs long_500k.
"""
from repro.configs.registry import arch_registry
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_kinds=tuple("slstm" if (i % 8) == 7 else "mlstm"
                      for i in range(48)),
    norm="layernorm", act="gelu",
)

arch_registry.register("xlstm-1.3b", CONFIG)
