from __future__ import annotations

from repro.models.config import ModelConfig
from repro.utils.registry import Registry

arch_registry: Registry[ModelConfig] = Registry("architecture")


def get_arch(name: str) -> ModelConfig:
    return arch_registry.get(name)


def list_archs() -> list[str]:
    return arch_registry.names()
