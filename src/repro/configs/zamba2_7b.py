"""zamba2-7b [hybrid] — arXiv:2411.15242.

81 Mamba2 blocks d_model=3584, ssm_state=64, + ONE weight-shared
attention+MLP block (32H kv=32, d_ff=14336) invoked every 6 blocks
(we omit per-invocation LoRA; DESIGN.md). Hybrid -> runs long_500k.
"""
from repro.configs.registry import arch_registry
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_heads=112,
    attn_period=6,
    act="swiglu", norm="rmsnorm",
)

arch_registry.register("zamba2-7b", CONFIG)
