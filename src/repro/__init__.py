"""repro — NEAT (automated floating-point approximation exploration) as a
production JAX/TPU training + inference framework."""

__version__ = "1.0.0"
