"""Fault-tolerant checkpointing (no orbax in this environment).

Guarantees:
* **atomic** — state is written to ``step_XXXX.tmp/`` then ``os.rename``d;
  a crash mid-write can never corrupt the latest-valid pointer,
* **async** — serialization runs on a background thread; the train loop
  donates nothing to it (arrays are fetched to host first),
* **keep-k** — oldest checkpoints beyond ``keep`` are garbage-collected,
* **elastic restore** — arrays are restored host-side then ``device_put``
  with whatever shardings the *current* mesh prescribes, so a job may
  resume on a different pod count / mesh shape than it saved from,
* **integrity** — a manifest (step, tree structure, shapes, dtypes) is
  fsynced before the rename; restore validates shapes against it.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    from repro.utils.jax_compat import tree_flatten_with_path
    flat, treedef = tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        """Snapshot `state` (any pytree of arrays) at `step`."""
        self.wait()                      # one in-flight save at a time
        flat, _ = _flatten_with_paths(state)
        host = {k: np.asarray(v) for k, v in flat.items()}

        def _write():
            try:
                tmp = os.path.join(self.directory, f"step_{step:08d}.tmp")
                final = os.path.join(self.directory, f"step_{step:08d}")
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **host)
                manifest = {
                    "step": step,
                    "arrays": {k: {"shape": list(v.shape),
                                   "dtype": str(v.dtype)}
                               for k, v in host.items()},
                }
                mpath = os.path.join(tmp, "manifest.json")
                with open(mpath, "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)    # atomic publish
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if blocking:
            _write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {e}") from e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                steps.append(int(name[5:]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `target` (a pytree of arrays or
        ShapeDtypeStructs). `shardings` (same structure or None) enables
        elastic placement onto the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_t, treedef = _flatten_with_paths(target)
        flat_s = (_flatten_with_paths(shardings)[0]
                  if shardings is not None else {})
        out = {}
        for key, ref in flat_t.items():
            if key not in data:
                raise KeyError(f"checkpoint missing array {key!r}")
            arr = data[key]
            want = tuple(ref.shape)
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != target {want}")
            if key in flat_s and flat_s[key] is not None:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.numpy.asarray(arr, dtype=ref.dtype)
        # rebuild in target order
        leaves = [out[k] for k in flat_t]
        return jax.tree.unflatten(treedef, leaves)
