from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import Trainer, TrainerConfig
