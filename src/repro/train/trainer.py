"""The training loop: jit'd train_step with microbatch gradient
accumulation, global-norm clipping, AdamW, NEAT placement-rule support
(QAT under a mantissa policy), checkpoint/restart, and step-level fault
retry. Sharding-agnostic: under a mesh the caller passes in/out shardings
built by ``repro.sharding.specs``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.placement import PlacementRule
from repro.core.quantize import use_rule
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.clipping import clip_by_global_norm
from repro.optim.schedule import warmup_cosine
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    clip_norm: float = 1.0
    microbatches: int = 1             # gradient accumulation
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    max_step_retries: int = 2         # transient-failure tolerance


class Trainer:
    def __init__(self, loss_fn: Callable, cfg: TrainerConfig,
                 rule: Optional[PlacementRule] = None):
        """loss_fn(params, batch) -> (loss, metrics). `rule` applies NEAT
        placement during training (straight-through truncation)."""
        self.cfg = cfg
        self.rule = rule
        self.sched = warmup_cosine(cfg.peak_lr, cfg.warmup_steps,
                                   cfg.total_steps)
        self.ckpt = (CheckpointManager(cfg.checkpoint_dir,
                                       cfg.keep_checkpoints)
                     if cfg.checkpoint_dir else None)

        def step_fn(params, opt_state, batch, step):
            def lossm(p, b):
                out = loss_fn(p, b)
                return out if isinstance(out, tuple) else (out, {})

            if cfg.microbatches > 1:
                def micro(i, carry):
                    gacc, lacc = carry
                    mb = jax.tree.map(
                        lambda x: jax.lax.dynamic_slice_in_dim(
                            x, i * (x.shape[0] // cfg.microbatches),
                            x.shape[0] // cfg.microbatches, 0), batch)
                    (l, _), g = jax.value_and_grad(lossm, has_aux=True)(
                        params, mb)
                    gacc = jax.tree.map(jnp.add, gacc, g)
                    return gacc, lacc + l
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, loss = jax.lax.fori_loop(
                    0, cfg.microbatches, micro, (zeros, jnp.float32(0)))
                grads = jax.tree.map(
                    lambda g: g / cfg.microbatches, grads)
                loss = loss / cfg.microbatches
            else:
                (loss, _), grads = jax.value_and_grad(lossm, has_aux=True)(
                    params, batch)

            grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
            lr = self.sched(step)
            params, opt_state = adamw_update(grads, opt_state, params, lr,
                                             cfg.adamw)
            metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
            return params, opt_state, metrics

        self._step_fn = step_fn
        self._jitted: Optional[Callable] = None

    def compile(self, donate: bool = True, **jit_kwargs) -> Callable:
        if self._jitted is None:
            kw = dict(jit_kwargs)
            if donate:
                kw.setdefault("donate_argnums", (0, 1))
            self._jitted = jax.jit(self._step_fn, **kw)
        return self._jitted

    def init_state(self, params):
        return adamw_init(params, self.cfg.adamw)

    # -- the loop -------------------------------------------------------------
    def fit(self, params, data_fn: Callable[[int], Dict], *,
            steps: Optional[int] = None, start_step: int = 0,
            log_every: int = 50, resume: bool = True):
        """Run training. `data_fn(step)` must be deterministic in `step`
        (the synthetic pipeline is) — that is what makes restart/straggler
        skip-ahead exact."""
        cfg = self.cfg
        steps = steps if steps is not None else cfg.total_steps
        opt_state = self.init_state(params)
        step = start_step

        if resume and self.ckpt is not None and self.ckpt.latest_step():
            step = self.ckpt.latest_step()
            state = self.ckpt.restore(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"[trainer] resumed from step {step}")

        fn = self.compile()
        history = []
        with use_rule(self.rule):
            while step < steps:
                batch = data_fn(step)
                for attempt in range(cfg.max_step_retries + 1):
                    try:
                        params, opt_state, metrics = fn(
                            params, opt_state, batch, jnp.int32(step))
                        break
                    except Exception:
                        if attempt == cfg.max_step_retries:
                            raise
                        # re-jit after transient failure (lost buffers)
                        self._jitted = None
                        fn = self.compile()
                step += 1
                if step % log_every == 0 or step == steps:
                    m = {k: float(v) for k, v in metrics.items()}
                    history.append({"step": step, **m})
                    print(f"[trainer] step {step}: " +
                          " ".join(f"{k}={v:.4g}" for k, v in m.items()))
                if (self.ckpt is not None
                        and step % cfg.checkpoint_every == 0):
                    self.ckpt.save(step, {"params": params,
                                          "opt": opt_state})
        if self.ckpt is not None:
            self.ckpt.save(steps, {"params": params, "opt": opt_state},
                           blocking=True)
        return params, opt_state, history
