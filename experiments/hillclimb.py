"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse
for the three selected cells. Each variant is a build_cell invocation with
explicit levers; results land in experiments/perf/ and the before/after
log is printed for EXPERIMENTS.md §Perf.

  PYTHONPATH=src python experiments/hillclimb.py [--cell 1|2|3]
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import build_cell   # sets XLA device count first

OUT = os.path.join(os.path.dirname(__file__), "perf")

# (cell tag, arch, shape, [(variant name, kwargs, hypothesis)])
PLANS = {
    1: ("qwen2-72b_train_4k", "qwen2-72b", "train_4k", [
        ("base", dict(tp_intermediates=False),
         "baseline: GSPMD gathers full f32 weights per layer inside the "
         "scan (seen in HLO); collective-bound"),
        ("tp_hints_all", dict(tp_intermediates=True),
         "pin FFN-hidden AND head intermediates to the model axis; "
         "REFUTED in run 1: the heads hint fights the seq-sharded "
         "q-block scan (SPMD involuntary-remat warnings), collective "
         "6.6x WORSE - keep for the record"),
        ("mlp_hint_only", dict(tp_intermediates="hidden"),
         "pin only the FFN hidden (no heads hint): MLP weights are ~75% "
         "of per-layer bytes; predict most of the weight-gather saving "
         "without the attention resharding storm"),
        ("mlp_hint_bf16w", dict(tp_intermediates="hidden",
                                overrides={"param_dtype": "bfloat16"}),
         "gather weights in bf16 not f32; predict remaining weight-"
         "gather bytes halve"),
        ("mlp_bf16_dots", dict(tp_intermediates="hidden",
                              overrides={"param_dtype": "bfloat16",
                                         "remat_policy": "dots"}),
         "save dot outputs instead of full remat; predict compute term "
         "-20%, temp bytes up"),
    ]),
    2: ("granite-moe_train_4k", "granite-moe-1b-a400m", "train_4k", [
        ("base_ragged", dict(tp_intermediates=False,
                             overrides={"moe_impl": "ragged"}),
         "baseline: global-sort dropless dispatch under pjit; GSPMD "
         "must all-gather tokens for the sort -> collective-bound"),
        ("ep_shardmap", dict(tp_intermediates=False,
                             overrides={"moe_impl": "ep"}),
         "shard_map EP: experts on model axis, capacity dispatch local, "
         "one psum combine; predict collective down several x"),
        ("ep_tp_hints", dict(tp_intermediates=True,
                             overrides={"moe_impl": "ep"}),
         "add TP hints for the attention halves; predict further "
         "collective reduction"),
        ("ep_bf16w", dict(tp_intermediates=True,
                          overrides={"moe_impl": "ep",
                                     "param_dtype": "bfloat16"}),
         "bf16 weight gathers; predict collective/memory down ~2x on "
         "the weight-bound share"),
    ]),
    3: ("xlstm_decode_32k", "xlstm-1.3b", "decode_32k", [
        ("base", dict(tp_intermediates=False),
         "baseline: decode step re-gathers FSDP-sharded weights every "
         "token -> collective-bound decode"),
        ("no_fsdp", dict(tp_intermediates=False, fsdp=False),
         "serving weights should be TP-sharded but NOT FSDP-sharded "
         "(no updates to shard for); predict per-step weight gathers "
         "vanish, collective down ~10x"),
        ("no_fsdp_bf16w", dict(tp_intermediates=False, fsdp=False,
                               overrides={"param_dtype": "bfloat16"}),
         "bf16 resident weights; predict memory term down ~2x (decode "
         "is weight-bandwidth-bound)"),
        ("no_fsdp_bf16_hints", dict(tp_intermediates=True, fsdp=False,
                                    overrides={"param_dtype": "bfloat16"}),
         "TP hints on the recurrence projections; predict small further "
         "collective reduction"),
    ]),
}


def run(cell: int):
    tag, arch, shape, variants = PLANS[cell]
    os.makedirs(OUT, exist_ok=True)
    print(f"=== HILLCLIMB cell {cell}: {arch} x {shape} ===")
    prev = None
    for name, kwargs, hypothesis in variants:
        rec = build_cell(arch, shape, **kwargs)
        rec["variant"] = name
        rec["hypothesis"] = hypothesis
        path = os.path.join(OUT, f"{tag}__{name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] != "ok":
            print(f"[{name}] FAILED: {rec.get('error')}")
            continue
        ro = rec["roofline"]
        temp = rec["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
        line = (f"[{name}] compute={ro['compute_s']:.3f}s "
                f"memory={ro['memory_s']:.3f}s "
                f"collective={ro['collective_s']:.3f}s "
                f"bottleneck={ro['bottleneck']} step={ro['step_s']:.3f}s "
                f"mfu={ro['mfu']:.4f} temp={temp:.1f}GiB")
        if prev is not None:
            d = prev["step_s"] / max(ro["step_s"], 1e-12)
            line += f"  (step {d:.2f}x vs prev)"
        print("HYPOTHESIS:", hypothesis)
        print(line)
        prev = ro


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", type=int, default=None)
    args = ap.parse_args()
    cells = [args.cell] if args.cell else [1, 2, 3]
    for c in cells:
        run(c)
