"""CI gate over the bench-smoke artifacts.

Reads the ``BENCH_*.json`` files emitted by ``benchmarks.run`` and fails
(exit 1) when a regression lands:

* explorer: batched dispatch counts must stay well under the serial
  path's (the population batching exists to collapse them), and the
  batched/serial Pareto fronts must stay identical;
* explorer-dynamic: a dynamic-objective exploration must issue at most
  ``MAX_DYNAMIC_EXTRA_DISPATCHES`` more compiled dispatches than the
  static objective at identical budget (the bit-census accumulators ride
  the existing vmapped dispatch), the device-folded dynamic energies
  must match the host-side ``dynamic_fpu_energy`` reference to
  ``DYNAMIC_HOST_DEVICE_RTOL``, and dynamic energy must never exceed
  static for identical genomes;
* serve: the continuous engine must take <= 1/1.5 the compiled decode
  steps of the wave engine on the skewed workload, with identical greedy
  completions. Step time is constant at fixed batch shape, so the steps
  ratio is the deterministic form of the tokens/sec speedup.
* serve-prefill: chunked prefill must cut mean time-to-first-token by
  >= ``MIN_TTFT_SPEEDUP`` over streaming prefill on the skewed workload
  (expected ~an order of magnitude: 32-token chunks collapse ~96
  per-token dispatches into 3), with greedy completions identical to the
  wave reference; the chunked/streaming prefill *step* counts must also
  differ by >= the same factor (the deterministic form of the TTFT win).

Wall-clock numbers (us, tokens/sec) are reported but not gated except
for the serve-prefill TTFT ratio, whose expected margin dwarfs CI
runner noise — dispatch counts, step counts and parity bits are exact
for a fixed seed/workload.

  python -m benchmarks.check_smoke [--json-dir .]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

MIN_SERVE_SPEEDUP = 1.5
MIN_TTFT_SPEEDUP = 2.0             # chunked vs streaming prefill
MAX_DISPATCH_RATIO = 0.25          # batched <= serial / 4
MAX_DYNAMIC_EXTRA_DISPATCHES = 2   # dynamic objective <= static + 2
DYNAMIC_HOST_DEVICE_RTOL = 1e-6


def _rows(path: str) -> dict:
    with open(path) as f:
        return {name: derived for name, _, derived in json.load(f)["rows"]}


def _field(derived: str, key: str) -> str:
    for part in derived.split(";"):
        if part.startswith(key + "="):
            return part.split("=", 1)[1]
    raise KeyError(f"{key!r} not in {derived!r}")


def check_explorer(path: str) -> list:
    rows = _rows(path)
    errs = []
    disp = rows["explorer_dispatches"]
    batched = int(_field(disp, "batched"))
    serial = int(_field(disp, "serial"))
    if batched > serial * MAX_DISPATCH_RATIO:
        errs.append(f"explorer dispatch regression: batched={batched} "
                    f"vs serial={serial}")
    if not rows["explorer_front_identical"].startswith("True"):
        errs.append("explorer Pareto parity regression: batched front != "
                    f"serial front ({rows['explorer_front_identical']})")
    return errs


def check_explorer_dynamic(path: str) -> list:
    rows = _rows(path)
    errs = []
    disp = rows["explorer_dynamic_dispatches"]
    dyn = int(_field(disp, "dynamic"))
    stat = int(_field(disp, "static"))
    if dyn > stat + MAX_DYNAMIC_EXTRA_DISPATCHES:
        errs.append(f"dynamic-objective dispatch regression: dynamic={dyn} "
                    f"vs static={stat} (allowed +"
                    f"{MAX_DYNAMIC_EXTRA_DISPATCHES})")
    rel = float(_field(rows["explorer_dynamic_host_device"],
                       "max_rel_diff"))
    if not rel <= DYNAMIC_HOST_DEVICE_RTOL:
        errs.append(f"dynamic energy host/device divergence: max rel diff "
                    f"{rel:.3e} > {DYNAMIC_HOST_DEVICE_RTOL}")
    if _field(rows["explorer_dynamic_sanity"], "dyn_le_static") != "True":
        errs.append("dynamic energy exceeded static for an identical "
                    "genome — the census upper bound is broken")
    return errs


def check_serve(path: str) -> list:
    rows = _rows(path)
    errs = []
    cont_steps = int(_field(rows["serve_continuous"], "steps"))
    wave_steps = int(_field(rows["serve_wave"], "steps"))
    step_speedup = wave_steps / max(cont_steps, 1)
    if step_speedup < MIN_SERVE_SPEEDUP:
        errs.append(f"serve speedup regression: wave/continuous step "
                    f"ratio {step_speedup:.2f}x < {MIN_SERVE_SPEEDUP}x "
                    f"(wave={wave_steps}, continuous={cont_steps})")
    if _field(rows["serve_speedup"], "parity") != "True":
        errs.append("serve parity regression: continuous != wave "
                    "completions under greedy decoding")
    return errs


def check_serve_prefill(path: str) -> list:
    rows = _rows(path)
    errs = []
    ttft = float(_field(rows["serve_prefill_speedup"], "ttft_speedup")
                 .rstrip("x"))
    if ttft < MIN_TTFT_SPEEDUP:
        errs.append(f"chunked-prefill TTFT regression: {ttft:.2f}x < "
                    f"{MIN_TTFT_SPEEDUP}x over streaming prefill")
    ch_steps = int(_field(rows["serve_prefill_chunked"], "prefill_steps"))
    st_steps = int(_field(rows["serve_prefill_streaming"],
                          "prefill_steps"))
    step_ratio = st_steps / max(ch_steps, 1)
    if step_ratio < MIN_TTFT_SPEEDUP:
        errs.append(f"chunked-prefill step regression: streaming/chunked "
                    f"prefill-step ratio {step_ratio:.2f}x < "
                    f"{MIN_TTFT_SPEEDUP}x (streaming={st_steps}, "
                    f"chunked={ch_steps})")
    if _field(rows["serve_prefill_speedup"], "parity") != "True":
        errs.append("chunked-prefill parity regression: chunked != wave "
                    "greedy completions")
    return errs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    args = ap.parse_args()

    checks = [("BENCH_explorer_pop.json", check_explorer),
              ("BENCH_explorer-dynamic.json", check_explorer_dynamic),
              ("BENCH_serve.json", check_serve),
              ("BENCH_serve-prefill.json", check_serve_prefill)]
    errs = []
    for fname, fn in checks:
        path = os.path.join(args.json_dir, fname)
        if not os.path.exists(path):
            errs.append(f"missing artifact {fname} — did benchmarks.run "
                        "--only explorer,serve succeed?")
            continue
        errs.extend(fn(path))

    if errs:
        for e in errs:
            print(f"[check_smoke] FAIL: {e}", file=sys.stderr)
        raise SystemExit(1)
    print("[check_smoke] OK: dispatch counts, Pareto parity, dynamic-"
          "energy host/device agreement, serve speedup and chunked-"
          "prefill TTFT within bounds")


if __name__ == "__main__":
    main()
